//! End-to-end behavioral tests on the simulator: the paper's qualitative
//! claims as assertions (the quantitative versions are the bench/figure
//! drivers in `harness::experiments`).

use matchmaker::config::{Configuration, OptFlags};
use matchmaker::harness::experiments::{run_horizontal_schedule, run_reconfig_schedule};
use matchmaker::harness::{msec, secs, Cluster};
use matchmaker::metrics::{interval_summary, timeline};
use matchmaker::node::Announce;
use matchmaker::roles::{Client, Leader, Matchmaker, Replica};
use matchmaker::sim::NetworkModel;
use matchmaker::workload::WorkloadSpec;
use matchmaker::{MS, SEC};

/// §8.1 headline: reconfiguration every second changes median latency and
/// throughput by only a few percent.
#[test]
fn reconfiguration_has_negligible_impact() {
    let run = run_reconfig_schedule(1, 4, true, 42, secs(21));
    let a = interval_summary(&run.samples, 0, secs(10)).unwrap();
    let b = interval_summary(&run.samples, secs(10), secs(20)).unwrap();
    let lat_change = ((b.latency.median - a.latency.median) / a.latency.median).abs();
    let tput_change =
        ((b.throughput.median - a.throughput.median) / a.throughput.median).abs();
    assert!(lat_change < 0.05, "median latency changed {:.1}%", lat_change * 100.0);
    assert!(tput_change < 0.05, "median throughput changed {:.1}%", tput_change * 100.0);
}

/// §8.1: "the new acceptors become active within a millisecond [of the
/// matchmaking round trip]; the old acceptors are garbage collected within
/// five milliseconds"; H_i stays a single configuration.
#[test]
fn reconfiguration_is_fast_and_gc_converges() {
    let run = run_reconfig_schedule(1, 4, true, 7, secs(21));
    assert!(run.reconfig_latencies.len() >= 10);
    for (active_ms, retired_ms) in &run.reconfig_latencies {
        assert!(*active_ms < 5.0, "activation took {active_ms} ms");
        let retired = retired_ms.expect("GC must complete");
        assert!(retired < 20.0, "retirement took {retired} ms");
    }
    assert!(run.max_prior_configs <= 1, "matchmakers returned {} configs", run.max_prior_configs);
}

/// Thriftiness trade-off (§8.1): after an acceptor failure, thrifty
/// throughput collapses until the reconfiguration replaces the dead node;
/// non-thrifty barely notices. Both recover fully.
#[test]
fn thrifty_failure_dip_and_recovery() {
    for thrifty in [true, false] {
        let run = run_reconfig_schedule(1, 4, thrifty, 11, secs(35));
        let before = interval_summary(&run.samples, secs(20), secs(25)).unwrap();
        let during = interval_summary(&run.samples, secs(26), secs(30)).unwrap();
        let after = interval_summary(&run.samples, secs(31), secs(35)).unwrap();
        let dip = during.throughput.median / before.throughput.median;
        if thrifty {
            assert!(dip < 0.5, "thrifty dip was only {:.2}x", dip);
        } else {
            assert!(dip > 0.8, "non-thrifty dipped {:.2}x", dip);
        }
        let recovery = after.throughput.median / before.throughput.median;
        assert!(recovery > 0.9, "throughput did not recover: {:.2}", recovery);
    }
}

/// §8.2 ablation shape on an emulated WAN (+250 ms Phase1B/MatchB):
/// without optimizations a reconfiguration stalls commands for ~500 ms;
/// with Phase-1 bypassing ~250 ms; with all optimizations no stall.
#[test]
fn ablation_stall_shape() {
    let gap_for = |opts: OptFlags| -> u64 {
        let net = NetworkModel::default().with_wan_phase1(250 * MS);
        let mut cluster = Cluster::builder().opts(opts).seed(3).net(net).build();
        let leader = cluster.initial_leader();
        let cfg = cluster.random_config(1);
        cluster.sim.schedule(secs(4), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        cluster.sim.run_until(secs(8));
        cluster.assert_safe();
        // Largest inter-completion gap around the reconfiguration.
        let samples = cluster.samples();
        let mut gap = 0u64;
        let mut prev = secs(3);
        for (t, _) in samples.iter().filter(|(t, _)| *t > secs(3)) {
            gap = gap.max(t - prev);
            prev = *t;
        }
        gap
    };

    let none = gap_for(OptFlags {
        proactive_matchmaking: false,
        phase1_bypass: false,
        garbage_collection: true,
        round_pruning: false,
        thrifty: true,
        ..OptFlags::default()
    });
    let bypass = gap_for(OptFlags {
        proactive_matchmaking: false,
        phase1_bypass: true,
        garbage_collection: true,
        round_pruning: false,
        thrifty: true,
        ..OptFlags::default()
    });
    let all = gap_for(OptFlags::default());

    assert!(none >= 450 * MS, "no-opt stall was {} ms", none / MS);
    assert!(
        (200 * MS..450 * MS).contains(&bypass),
        "bypass-only stall was {} ms",
        bypass / MS
    );
    assert!(all < 50 * MS, "fully-optimized stall was {} ms", all / MS);
}

/// §8.3: leader failure stops progress; the next proposer takes over after
/// its election timeout and throughput recovers.
#[test]
fn leader_failover_recovers() {
    let mut cluster = Cluster::builder().seed(5).build();
    let p0 = cluster.layout.proposers[0];
    let p1 = cluster.layout.proposers[1];
    if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
        l.timing.election_timeout = secs(2);
    }
    cluster.sim.schedule(secs(3), move |s| s.crash(p0));
    cluster.sim.run_until(secs(8));
    cluster.assert_safe();
    let samples = cluster.samples();
    let tl = timeline(&samples, secs(8), SEC, SEC);
    // Outage window [3s, 5s]: throughput ~0. Recovery by 7s.
    assert!(tl.throughput[3] < tl.throughput[1] * 0.5, "no outage visible");
    assert!(
        tl.throughput[7] > tl.throughput[1] * 0.7,
        "no recovery: {:?}",
        tl.throughput
    );
    // The new leader is steady.
    assert!(cluster
        .sim
        .announces
        .iter()
        .any(|(_, n, a)| *n == p1 && matches!(a, Announce::LeaderSteady { .. })));
}

/// §8.4: matchmaker reconfigurations are off the critical path — a storm
/// of them changes client-visible performance by < 5%.
#[test]
fn matchmaker_reconfig_off_critical_path() {
    let mut cluster = Cluster::builder().seed(6).build();
    let leader = cluster.initial_leader();
    for i in 0..10u64 {
        let set = cluster.random_matchmakers();
        cluster.sim.schedule(secs(2) + i * SEC / 2, move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| {
                l.reconfigure_matchmakers(set.clone(), now, fx)
            });
        });
    }
    cluster.sim.run_until(secs(8));
    cluster.assert_safe();
    let samples = cluster.samples();
    let quiet = interval_summary(&samples, 0, secs(2)).unwrap();
    let storm = interval_summary(&samples, secs(2), secs(7)).unwrap();
    let change = ((storm.latency.median - quiet.latency.median) / quiet.latency.median).abs();
    assert!(change < 0.05, "mm reconfig affected latency by {:.1}%", change * 100.0);
    // And acceptor reconfiguration still works afterwards.
    let cfg = cluster.random_config(77);
    cluster.sim.schedule(msec(8100), move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });
    cluster.sim.run_until(secs(10));
    cluster.assert_safe();
    let leader_node = cluster.sim.node_mut::<Leader>(leader).unwrap();
    assert!(leader_node.gc_completed >= 2);
}

/// f = 2 clusters work end to end, including reconfiguration.
#[test]
fn f2_cluster_end_to_end() {
    let mut cluster = Cluster::builder().f(2).seed(8).build();
    let leader = cluster.initial_leader();
    assert_eq!(cluster.layout.initial_config().acceptors.len(), 5);
    let cfg = cluster.random_config(1);
    cluster.sim.schedule(secs(1), move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });
    cluster.sim.run_until(secs(2));
    cluster.assert_safe();
    assert!(cluster.samples().len() > 1000);
}

/// The horizontal baseline also reconfigures without visible impact
/// (Figure 10) — the paper's point is parity in the steady case, with
/// matchmakers winning on generality.
#[test]
fn horizontal_baseline_parity() {
    let (samples, _) = run_horizontal_schedule(1, 4, true, 9, secs(21));
    let a = interval_summary(&samples, 0, secs(10)).unwrap();
    let b = interval_summary(&samples, secs(10), secs(20)).unwrap();
    let change = ((b.latency.median - a.latency.median) / a.latency.median).abs();
    assert!(change < 0.05, "horizontal reconfig changed latency {:.1}%", change * 100.0);
}

/// A replica that loses messages catches up via leader re-sends, and a
/// late-started client still gets served.
#[test]
fn replica_catchup_and_late_client() {
    let mut cluster = Cluster::builder().clients(1).seed(10).build();
    let replica = cluster.layout.replicas[0];
    let other = cluster.layout.replicas[1];
    // Partition one replica from the leader for a while.
    let leader = cluster.initial_leader();
    cluster.sim.schedule(msec(100), move |s| s.set_link(leader, replica, false));
    cluster.sim.schedule(msec(900), move |s| s.set_link(leader, replica, true));
    // A second client whose workload only starts at 1.2 s.
    let late = cluster.layout.clients[0] + 1;
    cluster.sim.add_node(
        late,
        Box::new(Client::new(
            late,
            cluster.layout.proposers.clone(),
            WorkloadSpec::closed_loop().start_at(msec(1200)),
        )),
    );
    cluster.sim.run_until(secs(3));
    cluster.assert_safe();
    let wm_cut = cluster.sim.node_mut::<Replica>(replica).unwrap().exec_watermark;
    let wm_ok = cluster.sim.node_mut::<Replica>(other).unwrap().exec_watermark;
    // The cut replica must have caught up to within a small tail.
    assert!(
        wm_cut + 64 >= wm_ok,
        "replica did not catch up: {wm_cut} vs {wm_ok}"
    );
    let late_samples = &cluster.sim.node_mut::<Client>(late).unwrap().samples;
    assert!(!late_samples.is_empty(), "late client starved");
}

/// GC is required for matchmaker logs to stay bounded: without it, |H_i|
/// grows with every reconfiguration (Optimization 3's motivation).
#[test]
fn without_gc_prior_configs_accumulate() {
    let mut opts = OptFlags::default();
    opts.garbage_collection = false;
    let mut cluster = Cluster::builder().clients(2).opts(opts).seed(12).build();
    let leader = cluster.initial_leader();
    for i in 0..5u64 {
        let cfg = cluster.random_config(i + 1);
        cluster.sim.schedule(msec(200 + i * 200), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
    }
    cluster.sim.run_until(secs(2));
    cluster.assert_safe();
    let leader_node = cluster.sim.node_mut::<Leader>(leader).unwrap();
    assert!(
        leader_node.max_prior_configs >= 4,
        "expected H_i to grow without GC, saw {}",
        leader_node.max_prior_configs
    );
    // Matchmaker logs likewise retain all rounds.
    let mm = cluster.layout.initial_matchmakers()[0];
    let log_len = cluster.sim.node_mut::<Matchmaker>(mm).unwrap().total_log_len();
    assert!(log_len >= 5, "matchmaker log unexpectedly short: {log_len}");
}

/// Optimization 5 (concurrent Matchmaking + Phase 1): on a WAN where both
/// MatchB and Phase1B cost 250 ms, a leader election reaches steady state
/// in ~1 delayed round trip instead of two.
#[test]
fn concurrent_phase1_saves_a_round_trip() {
    let steady_time = |concurrent: bool| -> u64 {
        let mut opts = OptFlags::default();
        opts.concurrent_phase1 = concurrent;
        let net = NetworkModel::default().with_wan_phase1(250 * MS);
        let mut cluster = Cluster::builder().clients(2).opts(opts).seed(21).net(net).build();
        let p0 = cluster.layout.proposers[0];
        let p1 = cluster.layout.proposers[1];
        if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
            l.timing.election_timeout = secs(1);
        }
        cluster.sim.schedule(secs(2), move |s| s.crash(p0));
        cluster.sim.run_until(secs(6));
        cluster.assert_safe();
        // Time from crash to the new leader's steady announcement.
        cluster
            .sim
            .announces
            .iter()
            .find_map(|(t, n, a)| {
                (*n == p1 && matches!(a, Announce::LeaderSteady { .. })).then_some(*t)
            })
            .expect("new leader steady")
            - secs(2)
    };
    let sequential = steady_time(false);
    let concurrent = steady_time(true);
    // Sequential: election wait + MatchB (250 ms) + Phase1B (250 ms).
    // Concurrent: election wait + max(MatchB, Phase1B) = one 250 ms wait.
    assert!(
        sequential >= concurrent + 200 * MS,
        "opt 5 saved only {} ms (sequential {} ms, concurrent {} ms)",
        (sequential - concurrent) / MS,
        sequential / MS,
        concurrent / MS
    );
    assert!(concurrent < secs(2), "concurrent election took {} ms", concurrent / MS);
}

/// Nemesis regression: a deposed leader's stale heartbeats, still
/// arriving through an asymmetric partition, must not suppress a
/// follower's election ticks. Old leader p0 is isolated except for a
/// one-way heartbeat path to follower p2; p1 takes over at a higher
/// epoch, then crashes. p2 has seen p1's epoch, so p0's still-flowing
/// old-epoch heartbeats are stale and must not reset p2's election
/// timer — without the epoch fence in the leader's Heartbeat handler,
/// p2 defers to the ghost forever and the cluster never recovers.
#[test]
fn stale_heartbeats_do_not_suppress_elections() {
    let mut cluster = Cluster::builder().f(2).seed(14).build();
    let p0 = cluster.layout.proposers[0];
    let p1 = cluster.layout.proposers[1];
    let p2 = cluster.layout.proposers[2];
    // The gray old leader never notices its own stall: quorum-loss
    // step-down is disabled so its stale heartbeats keep flowing.
    if let Some(l) = cluster.sim.node_mut::<Leader>(p0) {
        l.timing.quorum_loss_timeout = secs(100);
    }
    cluster.sim.schedule(secs(3), move |s| {
        // Asymmetric partition: p0 hears nothing and reaches nothing —
        // except its one-way heartbeat link to p2, which stays open.
        for n in s.node_ids() {
            if n != p0 {
                s.set_link_oneway(n, p0, false);
            }
            if n != p0 && n != p2 {
                s.set_link_oneway(p0, n, false);
            }
        }
    });
    // p1 stops hearing heartbeats and takes over; p2 keeps deferring —
    // first to p0's then-live heartbeats, then to p1's. At 6s the new
    // leader crashes: only p0's stale heartbeats still reach p2.
    cluster.sim.schedule(secs(6), move |s| s.crash(p1));
    cluster.sim.run_until(secs(10));
    cluster.assert_safe();
    // The partitioned old leader still believes it leads — its stale
    // heartbeats really were flowing at p2 the whole time ...
    assert!(
        cluster.sim.node_mut::<Leader>(p0).unwrap().is_leader,
        "test premise broken: the ghost leader stepped down"
    );
    // ... yet p2 elected itself over them after p1's crash.
    assert!(
        cluster.sim.announces.iter().any(|(at, n, a)| {
            *n == p2 && *at > secs(6) && matches!(a, Announce::LeaderSteady { .. })
        }),
        "follower never took over: stale heartbeats suppressed its election"
    );
    let samples = cluster.samples();
    let tl = timeline(&samples, secs(10), SEC, SEC);
    assert!(
        tl.throughput[9] > tl.throughput[1] * 0.5,
        "no recovery after the ghost-leader crash: {:?}",
        tl.throughput
    );
}
