//! Integration tests for `repro sweep` (DESIGN.md §Sweeps): seeded
//! determinism of the sweep pipeline end to end, and the baseline
//! regression gate driven through real files on disk.

use matchmaker::harness::report::{BenchJson, BenchRow};
use matchmaker::sweep::{self, ParameterSpace, SweepConfig, SweepMode};
use matchmaker::SEC;
use std::path::PathBuf;

/// A small seeded sample so the double-run determinism tests stay
/// cheap; the full smoke sample is exercised by `repro sweep` in CI.
fn small_sample() -> Vec<SweepConfig> {
    ParameterSpace::default().sample(6, 7)
}

/// A scratch directory unique to this test binary run.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mm_sweep_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

#[test]
fn smoke_mode_covers_at_least_fifty_distinct_configurations() {
    let configs = SweepMode::Smoke.configs(42);
    assert!(configs.len() >= 50, "smoke sweep must run >= 50 configs, got {}", configs.len());
    let mut labels: Vec<String> = configs.iter().map(|c| c.label()).collect();
    labels.sort();
    labels.dedup();
    assert_eq!(labels.len(), configs.len(), "labels must be distinct");
    // Per-config seeds are position-independent and pairwise distinct.
    let mut seeds: Vec<u64> = configs.iter().map(|c| c.seed(42)).collect();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), configs.len(), "derived seeds must be distinct");
}

/// The tentpole determinism guarantee: same configs + same root seed →
/// byte-identical artifacts (BENCH JSON and CSV, which includes every
/// composite score), regardless of how many worker threads ran the
/// sweep or how the scheduler interleaved them.
#[test]
fn same_root_seed_is_byte_identical_across_runs_and_job_counts() {
    let configs = small_sample();
    let duration = SEC / 2;
    let a = sweep::run_sweep(&configs, 42, duration, 2);
    let b = sweep::run_sweep(&configs, 42, duration, 5);

    let json_a = sweep::to_bench_json(&a, SweepMode::Smoke, 42).to_json();
    let json_b = sweep::to_bench_json(&b, SweepMode::Smoke, 42).to_json();
    assert_eq!(json_a, json_b, "BENCH artifacts must be byte-identical");

    let csv_a = sweep::to_csv(&a);
    let csv_b = sweep::to_csv(&b);
    assert_eq!(csv_a, csv_b, "CSV artifacts must be byte-identical");

    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(
            ra.score.to_bits(),
            rb.score.to_bits(),
            "composite score must be bit-identical for {}",
            ra.config.label()
        );
    }
}

/// `repro sweep --only LABEL` replays one configuration in isolation;
/// its row must match the same label's row from a full parallel sweep
/// bit for bit (the seed depends only on the root seed and the label).
#[test]
fn single_config_replay_matches_its_row_in_a_full_sweep() {
    let configs = small_sample();
    let duration = SEC / 2;
    let rows = sweep::run_sweep(&configs, 42, duration, 3);
    let target = &rows[configs.len() / 2];
    let solo = sweep::run_config(&target.config, 42, duration);
    assert_eq!(solo.seed, target.seed);
    assert_eq!(solo.throughput.to_bits(), target.throughput.to_bits());
    assert_eq!(solo.p50_ms.to_bits(), target.p50_ms.to_bits());
    assert_eq!(solo.p99_ms.to_bits(), target.p99_ms.to_bits());
    assert_eq!(solo.score.to_bits(), target.score.to_bits());
    assert_eq!(solo.max_log_len, target.max_log_len);
}

/// A different root seed re-derives every per-config simulation seed.
#[test]
fn different_root_seed_changes_every_derived_seed() {
    let configs = small_sample();
    for cfg in &configs {
        assert_ne!(cfg.seed(42), cfg.seed(43), "{}", cfg.label());
    }
}

/// The sweep's BENCH artifact survives a write → read → parse round
/// trip through the filesystem, via the same schema as `repro exp
/// --bench-json`.
#[test]
fn sweep_bench_artifact_round_trips_through_disk() {
    let configs = ParameterSpace::default().sample(3, 11);
    let rows = sweep::run_sweep(&configs, 42, SEC / 2, 0);
    let bench = sweep::to_bench_json(&rows, SweepMode::Smoke, 42);
    let dir = scratch("roundtrip");
    let path = dir.join("BENCH_sweep_smoke.json");
    std::fs::write(&path, bench.to_json()).unwrap();
    let parsed = BenchJson::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(parsed, bench);
    let _ = std::fs::remove_dir_all(&dir);
}

fn fixture_row(label: &str, throughput: f64) -> BenchRow {
    BenchRow {
        label: label.to_string(),
        throughput,
        p50_ms: 0.5,
        p99_ms: 2.0,
        offered_per_sec: 4000.0,
    }
}

fn fixture_bench(rows: Vec<BenchRow>) -> BenchJson {
    BenchJson { experiment: "sweep_smoke".to_string(), seed: 42, rows }
}

/// The regression gate, driven through real baseline files: a
/// synthetically degraded run must fail with a diagnostic naming the
/// offending configuration and its worst axis; an improved run must
/// pass and print the delta. Wall-clock baselines (x10) are skipped.
#[test]
fn compare_dir_gates_regressions_and_passes_improvements() {
    let dir = scratch("gate");
    // The committed baseline pins two configurations.
    let baseline =
        fixture_bench(vec![fixture_row("cfg_alpha", 1000.0), fixture_row("cfg_beta", 1000.0)]);
    std::fs::write(dir.join("BENCH_sweep_smoke.json"), baseline.to_json()).unwrap();
    // An x10 baseline rides along and must be skipped, not re-run.
    let x10 = BenchJson {
        experiment: "x10".to_string(),
        seed: 42,
        rows: vec![fixture_row("pre_crash", 300.0)],
    };
    std::fs::write(dir.join("BENCH_x10.json"), x10.to_json()).unwrap();

    // Degraded: cfg_beta lost half its throughput.
    let degraded =
        fixture_bench(vec![fixture_row("cfg_alpha", 1000.0), fixture_row("cfg_beta", 500.0)]);
    let report = sweep::compare_dir(&dir, &degraded, 42)
        .expect_err("a 50% throughput drop must fail the 10% gate");
    assert!(report.contains("cfg_beta"), "diagnostic must name the config: {report}");
    assert!(report.contains("throughput"), "diagnostic must name the axis: {report}");
    assert!(report.contains("FAIL"), "{report}");
    assert!(!report.contains("cfg_alpha regressed"), "{report}");

    // Improved: both configurations got faster — passes, prints deltas.
    let improved =
        fixture_bench(vec![fixture_row("cfg_alpha", 1400.0), fixture_row("cfg_beta", 1300.0)]);
    let report = sweep::compare_dir(&dir, &improved, 42).expect("improvements must pass");
    assert!(report.contains("improved"), "{report}");
    assert!(report.contains('+'), "delta missing: {report}");
    assert!(report.contains("not gated"), "x10 skip note missing: {report}");

    // Identical: passes within tolerance.
    sweep::compare_dir(&dir, &baseline, 42).expect("identical rows must pass");

    let _ = std::fs::remove_dir_all(&dir);
}

/// A missing pinned configuration is a failure (a silently dropped
/// config must not pass the gate), and a root-seed mismatch is called
/// out rather than producing a wall of missing-label noise.
#[test]
fn compare_dir_rejects_missing_configs_and_seed_mismatch() {
    let dir = scratch("missing");
    let baseline =
        fixture_bench(vec![fixture_row("cfg_kept", 1000.0), fixture_row("cfg_gone", 800.0)]);
    std::fs::write(dir.join("BENCH_sweep_smoke.json"), baseline.to_json()).unwrap();

    let current = fixture_bench(vec![fixture_row("cfg_kept", 1000.0)]);
    let report = sweep::compare_dir(&dir, &current, 42).expect_err("dropped config must fail");
    assert!(report.contains("cfg_gone"), "{report}");
    assert!(report.contains("missing"), "{report}");

    let report = sweep::compare_dir(&dir, &baseline, 99)
        .expect_err("root-seed mismatch must fail loudly");
    assert!(report.contains("--seed"), "{report}");

    let _ = std::fs::remove_dir_all(&dir);
}
