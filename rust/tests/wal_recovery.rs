//! Durability integration suite (DESIGN.md §Durability).
//!
//! The unit tests in `storage/wal.rs` pin single known corruptions; this
//! suite sweeps a *seeded corpus* of random damage — torn tails, bit
//! flips, appended garbage, stomped length prefixes — and asserts the
//! recovery contract from the outside: replay always yields a clean
//! prefix of what was appended (never reordered, never fabricated),
//! role recovery over a damaged log equals recovery over its surviving
//! prefix, and the chunked snapshot transfer survives a receiver
//! `kill -9` mid-stream.

use matchmaker::config::SnapshotSpec;
use matchmaker::msg::{Command, Msg, Value};
use matchmaker::node::{Announce, Effects, Node, Timer};
use matchmaker::roles::{Acceptor, Replica};
use matchmaker::round::Round;
use matchmaker::statemachine;
use matchmaker::storage::wal::{WalOptions, WalStorage};
use matchmaker::storage::{scratch_dir, MemStorage, Storage, WalRecord};
use matchmaker::{Slot, MS};
use std::fs;
use std::path::{Path, PathBuf};

/// Deterministic xorshift64* — the corpus must not depend on ambient
/// entropy, so a failing case number reproduces exactly.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }
}

fn opts() -> WalOptions {
    // fsync off: the corpus hammers hundreds of appends, and damage is
    // injected after the handle closes anyway. Tiny segments keep
    // rotation (and cross-segment damage) in play.
    WalOptions { fsync: false, segment_bytes: 512, full_every: 2 }
}

fn r(epoch: u64) -> Round {
    Round { epoch, proposer: 1, seq: 0 }
}

fn records(n: u64) -> Vec<WalRecord> {
    (0..n)
        .map(|i| match i % 3 {
            0 => WalRecord::Promise { round: r(i + 1) },
            1 => WalRecord::Vote {
                slot: i,
                vr: r(i),
                vv: Value::Cmd(Command { client: 7, seq: i, payload: vec![i as u8; 9] }),
            },
            _ => WalRecord::Chosen { slot: i, value: Value::Noop },
        })
        .collect()
}

fn segment_files(dir: &Path) -> Vec<PathBuf> {
    let mut segs: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .unwrap()
                .to_string_lossy()
                .strip_prefix("wal-")
                .is_some_and(|rest| rest.ends_with(".log"))
        })
        .collect();
    segs.sort();
    segs
}

/// Whatever the damage, replay yields a *prefix* of what was appended,
/// and the repaired log accepts appends that survive a further reopen.
#[test]
fn corruption_corpus_recovers_a_clean_prefix() {
    let mut rng = Rng(0xC0FF_EED1_5EA5_E500);
    let recs = records(120);
    for case in 0..48 {
        let dir = scratch_dir(&format!("wal-corpus-{case}"));
        {
            let mut w = WalStorage::open(&dir, opts()).unwrap();
            for rec in &recs {
                w.append(rec).unwrap();
            }
        }
        let segs = segment_files(&dir);
        assert!(segs.len() > 1, "corpus needs rotation in play");
        // A torn write can only physically land on the newest segment
        // (appends go nowhere else); flips/garbage/stomps model media
        // damage and may hit any segment — CRC framing detects those,
        // truncates there, and drops every later segment.
        let target = if case % 4 == 0 {
            segs.last().unwrap()
        } else {
            &segs[rng.below(segs.len())]
        };
        let mut bytes = fs::read(target).unwrap();
        match case % 4 {
            0 => {
                // Torn tail: chop 1..=24 bytes off the newest segment.
                let cut = 1 + rng.below(24.min(bytes.len() - 1));
                bytes.truncate(bytes.len() - cut);
            }
            1 => {
                // Single bit flip anywhere in the segment.
                let at = rng.below(bytes.len());
                bytes[at] ^= 1 << rng.below(8);
            }
            2 => {
                // Garbage appended past the last frame.
                for _ in 0..1 + rng.below(16) {
                    bytes.push(rng.next() as u8);
                }
            }
            _ => {
                // Stomp four bytes with a wild length prefix.
                let at = rng.below(bytes.len().saturating_sub(4).max(1));
                let end = (at + 4).min(bytes.len());
                bytes[at..end].copy_from_slice(&[0xFF; 4][..end - at]);
            }
        }
        fs::write(target, &bytes).unwrap();

        let mut w = WalStorage::open(&dir, opts()).unwrap();
        let got = w.replay().unwrap();
        assert!(got.len() <= recs.len(), "case {case}: records fabricated");
        assert_eq!(
            got.as_slice(),
            &recs[..got.len()],
            "case {case}: replay is not a prefix of the appended records"
        );
        if case % 4 != 2 {
            // Tears, flips, and stomps always claim at least one frame;
            // only appended garbage can leave the full log intact.
            assert!(got.len() < recs.len(), "case {case}: damage went undetected");
        }
        // The repaired log is writable, and repair + new append survive
        // a reopen.
        w.append(&WalRecord::Watermark { upto: 999 }).unwrap();
        drop(w);
        let mut w = WalStorage::open(&dir, opts()).unwrap();
        let after = w.replay().unwrap();
        assert_eq!(after.len(), got.len() + 1, "case {case}: repair did not persist");
        assert_eq!(after[after.len() - 1], WalRecord::Watermark { upto: 999 });
        fs::remove_dir_all(&dir).unwrap();
    }
}

/// Role-level soundness: recovering an acceptor over a damaged WAL is
/// identical to recovering over the WAL's surviving record prefix — the
/// role sees a *shorter* history after a crash, never a corrupt one.
#[test]
fn acceptor_recovery_over_damaged_wal_matches_surviving_prefix() {
    let mut rng = Rng(0xBADC_0DE0_0000_0001);
    for case in 0..12 {
        let dir = scratch_dir(&format!("wal-acc-{case}"));
        {
            let mut w = WalStorage::open(&dir, opts()).unwrap();
            // An acceptor-shaped history: rising promises, votes, and a
            // watermark advance partway through.
            for i in 0..40u64 {
                w.append(&WalRecord::Promise { round: r(i + 1) }).unwrap();
                w.append(&WalRecord::Vote {
                    slot: i,
                    vr: r(i + 1),
                    vv: Value::Cmd(Command { client: 3, seq: i, payload: vec![0xAB; 5] }),
                })
                .unwrap();
                if i == 20 {
                    w.append(&WalRecord::Watermark { upto: 10 }).unwrap();
                }
            }
        }
        // Tear a random amount off the newest segment.
        let segs = segment_files(&dir);
        let target = segs.last().unwrap();
        let len = fs::metadata(target).unwrap().len();
        let cut = 1 + rng.below(len as usize - 1);
        let f = fs::OpenOptions::new().write(true).open(target).unwrap();
        f.set_len(len - cut as u64).unwrap();
        drop(f);

        // Recover a live acceptor straight over the damaged directory.
        let mut from_wal = Acceptor::new(2);
        from_wal.attach_storage(Box::new(WalStorage::open(&dir, opts()).unwrap()));
        from_wal.recover(&mut Effects::new());

        // Independently read the surviving prefix and feed it through an
        // in-memory log: the two recoveries must agree exactly.
        let mut reader = WalStorage::open(&dir, opts()).unwrap();
        let surviving = reader.replay().unwrap();
        drop(reader);
        let mut mem = MemStorage::default();
        for rec in &surviving {
            mem.append(rec).unwrap();
        }
        let mut from_mem = Acceptor::new(2);
        from_mem.attach_storage(Box::new(mem));
        from_mem.recover(&mut Effects::new());

        assert_eq!(from_wal.round, from_mem.round, "case {case}");
        assert_eq!(from_wal.votes, from_mem.votes, "case {case}");
        assert_eq!(from_wal.chosen_watermark, from_mem.chosen_watermark, "case {case}");
        fs::remove_dir_all(&dir).unwrap();
    }
}

fn counter_replica(id: u32) -> Replica {
    let mut rep = Replica::new(id, statemachine::by_name("counter").unwrap());
    let mut spec = SnapshotSpec::every(MS, 4);
    // Below the constructor's retry-horizon clamp: a tiny tail forces
    // real truncation (and hence the chunked transfer path) at small
    // command counts.
    spec.tail = 4;
    rep.snapshot = spec;
    rep.peers = vec![1, 2];
    rep
}

fn chosen(slot: Slot) -> Msg {
    Msg::Chosen {
        slot,
        value: Value::Cmd(Command {
            client: 7,
            seq: slot + 1,
            payload: 1i64.to_le_bytes().to_vec(),
        }),
    }
}

/// The chunked snapshot transfer survives a receiver `kill -9`
/// mid-stream: the restarted receiver (recovered from its WAL) steers
/// the sender back to chunk 0 with `SnapshotResume`, assembles the full
/// restream, and persists the installed snapshot so a *second* crash
/// recovers the transferred state from disk alone.
#[test]
fn chunked_transfer_resumes_after_receiver_restart() {
    // Source: 40 counter increments, snapshotted and truncated, tiny
    // chunks so the transfer has a mid-stream to die in.
    let mut src = counter_replica(1);
    src.chunk_bytes = 16;
    let mut fx = Effects::new();
    for s in 0..40 {
        src.on_msg(MS, 0, chosen(s), &mut fx);
    }
    let mut fx = Effects::new();
    src.on_timer(2 * MS, Timer::SnapshotTick, &mut fx);
    assert!(src.truncated_below > 0, "source never truncated");

    let dir = scratch_dir("wal-chunk-restart");
    let boot = || {
        let mut rep = counter_replica(2);
        rep.attach_storage(Box::new(WalStorage::open(&dir, opts()).unwrap()));
        rep.recover();
        rep
    };
    let mut rx = boot();

    // Leader hint → snapshot request → chunks flow.
    let mut fx = Effects::new();
    rx.on_msg(3 * MS, 0, Msg::CatchUp { below: 40, peer: 1 }, &mut fx);
    assert!(
        fx.msgs
            .iter()
            .any(|(to, m)| *to == 1 && matches!(m, Msg::SnapshotRequest { .. })),
        "{:?}",
        fx.msgs
    );
    let mut sfx = Effects::new();
    src.on_msg(3 * MS, 2, Msg::SnapshotRequest { from: 0 }, &mut sfx);
    let chunks: Vec<Msg> =
        sfx.msgs.into_iter().filter(|(to, _)| *to == 2).map(|(_, m)| m).collect();
    assert!(chunks.len() >= 3, "state did not chunk ({} frames)", chunks.len());
    assert!(matches!(chunks[0], Msg::SnapshotChunk { seq: 0, .. }));

    // Deliver only the first chunk, then kill -9 the receiver.
    let mut fx = Effects::new();
    rx.on_msg(4 * MS, 1, chunks[0].clone(), &mut fx);
    drop(rx);
    let mut rx = boot();
    assert_eq!(rx.exec_watermark, 0, "nothing was durable yet");

    // A mid-stream chunk hits the restarted receiver: it must steer the
    // sender back to the start of the stream.
    let mut fx = Effects::new();
    rx.on_msg(5 * MS, 1, chunks[1].clone(), &mut fx);
    let resume = fx.msgs.iter().find_map(|(to, m)| match m {
        Msg::SnapshotResume { base, next } if *to == 1 => Some((*base, *next)),
        _ => None,
    });
    assert_eq!(resume.map(|(_, next)| next), Some(0), "{:?}", fx.msgs);

    // The sender restreams from chunk 0; the receiver assembles the
    // full set and installs.
    let (base, _) = resume.unwrap();
    let mut sfx = Effects::new();
    src.on_msg(5 * MS, 2, Msg::SnapshotResume { base, next: 0 }, &mut sfx);
    let restream: Vec<Msg> =
        sfx.msgs.into_iter().filter(|(to, _)| *to == 2).map(|(_, m)| m).collect();
    assert_eq!(restream.len(), chunks.len(), "resume did not restart from chunk 0");
    let mut installed = false;
    for m in restream {
        let mut fx = Effects::new();
        rx.on_msg(6 * MS, 1, m, &mut fx);
        installed |= fx
            .announces
            .iter()
            .any(|a| matches!(a, Announce::SnapshotInstalled { .. }));
    }
    assert!(installed, "assembled snapshot did not install");
    assert_eq!(rx.exec_watermark, 40);
    assert_eq!(rx.sm.digest(), src.sm.digest());

    // The install was persisted: a second kill -9 recovers the
    // transferred state from the receiver's own WAL directory alone.
    drop(rx);
    let rx = boot();
    assert_eq!(rx.exec_watermark, 40);
    assert_eq!(rx.sm.digest(), src.sm.digest());
    fs::remove_dir_all(&dir).unwrap();
}
