//! Property-based safety tests.
//!
//! The §3/§5/§6 proofs become executable invariants here. A small
//! in-tree property-test driver (seeded exploration over the deterministic
//! simulator; every failure reports its seed, so shrinking = re-running
//! with that seed) replaces an external proptest dependency — the build is
//! fully offline.

use matchmaker::codec::{sample_messages, Wire};
use matchmaker::config::{AdmissionSpec, Configuration, LeaseSpec, OptFlags, SnapshotSpec};
use matchmaker::metrics::check_counter_reads;
use matchmaker::harness::{msec, secs, Cluster, ShardedCluster};
use matchmaker::msg::{Envelope, Msg, Value};
use matchmaker::node::Announce;
use matchmaker::quorum::QuorumSpec;
use matchmaker::roles::router::{key_of_payload, shard_of};
use matchmaker::roles::{Leader, Matchmaker, Replica};
use matchmaker::sim::NetworkModel;
use matchmaker::statemachine::{Counter, KvStore};
use matchmaker::util::Rng;
use matchmaker::workload::WorkloadSpec;
use matchmaker::{GroupId, NodeId, Slot};
use std::collections::{BTreeMap, BTreeSet};

/// Run `f` for `cases` seeds; panics carry the seed for reproduction.
fn property(name: &str, cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

// =========================================================================
// Chosen-safety under adversarial conditions
// =========================================================================

/// Reconfiguration storm + lossy network: at most one value is ever chosen
/// per slot, and replicas never diverge.
#[test]
fn safety_under_reconfig_storm_and_loss() {
    property("reconfig storm + loss", 8, |seed| {
        let net = NetworkModel {
            drop_prob: 0.05,
            jitter: 80 * matchmaker::US,
            ..NetworkModel::default()
        };
        let mut cluster = Cluster::builder().clients(3).seed(seed).net(net).build();
        let leader = cluster.initial_leader();
        // 20 reconfigurations, one every 50 ms.
        for i in 0..20u64 {
            let cfg = cluster.random_config(i + 1);
            cluster.sim.schedule(msec(100 + i * 50), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        assert_replicas_prefix_consistent(&mut cluster);
    });
}

/// Crashing up to f acceptors of the active configuration never violates
/// safety (liveness may suffer until a reconfiguration, which we perform).
#[test]
fn safety_under_acceptor_crashes() {
    property("acceptor crashes", 8, |seed| {
        let mut cluster = Cluster::builder().clients(3).seed(seed).build();
        let leader = cluster.initial_leader();
        let mut rng = Rng::new(seed ^ 0xdead);
        // Crash one initial acceptor early, reconfigure away later.
        let victim = cluster.layout.initial_config().acceptors
            [rng.gen_range(3) as usize];
        cluster.sim.schedule(msec(200), move |s| s.crash(victim));
        let healthy: Vec<NodeId> = cluster
            .layout
            .acceptor_pool
            .iter()
            .copied()
            .filter(|&a| a != victim)
            .take(3)
            .collect();
        let cfg = Configuration::majority(9, healthy);
        cluster.sim.schedule(msec(600), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        // The system must have made progress after the repair.
        let samples = cluster.samples();
        assert!(
            samples.iter().any(|(t, _)| *t > msec(1200)),
            "no progress after reconfiguration away from crashed acceptor"
        );
    });
}

/// Dueling leaders: repeatedly force the follower to usurp leadership
/// while the old leader is still alive. Nacks + matchmaker refusals must
/// keep the system safe.
#[test]
fn safety_under_dueling_leaders() {
    property("dueling leaders", 8, |seed| {
        let mut cluster = Cluster::builder().clients(3).seed(seed).build();
        let p1 = cluster.layout.proposers[1];
        for i in 0..5u64 {
            cluster.sim.schedule(msec(150 + i * 150), move |s| {
                s.with_node::<Leader, _>(p1, |l, now, fx| l.become_leader(now, fx));
            });
        }
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        assert_replicas_prefix_consistent(&mut cluster);
    });
}

/// Leader crash + election under message loss.
#[test]
fn safety_under_leader_failover_with_loss() {
    property("leader failover + loss", 6, |seed| {
        let net = NetworkModel { drop_prob: 0.02, ..NetworkModel::default() };
        let mut cluster = Cluster::builder().clients(3).seed(seed).net(net).build();
        let p0 = cluster.layout.proposers[0];
        let p1 = cluster.layout.proposers[1];
        if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
            l.timing.election_timeout = msec(300);
        }
        cluster.sim.schedule(msec(500), move |s| s.crash(p0));
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();
        let samples = cluster.samples();
        assert!(
            samples.iter().any(|(t, _)| *t > secs(2)),
            "no progress after failover (seed {seed})"
        );
    });
}

/// Matchmaker reconfiguration storms compose with acceptor
/// reconfigurations without violating safety.
#[test]
fn safety_under_matchmaker_reconfig_storm() {
    property("mm reconfig storm", 6, |seed| {
        let mut cluster = Cluster::builder().clients(2).seed(seed).build();
        let leader = cluster.initial_leader();
        for i in 0..6u64 {
            let mms = cluster.random_matchmakers();
            cluster.sim.schedule(msec(200 + i * 200), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure_matchmakers(mms.clone(), now, fx)
                });
            });
            let cfg = cluster.random_config(i + 1);
            cluster.sim.schedule(msec(300 + i * 200), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();
        assert_replicas_prefix_consistent(&mut cluster);
    });
}

/// Phase 2 batching tentpole property: under a reconfiguration storm,
/// every batched command is decided exactly once and executed in
/// per-client FIFO order with no gaps — with and without Optimizations
/// 1/2 (proactive matchmaking, Phase 1 bypassing), i.e. both when
/// batches keep flowing to `C_old` during matchmaking and when they
/// stall and drain through the full Phase 1 path.
#[test]
fn batching_exactly_once_fifo_across_reconfig() {
    for (proactive, bypass) in [(true, true), (false, false)] {
        let name = format!("batching exactly-once (opt1={proactive}, opt2={bypass})");
        property(&name, 5, |seed| {
            let mut opts = OptFlags::default().with_batching(8, 500 * matchmaker::US);
            opts.proactive_matchmaking = proactive;
            opts.phase1_bypass = bypass;
            let mut cluster = Cluster::builder().clients(6).opts(opts).seed(seed).build();
            let leader = cluster.initial_leader();
            // Four reconfigurations while commands stream.
            for i in 0..4u64 {
                let cfg = cluster.random_config(i + 1);
                cluster.sim.schedule(msec(250 + i * 250), move |s| {
                    s.with_node::<Leader, _>(leader, |l, now, fx| {
                        l.reconfigure(cfg.clone(), now, fx)
                    });
                });
            }
            cluster.sim.run_until(secs(2));
            cluster.assert_safe();
            assert_batched_exactly_once_fifo(&mut cluster);
            assert_replicas_prefix_consistent(&mut cluster);
            // Commands flowed throughout (no permanent stall).
            let samples = cluster.samples();
            assert!(
                samples.iter().any(|(t, _)| *t > msec(1500)),
                "no progress late in the run (seed {seed})"
            );
        });
    }
}

/// Workload-API tentpole property: open-loop and pipelined clients (a
/// pipeline window > 1, so the network can reorder a client's in-flight
/// requests) under a reconfiguration storm still yield exactly-once,
/// per-client-FIFO execution — across Optimizations 1/2 on and off,
/// i.e. both when commands keep flowing to `C_old` during matchmaking
/// and when they stall and drain through the full Phase 1 path.
#[test]
fn pipelined_and_open_loop_exactly_once_fifo_across_reconfig() {
    let workloads: [(&str, WorkloadSpec); 3] = [
        ("pipelined-4", WorkloadSpec::pipelined(4)),
        ("open-loop", WorkloadSpec::open_loop(2000.0).max_in_flight(8)),
        ("open-loop-poisson", WorkloadSpec::open_loop_poisson(1500.0).max_in_flight(8)),
    ];
    for (wl_name, spec) in &workloads {
        for (proactive, bypass) in [(true, true), (false, false)] {
            let name =
                format!("{wl_name} exactly-once FIFO (opt1={proactive}, opt2={bypass})");
            property(&name, 3, |seed| {
                let mut opts = OptFlags::default();
                opts.proactive_matchmaking = proactive;
                opts.phase1_bypass = bypass;
                let mut cluster = Cluster::builder()
                    .clients(4)
                    .workload(spec.clone())
                    .opts(opts)
                    .seed(seed)
                    .build();
                let leader = cluster.initial_leader();
                // Four reconfigurations while requests are pipelined.
                for i in 0..4u64 {
                    let cfg = cluster.random_config(i + 1);
                    cluster.sim.schedule(msec(250 + i * 250), move |s| {
                        s.with_node::<Leader, _>(leader, |l, now, fx| {
                            l.reconfigure(cfg.clone(), now, fx)
                        });
                    });
                }
                cluster.sim.run_until(secs(2));
                cluster.assert_safe();
                assert_batched_exactly_once_fifo(&mut cluster);
                assert_replicas_prefix_consistent(&mut cluster);
                // Commands flowed throughout (no permanent stall).
                let samples = cluster.samples();
                assert!(
                    samples.iter().any(|(t, _)| *t > msec(1500)),
                    "no progress late in the run (seed {seed})"
                );
            });
        }
    }
}

/// Leased-reads tentpole property: linearizable reads never return
/// stale values across a reconfiguration storm on a lossy network.
/// Counter state machine (+1 writes, total-reads), interleaved
/// reads/writes with reads landing at every replica, across: leases on
/// (grant fast path, with natural expiry/revocation as the storm pauses
/// renewals), leases on with Optimizations 1/2 off (reads span full
/// Phase-1 installs), leases off (the pure one-message ReadIndex
/// fallback), and leases on across a leader crash + election (the
/// lease-fence path). Every completed read is checked against the
/// global write history: it must observe at least every write
/// acknowledged before it was issued.
#[test]
fn leased_reads_never_stale_across_reconfig_storm() {
    let variants: [(bool, bool, bool, bool); 4] = [
        // (leases, opt1 proactive, opt2 bypass, crash the leader)
        (true, true, true, false),
        (true, false, false, false),
        (false, true, true, false),
        (true, true, true, true),
    ];
    for (leases_on, proactive, bypass, crash) in variants {
        let name = format!(
            "leased reads (leases={leases_on}, opt1={proactive}, opt2={bypass}, crash={crash})"
        );
        property(&name, 3, |seed| {
            let mut opts = OptFlags::default();
            opts.proactive_matchmaking = proactive;
            opts.phase1_bypass = bypass;
            if leases_on {
                opts.leases = LeaseSpec::every(msec(30), msec(2), 100 * matchmaker::US);
            }
            let net = NetworkModel {
                drop_prob: 0.03,
                jitter: 80 * matchmaker::US,
                ..NetworkModel::default()
            };
            let spec = WorkloadSpec::open_loop(800.0)
                .max_in_flight(8)
                .read_fraction(0.5)
                .payload(1i64.to_le_bytes().to_vec())
                .read_payload(Vec::new())
                .stop_at(msec(2200));
            let mut cluster = Cluster::builder()
                .clients(4)
                .workload(spec)
                .opts(opts)
                .net(net)
                .seed(seed)
                .build();
            for &r in &cluster.layout.replicas.clone() {
                if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
                    rep.sm = Box::new(Counter::new());
                }
            }
            let p0 = cluster.initial_leader();
            // 5-reconfiguration storm while reads and writes interleave
            // (all scheduled before the optional crash at 700 ms, so no
            // control-plane call ever targets a dead node).
            for i in 0..5u64 {
                let cfg = cluster.random_config(i + 1);
                cluster.sim.schedule(msec(300 + i * 80), move |s| {
                    s.with_node::<Leader, _>(p0, |l, now, fx| {
                        l.reconfigure(cfg.clone(), now, fx)
                    });
                });
            }
            if crash {
                // Leader change mid-storm: outstanding leases must be
                // fenced out before the new leader's Phase 2.
                let p1 = cluster.layout.proposers[1];
                if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
                    l.timing.election_timeout = msec(300);
                }
                cluster.sim.schedule(msec(700), move |s| s.crash(p0));
            }
            cluster.sim.run_until(secs(3));
            cluster.assert_safe();
            let reads = cluster.read_records();
            let (completions, issues) = cluster.write_records();
            assert!(!reads.is_empty(), "no reads completed (seed {seed})");
            if let Err(e) = check_counter_reads(&reads, &completions, &issues) {
                panic!("stale read (seed {seed}): {e}");
            }
            // Reads were served at every replica, via the expected path.
            let stats = cluster.read_path_stats();
            for (r, leased, indexed) in &stats {
                assert!(
                    leased + indexed > 0,
                    "replica {r} served no reads (seed {seed}): {stats:?}"
                );
                if !leases_on {
                    assert_eq!(*leased, 0, "grant served with leases off (seed {seed})");
                }
            }
            if leases_on && !crash {
                assert!(
                    stats.iter().any(|(_, l, _)| *l > 0),
                    "leased fast path never used (seed {seed}): {stats:?}"
                );
            }
        });
    }
}

/// X7 acceptance gate (ISSUE 5): at equal offered load under the
/// 40 µs/msg egress model, the 90/10 leased mix sustains ≥ 2x the
/// all-through-Phase-2 baseline's throughput; zero stale reads across
/// the 5-reconfiguration storm in every variant; and the lease-expiry
/// fallback (no lease → one-message ReadIndex) stays linearizable.
#[test]
fn read_scaling_meets_acceptance() {
    use matchmaker::harness::experiments::{run_read_scaling, ReadVariant};
    let duration = secs(3);
    let base = run_read_scaling(42, ReadVariant::Baseline, duration);
    let fallback = run_read_scaling(42, ReadVariant::ReadIndexOnly, duration);
    let leased = run_read_scaling(42, ReadVariant::Leased, duration);
    for (label, run) in
        [("baseline", &base), ("read-index", &fallback), ("leased", &leased)]
    {
        run.check_linearizable()
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        assert!(
            run.reconfigs_completed >= 6,
            "{label}: storm incomplete ({} installs)",
            run.reconfigs_completed
        );
        assert!(run.summary.reads > 1000, "{label}: only {} reads", run.summary.reads);
    }
    // Offered load is identical by construction; the leased mix must at
    // least double aggregate throughput.
    let ratio = leased.summary.completed_per_sec / base.summary.completed_per_sec;
    assert!(
        ratio >= 2.0,
        "leased reads gained only {ratio:.2}x ({:.0} vs {:.0} ops/s at {:.0}/s offered)",
        leased.summary.completed_per_sec,
        base.summary.completed_per_sec,
        base.summary.offered_per_sec
    );
    // The leased run actually served the bulk of its reads from grants,
    // not the fallback; the no-lease run used only the fallback.
    let grants: u64 = leased.read_path.iter().map(|(_, l, _)| *l).sum();
    let indexed: u64 = leased.read_path.iter().map(|(_, _, i)| *i).sum();
    assert!(
        grants > indexed,
        "leases barely used: {grants} leased vs {indexed} indexed"
    );
    assert!(
        fallback.read_path.iter().all(|(_, l, _)| *l == 0),
        "no-lease run served grant reads: {:?}",
        fallback.read_path
    );
}

/// X9 acceptance gate (ISSUE 9): sweep offered load from well below to
/// past the saturation point under the 40 µs/msg egress model with
/// admission on (Busy + delayed retry, 16-slot inbox, 20 ms SLO, one
/// reconfiguration mid-run). Goodput at the top of the sweep must hold
/// within 10% of the sweep's peak — the leader pushes excess back
/// instead of collapsing under its own queue — and the completed-request
/// tail stays bounded instead of growing with the backlog. A shed-policy
/// run at the top rate must hold the same floor. (The admission-off
/// comparison rows render in `repro exp x9`; this gate pins only the
/// admission-on behavior.)
#[test]
fn overload_holds_goodput_past_saturation() {
    use matchmaker::harness::experiments::{run_overload, AdmissionPolicy};
    let duration = secs(3);
    let rates = [250.0, 500.0, 1000.0, 2000.0, 4000.0];
    let rows: Vec<_> = rates
        .iter()
        .map(|&r| run_overload(42, r, AdmissionPolicy::Retry, duration))
        .collect();
    // Sanity at the bottom of the sweep: far below saturation, nearly
    // everything offered completes.
    assert!(
        rows[0].goodput >= 0.8 * rows[0].offered_per_sec,
        "unsaturated run lost traffic: {:.0}/s of {:.0}/s offered",
        rows[0].goodput,
        rows[0].offered_per_sec
    );
    let peak = rows.iter().map(|r| r.goodput).fold(0.0f64, f64::max);
    let top = rows.last().unwrap();
    // The top of the sweep is actually past saturation: arrivals outrun
    // completions enough to overflow the bounded client queues.
    assert!(top.abandoned > 0, "top rate never overflowed a queue bound");
    assert!(
        top.offered_per_sec > top.goodput,
        "top rate not saturated: offered {:.0}/s, goodput {:.0}/s",
        top.offered_per_sec,
        top.goodput
    );
    // The gate: goodput holds within 10% of the sweep peak ...
    assert!(
        top.goodput >= 0.9 * peak,
        "goodput collapsed past saturation: {:.0}/s vs peak {:.0}/s",
        top.goodput,
        peak
    );
    // ... with the tail bounded (a congestion-collapsed leader shows
    // multi-second tails as its inbox grows for the whole run).
    assert!(top.p99_ms <= 2_000.0, "p99 unbounded at the top rate: {:.1} ms", top.p99_ms);
    // Shedding instead of delayed retry holds the same goodput floor.
    let shed = run_overload(42, 4000.0, AdmissionPolicy::Shed, duration);
    assert!(
        shed.goodput >= 0.85 * peak && shed.p99_ms <= 2_000.0,
        "shed policy degraded: {:.0}/s (peak {:.0}/s), p99 {:.1} ms",
        shed.goodput,
        peak,
        shed.p99_ms
    );
}

/// X12 acceptance gate (ISSUE 10): the scripted nemesis schedule
/// (partition the leader from its acceptors → heal → asymmetric
/// matchmaker partition → gray-slow acceptor → lease-clock skew) meets
/// the degradation bar. Zero chosen-safety/lease violations are checked
/// inside each run against the 1 ms drift envelope (the run panics on
/// violation); this gate pins the rest: zero stale reads, every
/// post-heal recovery bounded, goodput outside the fault windows ≥ 90%
/// of the fault-free twin at the same seed, and a byte-identical report
/// across two runs at the same seed (every injection is deterministic).
#[test]
fn x12_nemesis_schedule_meets_acceptance() {
    use matchmaker::harness::experiments::nemesis_figure;
    let rep = nemesis_figure(42);
    for n in &rep.notes {
        assert!(!n.contains("STALE"), "stale read in X12: {n}");
    }
    assert_eq!(rep.rows.len(), 4, "schedule produced {} fault windows", rep.rows.len());
    for row in &rep.rows {
        assert!(
            row.recover_ms.is_finite() && row.recover_ms <= 1_500.0,
            "{}: unbounded post-heal recovery ({:.1} ms)",
            row.label,
            row.recover_ms
        );
        assert!(
            row.max_stall_ms <= 2_500.0,
            "{}: unavailability exceeded the bound ({:.1} ms)",
            row.label,
            row.max_stall_ms
        );
    }
    // The leader partition actually caused an outage (step-down +
    // failover take ~1 s under the default detector timeouts); the
    // schedule is not a no-op.
    assert!(
        rep.rows[0].max_stall_ms >= 100.0,
        "leader partition caused no visible stall ({:.1} ms)",
        rep.rows[0].max_stall_ms
    );
    // Degradation stays graceful: outside the fault windows the faulted
    // run keeps ≥ 90% of the fault-free twin's goodput.
    assert!(rep.goodput_fault_free > 0.0, "fault-free twin made no progress");
    assert!(
        rep.goodput_faulted >= 0.9 * rep.goodput_fault_free,
        "goodput outside faults degraded: {:.0}/s vs {:.0}/s fault-free",
        rep.goodput_faulted,
        rep.goodput_fault_free
    );
    // Same seed → byte-identical report: the whole schedule (injections
    // included) lives in the deterministic event stream.
    assert_eq!(
        rep.render(),
        nemesis_figure(42).render(),
        "X12 report differs across two runs at the same seed"
    );
}

/// Nemesis tentpole property (ISSUE 10): a seeded asymmetric-partition
/// storm (short one-way cuts and heals over every proposer, acceptor,
/// and matchmaker) composed with a 4-reconfiguration storm preserves
/// exactly-once per-client FIFO over the chosen stream and read
/// linearizability against the global write history — across nemesis
/// on/off, Optimizations 1/2 on/off, and leases on/off. Each cut stays
/// below the election timeout, so this pins safety under *gray*
/// asymmetry (requests or replies vanish in one direction) rather than
/// under failover, which the X12 gate covers.
#[test]
fn nemesis_storm_preserves_exactly_once_fifo_and_linearizable_reads() {
    use matchmaker::nemesis::NemesisPlan;
    for nemesis in [true, false] {
        for (proactive, bypass) in [(true, true), (false, false)] {
            for leases_on in [true, false] {
                let name = format!(
                    "nemesis storm (nemesis={nemesis}, opt1={proactive}, \
                     opt2={bypass}, leases={leases_on})"
                );
                property(&name, 2, |seed| {
                    let mut opts = OptFlags::default();
                    opts.proactive_matchmaking = proactive;
                    opts.phase1_bypass = bypass;
                    if leases_on {
                        opts.leases =
                            LeaseSpec::every(msec(30), msec(2), 100 * matchmaker::US);
                    }
                    let spec = WorkloadSpec::open_loop(600.0)
                        .max_in_flight(8)
                        .read_fraction(0.5)
                        .payload(1i64.to_le_bytes().to_vec())
                        .read_payload(Vec::new())
                        .stop_at(msec(2200));
                    let mut cluster = Cluster::builder()
                        .clients(4)
                        .workload(spec)
                        .opts(opts)
                        .seed(seed)
                        .build();
                    for &r in &cluster.layout.replicas.clone() {
                        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
                            rep.sm = Box::new(Counter::new());
                        }
                    }
                    let leader = cluster.initial_leader();
                    for i in 0..4u64 {
                        let cfg = cluster.random_config(i + 1);
                        cluster.sim.schedule(msec(250 + i * 250), move |s| {
                            s.with_node::<Leader, _>(leader, |l, now, fx| {
                                l.reconfigure(cfg.clone(), now, fx)
                            });
                        });
                    }
                    if nemesis {
                        let mut targets = cluster.layout.proposers.clone();
                        targets.extend_from_slice(&cluster.layout.acceptor_pool);
                        targets.extend_from_slice(&cluster.layout.matchmaker_pool);
                        let plan = NemesisPlan::storm(seed, &targets, 2_000);
                        assert!(!plan.is_empty(), "storm produced no faults");
                        plan.apply_to_sim(&mut cluster.sim);
                    }
                    cluster.sim.run_until(secs(3));
                    cluster.assert_safe();
                    assert_chosen_stream_exactly_once_fifo(&cluster);
                    let reads = cluster.read_records();
                    let (completions, issues) = cluster.write_records();
                    assert!(!reads.is_empty(), "no reads completed (seed {seed})");
                    if let Err(e) = check_counter_reads(&reads, &completions, &issues) {
                        panic!("stale read (seed {seed}): {e}");
                    }
                    let samples = cluster.samples();
                    assert!(
                        samples.iter().any(|(t, _)| *t > msec(1500)),
                        "no progress late in the run (seed {seed})"
                    );
                });
            }
        }
    }
}

/// Overload-control tentpole property (ISSUE 9): Busy pushback with a
/// one-slot inbox — every pipelined window collides with the admission
/// bound, so the leader emits a sustained Busy storm — under a
/// 4-reconfiguration storm, with Optimizations 1/2 on and off and both
/// pushback policies. A Busy is a drop, not an ack: the leader advances
/// no per-client state for a rejected request, so the chosen stream
/// stays exactly-once with per-client seqs strictly increasing in slot
/// order. Under the retry policy nothing is ever abandoned, so the
/// stream must additionally be gap-free contiguous FIFO; under shedding
/// a shed seq legitimately leaves a gap (it is never chosen), but a
/// shed-then-reissued window must never reorder past, or duplicate, a
/// later command from the same client.
#[test]
fn busy_pushback_preserves_exactly_once_fifo_across_reconfig() {
    for shed in [false, true] {
        for (proactive, bypass) in [(true, true), (false, false)] {
            let name =
                format!("busy pushback FIFO (shed={shed}, opt1={proactive}, opt2={bypass})");
            property(&name, 3, |seed| {
                let mut opts = OptFlags::default();
                opts.proactive_matchmaking = proactive;
                opts.phase1_bypass = bypass;
                // One-slot inbox: with 4 clients x window 4, most of
                // every window beyond the head is rejected with Busy.
                opts.admission = AdmissionSpec::slo(1, 5_000, shed);
                let mut cluster = Cluster::builder()
                    .clients(4)
                    .workload(WorkloadSpec::pipelined(4))
                    .opts(opts)
                    .seed(seed)
                    .build();
                let leader = cluster.initial_leader();
                for i in 0..4u64 {
                    let cfg = cluster.random_config(i + 1);
                    cluster.sim.schedule(msec(250 + i * 250), move |s| {
                        s.with_node::<Leader, _>(leader, |l, now, fx| {
                            l.reconfigure(cfg.clone(), now, fx)
                        });
                    });
                }
                cluster.sim.run_until(secs(2));
                cluster.assert_safe();
                // The run actually exercised admission end to end: the
                // leader rejected requests and clients saw the pushback.
                let load = cluster.group_load();
                assert!(load.busy_rejections > 0, "no Busy emitted (seed {seed})");
                assert!(cluster.busy_observed() > 0, "no Busy delivered (seed {seed})");
                let (_, completed, abandoned) = cluster.workload_totals();
                assert!(completed > 0, "nothing completed under pushback (seed {seed})");
                if shed {
                    assert!(abandoned > 0, "shed policy never shed (seed {seed})");
                    assert_chosen_stream_exactly_once_monotone(&cluster);
                } else {
                    // Delayed retry never abandons; the stream is the
                    // full contiguous per-client FIFO.
                    assert_eq!(abandoned, 0, "retry policy abandoned (seed {seed})");
                    assert_chosen_stream_exactly_once_fifo(&cluster);
                }
                // Progress continued despite pushback + the storm.
                let samples = cluster.samples();
                assert!(
                    samples.iter().any(|(t, _)| *t > msec(1500)),
                    "no progress late in the run (seed {seed})"
                );
            });
        }
    }
}

/// State-retention tentpole property: snapshots + log truncation +
/// snapshot catch-up never lose or reorder a chosen command. A
/// reconfiguration storm runs with snapshots enabled on a lossy network;
/// one replica crashes mid-run and a fresh machine rejoins under its id
/// (its prefix is truncated cluster-wide, forcing the snapshot-transfer
/// path). The global chosen stream must stay exactly-once per-client
/// FIFO, and replicas with equal watermarks must hold identical state —
/// including the rejoined one.
#[test]
fn truncation_and_catchup_exactly_once_fifo() {
    // Per-client kv writes: the value depends on the client, so replica
    // digests reflect which commands actually executed.
    fn kv_payload(id: NodeId) -> Vec<u8> {
        KvStore::enc_set(&id.to_le_bytes(), &(id as u64).to_le_bytes())
    }
    property("snapshot truncation + rejoin", 5, |seed| {
        let net = NetworkModel {
            drop_prob: 0.01,
            jitter: 60 * matchmaker::US,
            ..NetworkModel::default()
        };
        let mut opts = OptFlags::default();
        // A deliberately tiny interval/tail so truncation happens many
        // times within the run.
        opts.snapshot = SnapshotSpec::every(20 * matchmaker::MS, 128);
        let mut cluster = Cluster::builder()
            .clients(4)
            .workload(
                WorkloadSpec::pipelined(4)
                    .payload_with(kv_payload)
                    .stop_at(secs(2)),
            )
            .opts(opts)
            .seed(seed)
            .net(net)
            .build();
        for &r in &cluster.layout.replicas.clone() {
            if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
                rep.sm = Box::new(KvStore::new());
            }
        }
        let leader = cluster.initial_leader();
        for i in 0..4u64 {
            let cfg = cluster.random_config(i + 1);
            cluster.sim.schedule(msec(300 + i * 300), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        // Crash replica 2 mid-storm; a fresh machine rejoins 400 ms later.
        let victim = cluster.layout.replicas[2];
        let peers = cluster.layout.replicas.clone();
        let spec = opts.snapshot;
        cluster.sim.schedule(msec(600), move |s| s.crash(victim));
        cluster.sim.schedule(msec(1000), move |s| {
            let mut rep = Replica::new(victim, Box::new(KvStore::new()));
            rep.snapshot = spec;
            rep.peers = peers;
            s.replace_node(victim, Box::new(rep));
        });
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();

        // The global chosen stream (slot order) is exactly-once and
        // per-client FIFO — truncation must not have dropped or
        // reordered anything that was decided.
        assert_chosen_stream_exactly_once_fifo(&cluster);

        // Replicas with equal executed prefixes hold identical state;
        // the rejoined replica went through snapshot transfer.
        let replicas = cluster.layout.replicas.clone();
        let mut states: Vec<(NodeId, Slot, u64, u64)> = Vec::new();
        for &r in &replicas {
            let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
            states.push((r, rep.exec_watermark, rep.sm.digest(), rep.snapshots_installed));
        }
        for i in 1..states.len() {
            if states[0].1 == states[i].1 {
                assert_eq!(
                    states[0].2, states[i].2,
                    "equal watermarks, different state: {:?} vs {:?} (seed {seed})",
                    states[0], states[i]
                );
            }
        }
        let rejoined = states.iter().find(|(r, ..)| *r == victim).unwrap();
        assert!(
            rejoined.3 >= 1,
            "rejoined replica never installed a snapshot (seed {seed}): {rejoined:?}"
        );
        assert!(rejoined.1 > 0, "rejoined replica made no progress (seed {seed})");
    });
}

// =========================================================================
// Sharded multi-group properties (headline for the sharding tentpole)
// =========================================================================

/// Sharding tentpole property: N consensus groups behind one shared
/// matchmaker set, pipelined and open-loop shard-routing clients, and a
/// **concurrent multi-group reconfiguration storm** (every group
/// reconfigures several times, interleaved) on a lossy, reordering
/// network — with Optimizations 1/2 on and off, and with snapshotting
/// (log truncation) enabled so the checker must survive truncated logs.
///
/// Invariants checked per seed:
/// * per-`(group, slot)` chosen safety (`assert_safe`),
/// * per-shard exactly-once, per-client FIFO over each group's chosen
///   stream (the truncation-tolerant announce-stream checker from the
///   state-retention PR, applied per group),
/// * per-key linearizability across shards: every chosen command's key
///   lives in its hash-home group, so all operations on a key serialize
///   through one group's totally ordered log,
/// * replicas of the same group with equal watermarks hold identical
///   state, and
/// * progress: commands keep completing late in the run.
#[test]
fn sharded_exactly_once_fifo_and_per_key_routing_under_reconfig_storm() {
    let shards = 3usize;
    let workloads: [(&str, WorkloadSpec); 2] = [
        ("pipelined-4", WorkloadSpec::pipelined(4)),
        ("open-loop", WorkloadSpec::open_loop(1500.0).max_in_flight(8)),
    ];
    for (wl_name, spec) in &workloads {
        for (proactive, bypass) in [(true, true), (false, false)] {
            let name = format!(
                "sharded {wl_name} exactly-once FIFO (opt1={proactive}, opt2={bypass})"
            );
            property(&name, 3, |seed| {
                let net = NetworkModel {
                    drop_prob: 0.01,
                    jitter: 60 * matchmaker::US,
                    ..NetworkModel::default()
                };
                let mut opts = OptFlags::default();
                opts.proactive_matchmaking = proactive;
                opts.phase1_bypass = bypass;
                // Truncation on: the per-group logs are cut while the
                // storm runs, so only the announce-stream checker works.
                opts.snapshot = SnapshotSpec::every(25 * matchmaker::MS, 128);
                let mut cluster = ShardedCluster::builder()
                    .shards(shards)
                    .clients(4)
                    .workload(spec.clone().keys(256).stop_at(secs(2)))
                    .opts(opts)
                    .seed(seed)
                    .net(net)
                    .build();
                // Counter state machines: the digest is the sum of the
                // executed payloads' key prefixes, so the divergence
                // check below actually bites (the builder's default Noop
                // digests to a constant). Snapshot/restore carries the
                // total, so truncation + catch-up are still exercised.
                for gl in cluster.groups.clone() {
                    for &r in &gl.replicas {
                        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
                            rep.sm = Box::new(Counter::new());
                        }
                    }
                }
                // Concurrent storm: every group reconfigures three
                // times, interleaved across groups.
                for g in 0..shards {
                    let leader = cluster.group_leader(g);
                    for i in 0..3u64 {
                        let cfg = cluster.random_config(g, (g as u64) * 10 + i + 1);
                        let at = msec(200 + (i * shards as u64 + g as u64) * 150);
                        cluster.sim.schedule(at, move |s| {
                            s.with_node::<Leader, _>(leader, |l, now, fx| {
                                l.reconfigure(cfg.clone(), now, fx)
                            });
                        });
                    }
                }
                cluster.sim.run_until(secs(3));
                cluster.assert_safe();
                assert_sharded_streams_safe(&cluster, shards);

                // Same-group replicas with equal watermarks agree.
                for gl in cluster.groups.clone() {
                    let mut states: Vec<(Slot, u64)> = Vec::new();
                    for &r in &gl.replicas {
                        let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
                        states.push((rep.exec_watermark, rep.sm.digest()));
                    }
                    for i in 1..states.len() {
                        if states[0].0 == states[i].0 {
                            assert_eq!(
                                states[0].1, states[i].1,
                                "equal watermarks, different state (seed {seed})"
                            );
                        }
                    }
                }

                // Progress late in the run despite the storm + loss.
                let samples = cluster.samples();
                assert!(
                    samples.iter().any(|(t, _)| *t > msec(1500)),
                    "no progress late in the run (seed {seed})"
                );
            });
        }
    }
}

/// Acceptance gate for the sharding tentpole (X6): at the same total
/// offered load and the same per-message egress cost, 4 groups must
/// aggregate ≥ 2.5x the single group's chosen-commands/sec; the groups
/// that are *not* reconfiguring must stay within 10% of their
/// steady-state rate while group 0 runs a 5-reconfiguration storm; and
/// the shared matchmaker log must stay bounded (per-group GC — a storm
/// on one group cannot grow the set's memory). Lives here with the
/// other slow seeded suites so the release-mode CI job runs it without
/// gating the fast debug loop (tier-1 `cargo test -q` still covers it).
#[test]
fn sharded_scaleout_meets_acceptance() {
    use matchmaker::harness::experiments::run_sharded_scaleout;
    let duration = secs(3);
    let one = run_sharded_scaleout(42, 1, duration);
    let four = run_sharded_scaleout(42, 4, duration);

    // Sanity: the single group is actually saturated (offered well
    // above what it completes) — otherwise the comparison is idle.
    assert!(
        one.offered_per_sec > 1.5 * one.aggregate_per_sec,
        "single group not saturated: offered {:.0}/s vs chosen {:.0}/s",
        one.offered_per_sec,
        one.aggregate_per_sec
    );

    // Scale-out: >= 2.5x aggregate with 4 groups.
    assert!(
        four.aggregate_per_sec >= 2.5 * one.aggregate_per_sec,
        "4 groups gained only {:.2}x ({:.0} vs {:.0} cmds/s)",
        four.aggregate_per_sec / one.aggregate_per_sec,
        four.aggregate_per_sec,
        one.aggregate_per_sec
    );
    // Every group served a meaningful share.
    for g in &four.groups {
        assert!(
            g.chosen_per_sec > 0.1 * four.aggregate_per_sec / 4.0,
            "group {} starved: {:.0} cmds/s",
            g.group,
            g.chosen_per_sec
        );
    }

    // The storm actually ran on group 0 (startup + 5 reconfigs).
    assert!(
        four.group0_reconfigs >= 6,
        "storm too small: {} reconfigs",
        four.group0_reconfigs
    );
    // Non-reconfiguring groups unperturbed within 10%.
    assert!(
        four.min_unperturbed_ratio >= 0.9,
        "a non-reconfiguring group dipped to {:.2} of steady state",
        four.min_unperturbed_ratio
    );

    // Shared matchmaker log bounded: ~1 live entry per group after
    // per-group GC, never the storm's history. (+2 slack for a GC
    // cycle still in flight at the horizon.)
    assert!(
        four.max_mm_log <= four.shards + 2,
        "shared matchmaker log grew to {} entries across {} groups",
        four.max_mm_log,
        four.shards
    );
    assert!(one.max_mm_log <= 3, "single-group mm log: {}", one.max_mm_log);
}

/// Satellite regression: the shared matchmaker's log stays bounded when
/// groups reconfigure at very different rates. A busy group's GC must
/// retire its own retired rounds even while another group never
/// reconfigures — and must never collect the quiet group's one live
/// entry. (Before per-group logs/watermarks, either failure mode was
/// possible: a global watermark would let the quiet group pin the busy
/// group's entries, or GC would nuke the quiet group's state.)
#[test]
fn shared_matchmaker_log_bounded_under_asymmetric_reconfig_rates() {
    property("asymmetric shard GC", 4, |seed| {
        // Alternate which group is the busy one so both directions of
        // the pin/collect hazard are exercised.
        let busy = (seed % 2) as usize;
        let quiet = 1 - busy;
        let mut cluster = ShardedCluster::builder()
            .shards(2)
            .clients(4)
            .workload(WorkloadSpec::pipelined(2))
            .seed(seed)
            .build();
        let leader = cluster.group_leader(busy);
        for i in 0..8u64 {
            let cfg = cluster.random_config(busy, i + 1);
            cluster.sim.schedule(msec(200 + i * 150), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        // Run well past the last reconfiguration so GC settles.
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();
        let busy_leader = cluster.sim.node_mut::<Leader>(leader).unwrap();
        assert!(
            busy_leader.reconfigs_completed >= 9,
            "storm incomplete: {} (seed {seed})",
            busy_leader.reconfigs_completed
        );
        for m in cluster.active_matchmakers() {
            let mm = cluster.sim.node_mut::<Matchmaker>(m).expect("matchmaker");
            let busy_len = mm.group_log_len(busy as GroupId);
            let quiet_len = mm.group_log_len(quiet as GroupId);
            // Busy group: GC retired the storm's rounds (≤ the live
            // round + one not-yet-collected predecessor).
            assert!(
                busy_len <= 2,
                "matchmaker {m}: busy group {busy} retains {busy_len} rounds (seed {seed})"
            );
            // Quiet group: its single startup round survived untouched.
            assert_eq!(
                quiet_len, 1,
                "matchmaker {m}: quiet group {quiet} has {quiet_len} entries (seed {seed})"
            );
            assert!(mm.total_log_len() <= 3);
        }
        // Both groups still serve commands.
        for g in 0..2u32 {
            assert!(
                !cluster.group_chosen_times(g).is_empty(),
                "group {g} starved (seed {seed})"
            );
        }
    });
}

/// Per-group chosen streams: exactly-once per-client FIFO within each
/// shard, plus per-key routing determinism (each key's commands all live
/// in the key's hash-home group). Works on truncated logs — it reads the
/// announce stream, not replica state.
fn assert_sharded_streams_safe(cluster: &ShardedCluster, shards: usize) {
    let mut by_slot: BTreeMap<(GroupId, Slot), &Value> = BTreeMap::new();
    for (_, _, a) in &cluster.sim.announces {
        if let Announce::Chosen { group, slot, value, .. } = a {
            by_slot.entry((*group, *slot)).or_insert(value);
        }
    }
    // Per (group, client): seqs are contiguous 1, 2, 3, ... in slot
    // order (each group lane is its own FIFO stream).
    let mut next: BTreeMap<(GroupId, NodeId), u64> = BTreeMap::new();
    let mut seen: BTreeSet<(GroupId, NodeId, u64)> = BTreeSet::new();
    let mut groups_with_traffic: BTreeSet<GroupId> = BTreeSet::new();
    for ((group, _), value) in &by_slot {
        let mut check = |c: &matchmaker::msg::Command| {
            assert!(
                seen.insert((*group, c.client, c.seq)),
                "command {:?} chosen twice in group {group}",
                c.id()
            );
            let e = next.entry((*group, c.client)).or_insert(1);
            assert_eq!(
                c.seq, *e,
                "client {} out of FIFO order in group {group}",
                c.client
            );
            *e += 1;
            // Per-key routing: the key must hash home to this group.
            let key = key_of_payload(&c.payload).expect("shard payload carries its key");
            assert_eq!(
                shard_of(key, shards),
                *group,
                "key {key} chosen in group {group}, but its home is {}",
                shard_of(key, shards)
            );
            groups_with_traffic.insert(*group);
        };
        match value {
            Value::Cmd(c) => check(c),
            Value::Batch(cmds) => cmds.iter().for_each(check),
            Value::Noop | Value::Reconfig(_) => {}
        }
    }
    assert!(
        groups_with_traffic.len() == shards,
        "only {:?} of {shards} groups saw traffic",
        groups_with_traffic
    );
}

/// Flatten the globally chosen stream (from the simulator's `Chosen`
/// announcements, deduplicated by slot — `assert_safe` already proved
/// per-slot uniqueness) and check exactly-once per-client FIFO. Unlike
/// [`assert_batched_exactly_once_fifo`] this does not read replica logs,
/// so it works when truncation has already dropped the prefix.
fn assert_chosen_stream_exactly_once_fifo(cluster: &Cluster) {
    let mut by_slot: BTreeMap<Slot, &Value> = BTreeMap::new();
    for (_, _, a) in &cluster.sim.announces {
        if let Announce::Chosen { slot, value, .. } = a {
            by_slot.entry(*slot).or_insert(value);
        }
    }
    let mut seen: BTreeSet<(NodeId, u64)> = BTreeSet::new();
    let mut next: BTreeMap<NodeId, u64> = BTreeMap::new();
    let mut check = |c: &matchmaker::msg::Command| {
        assert!(seen.insert((c.client, c.seq)), "command {:?} chosen twice", c.id());
        let e = next.entry(c.client).or_insert(1);
        assert_eq!(c.seq, *e, "client {} chosen out of FIFO order", c.client);
        *e += 1;
    };
    for value in by_slot.values() {
        match value {
            Value::Cmd(c) => check(c),
            Value::Batch(cmds) => cmds.iter().for_each(&mut check),
            Value::Noop | Value::Reconfig(_) => {}
        }
    }
}

/// Like [`assert_chosen_stream_exactly_once_fifo`], but for runs where
/// clients legitimately abandon seqs (Busy shedding, queue overflow):
/// gaps are allowed, yet each client's chosen seqs must still be
/// strictly increasing in slot order — which also implies exactly-once.
/// A shed-then-reissued request must never land after a later command
/// from the same client.
fn assert_chosen_stream_exactly_once_monotone(cluster: &Cluster) {
    let mut by_slot: BTreeMap<Slot, &Value> = BTreeMap::new();
    for (_, _, a) in &cluster.sim.announces {
        if let Announce::Chosen { slot, value, .. } = a {
            by_slot.entry(*slot).or_insert(value);
        }
    }
    let mut last: BTreeMap<NodeId, u64> = BTreeMap::new();
    let mut check = |c: &matchmaker::msg::Command| {
        let e = last.entry(c.client).or_insert(0);
        assert!(
            c.seq > *e,
            "client {} seq {} chosen at or after seq {} (reorder or duplicate)",
            c.client,
            c.seq,
            *e
        );
        *e = c.seq;
    };
    for value in by_slot.values() {
        match value {
            Value::Cmd(c) => check(c),
            Value::Batch(cmds) => cmds.iter().for_each(&mut check),
            Value::Noop | Value::Reconfig(_) => {}
        }
    }
}

/// Walk each replica's executed log in slot order, flattening batches:
/// no (client, seq) may appear twice, each client's commands must appear
/// in contiguous FIFO order (1, 2, 3, ...), and the replica's execution
/// counter must equal the number of distinct commands.
fn assert_batched_exactly_once_fifo(cluster: &mut Cluster) {
    for &r in &cluster.layout.replicas.clone() {
        let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
        let mut flat: Vec<(NodeId, u64)> = Vec::new();
        for slot in 0..rep.exec_watermark {
            match rep.log.get(&slot) {
                Some(Value::Cmd(c)) => flat.push((c.client, c.seq)),
                Some(Value::Batch(cmds)) => {
                    assert!(cmds.len() >= 2, "degenerate batch in slot {slot}");
                    flat.extend(cmds.iter().map(|c| (c.client, c.seq)));
                }
                _ => {}
            }
        }
        let mut seen: BTreeSet<(NodeId, u64)> = BTreeSet::new();
        for p in &flat {
            assert!(seen.insert(*p), "command {p:?} decided twice on replica {r}");
        }
        let mut next: BTreeMap<NodeId, u64> = BTreeMap::new();
        for (client, seq) in flat {
            let e = next.entry(client).or_insert(1);
            assert_eq!(
                seq, *e,
                "client {client} executed out of FIFO order on replica {r}"
            );
            *e += 1;
        }
        assert_eq!(
            rep.executed as usize,
            seen.len(),
            "replica {r} executed a command more or less than once"
        );
    }
}

/// Replica logs agree on every slot both have executed (prefix
/// consistency), and state digests match across equal prefixes.
fn assert_replicas_prefix_consistent(cluster: &mut Cluster) {
    let replicas = cluster.layout.replicas.clone();
    let mut logs = Vec::new();
    for &r in &replicas {
        let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
        logs.push((rep.exec_watermark, rep.log.clone(), rep.sm.digest()));
    }
    for i in 1..logs.len() {
        let common = logs[0].0.min(logs[i].0);
        for s in 0..common {
            assert_eq!(
                logs[0].1.get(&s),
                logs[i].1.get(&s),
                "replica logs diverge at slot {s}"
            );
        }
        if logs[0].0 == logs[i].0 {
            assert_eq!(logs[0].2, logs[i].2, "equal prefixes, different digests");
        }
    }
}

// =========================================================================
// Quorum-system properties
// =========================================================================

/// Randomized quorum systems: `intersects()` agrees with brute force, and
/// any acked set accepted as P1/P2 actually contains a quorum.
#[test]
fn quorum_intersection_matches_bruteforce() {
    property("quorum intersection", 200, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.gen_range(6) as usize;
        let acceptors: Vec<NodeId> = (0..n as NodeId).collect();
        let spec = random_spec(&mut rng, n);
        // Brute force: enumerate all subsets, find minimal P1/P2 quorums.
        let subsets: Vec<BTreeSet<NodeId>> = (0u32..(1 << n))
            .map(|mask| {
                (0..n)
                    .filter(|&i| mask & (1 << i) != 0)
                    .map(|i| acceptors[i])
                    .collect()
            })
            .collect();
        let p1s: Vec<&BTreeSet<NodeId>> =
            subsets.iter().filter(|s| spec.is_p1_quorum(&acceptors, s)).collect();
        let p2s: Vec<&BTreeSet<NodeId>> =
            subsets.iter().filter(|s| spec.is_p2_quorum(&acceptors, s)).collect();
        let brute = !p1s.is_empty()
            && !p2s.is_empty()
            && p1s.iter().all(|a| p2s.iter().all(|b| a.intersection(b).next().is_some()));
        assert_eq!(
            spec.intersects(n),
            brute,
            "spec {spec:?} over {n}: intersects() disagrees with brute force"
        );
    });
}

/// Thrifty sampling always returns a P2 quorum, for every spec kind.
#[test]
fn thrifty_sample_always_p2() {
    property("thrifty sample", 200, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.gen_range(6) as usize;
        let acceptors: Vec<NodeId> = (0..n as NodeId).collect();
        let spec = random_spec(&mut rng, n);
        if !spec.intersects(n) {
            return;
        }
        let picked: BTreeSet<NodeId> =
            spec.sample_p2(&acceptors, &mut rng).into_iter().collect();
        assert!(
            spec.is_p2_quorum(&acceptors, &picked),
            "sample {picked:?} not a P2 quorum of {spec:?}"
        );
    });
}

fn random_spec(rng: &mut Rng, n: usize) -> QuorumSpec {
    match rng.gen_range(4) {
        0 => QuorumSpec::Majority,
        1 => QuorumSpec::Flexible {
            p1: 1 + rng.gen_range(n as u64) as usize,
            p2: 1 + rng.gen_range(n as u64) as usize,
        },
        2 => QuorumSpec::FastUnanimous,
        _ => {
            let mut mk = |rng: &mut Rng| -> Vec<BTreeSet<usize>> {
                (0..1 + rng.gen_range(3))
                    .map(|_| {
                        (0..n).filter(|_| rng.chance(0.5)).collect::<BTreeSet<usize>>()
                    })
                    .filter(|s| !s.is_empty())
                    .collect()
            };
            QuorumSpec::Explicit { p1: mk(rng), p2: mk(rng) }
        }
    }
}

// =========================================================================
// Codec properties
// =========================================================================

/// Randomized mutation fuzz: flipping bytes of valid encodings must never
/// panic, and exact encodings always roundtrip.
#[test]
fn codec_mutation_fuzz() {
    property("codec fuzz", 50, |seed| {
        let mut rng = Rng::new(seed);
        for msg in sample_messages() {
            let bytes = Envelope { from: 1, to: 2, msg: msg.clone() }.encode();
            let back = Envelope::decode(&bytes).unwrap();
            assert_eq!(back.msg, msg);
            // Mutate a few bytes: decode must not panic (Err is fine).
            let mut mutated = bytes.clone();
            for _ in 0..4 {
                let idx = rng.gen_range(mutated.len() as u64) as usize;
                mutated[idx] ^= (1 + rng.gen_range(255)) as u8;
            }
            let _ = Envelope::decode(&mutated);
        }
    });
}

/// Encodings are canonical: encode(decode(encode(x))) == encode(x).
#[test]
fn codec_canonical() {
    for msg in sample_messages() {
        let bytes = msg.encode();
        let back = Msg::decode(&bytes).unwrap();
        assert_eq!(back.encode(), bytes);
    }
}

// =========================================================================
// Matchmaker log invariants
// =========================================================================

/// Random MatchA/GarbageA interleavings: once a matchmaker answers round
/// i, it never again answers any round ≤ i with a different configuration;
/// the GC watermark is monotone; H_i never contains a GC'd round.
#[test]
fn matchmaker_log_invariants() {
    use matchmaker::node::{Effects, Node};
    use matchmaker::roles::Matchmaker;
    use matchmaker::round::Round;

    property("matchmaker log", 100, |seed| {
        let mut rng = Rng::new(seed);
        let mut mm = Matchmaker::new(0);
        let mut highest_answered: Option<Round> = None;
        let mut watermark: Option<Round> = None;
        // The invariants are per group; exercise a non-zero one, with a
        // decoy group whose traffic must not interfere.
        let group: GroupId = 2;
        for step in 0..60 {
            let round = Round { epoch: rng.gen_range(6), proposer: 0, seq: rng.gen_range(6) };
            let mut fx = Effects::new();
            if rng.chance(0.1) {
                // Decoy traffic on another group: must not move group
                // 2's watermark or log.
                let mut dfx = Effects::new();
                let cfg = Configuration::majority(rng.next_u64(), vec![1, 2, 3]);
                mm.on_msg(step, 9, Msg::MatchA { group: 7, round, config: cfg }, &mut dfx);
                mm.on_msg(step, 9, Msg::GarbageA { group: 7, round }, &mut dfx);
            }
            if rng.chance(0.2) {
                mm.on_msg(step, 9, Msg::GarbageA { group, round }, &mut fx);
                if watermark.map_or(true, |w| round > w) {
                    watermark = Some(round);
                }
                continue;
            }
            let cfg = Configuration::majority(rng.next_u64(), vec![1, 2, 3]);
            mm.on_msg(step, 9, Msg::MatchA { group, round, config: cfg }, &mut fx);
            for (_, reply) in fx.msgs {
                match reply {
                    Msg::MatchB { group: g, round: r, gc_watermark, prior } => {
                        assert_eq!(g, group);
                        // Refusal discipline: must be a fresh high round
                        // (or an identical resend, which our generator
                        // never produces since config ids are random).
                        assert!(
                            highest_answered.map_or(true, |h| r > h),
                            "answered non-increasing round {r:?} after {highest_answered:?}"
                        );
                        highest_answered = Some(r);
                        assert_eq!(gc_watermark, watermark, "watermark mismatch");
                        if let Some(w) = watermark {
                            assert!(
                                prior.keys().all(|pr| *pr >= w),
                                "H_i contains a GC'd round"
                            );
                        }
                    }
                    Msg::MatchNack { .. } => {}
                    other => panic!("unexpected reply {other:?}"),
                }
            }
        }
    });
}

/// Determinism: identical seeds produce byte-identical experiment results.
#[test]
fn simulation_is_deterministic() {
    let run = |seed: u64| {
        let mut cluster = Cluster::builder().seed(seed).build();
        let leader = cluster.initial_leader();
        let cfg = cluster.random_config(1);
        cluster.sim.schedule(msec(300), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        cluster.sim.run_until(secs(1));
        let samples = cluster.samples();
        (samples.len(), samples.last().copied(), cluster.sim.delivered)
    };
    assert_eq!(run(11), run(11));
    assert_eq!(run(12), run(12));
    assert_ne!(run(11).2, run(13).2); // different seeds actually differ
}
