//! Property-based safety tests.
//!
//! The §3/§5/§6 proofs become executable invariants here. A small
//! in-tree property-test driver (seeded exploration over the deterministic
//! simulator; every failure reports its seed, so shrinking = re-running
//! with that seed) replaces an external proptest dependency — the build is
//! fully offline.

use matchmaker::codec::{sample_messages, Wire};
use matchmaker::config::{Configuration, OptFlags, SnapshotSpec};
use matchmaker::harness::{msec, secs, Cluster};
use matchmaker::msg::{Envelope, Msg, Value};
use matchmaker::node::Announce;
use matchmaker::quorum::QuorumSpec;
use matchmaker::roles::{Leader, Replica};
use matchmaker::sim::NetworkModel;
use matchmaker::statemachine::KvStore;
use matchmaker::util::Rng;
use matchmaker::workload::WorkloadSpec;
use matchmaker::{NodeId, Slot};
use std::collections::{BTreeMap, BTreeSet};

/// Run `f` for `cases` seeds; panics carry the seed for reproduction.
fn property(name: &str, cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

// =========================================================================
// Chosen-safety under adversarial conditions
// =========================================================================

/// Reconfiguration storm + lossy network: at most one value is ever chosen
/// per slot, and replicas never diverge.
#[test]
fn safety_under_reconfig_storm_and_loss() {
    property("reconfig storm + loss", 8, |seed| {
        let net = NetworkModel {
            drop_prob: 0.05,
            jitter: 80 * matchmaker::US,
            ..NetworkModel::default()
        };
        let mut cluster = Cluster::builder().clients(3).seed(seed).net(net).build();
        let leader = cluster.initial_leader();
        // 20 reconfigurations, one every 50 ms.
        for i in 0..20u64 {
            let cfg = cluster.random_config(i + 1);
            cluster.sim.schedule(msec(100 + i * 50), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        assert_replicas_prefix_consistent(&mut cluster);
    });
}

/// Crashing up to f acceptors of the active configuration never violates
/// safety (liveness may suffer until a reconfiguration, which we perform).
#[test]
fn safety_under_acceptor_crashes() {
    property("acceptor crashes", 8, |seed| {
        let mut cluster = Cluster::builder().clients(3).seed(seed).build();
        let leader = cluster.initial_leader();
        let mut rng = Rng::new(seed ^ 0xdead);
        // Crash one initial acceptor early, reconfigure away later.
        let victim = cluster.layout.initial_config().acceptors
            [rng.gen_range(3) as usize];
        cluster.sim.schedule(msec(200), move |s| s.crash(victim));
        let healthy: Vec<NodeId> = cluster
            .layout
            .acceptor_pool
            .iter()
            .copied()
            .filter(|&a| a != victim)
            .take(3)
            .collect();
        let cfg = Configuration::majority(9, healthy);
        cluster.sim.schedule(msec(600), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        // The system must have made progress after the repair.
        let samples = cluster.samples();
        assert!(
            samples.iter().any(|(t, _)| *t > msec(1200)),
            "no progress after reconfiguration away from crashed acceptor"
        );
    });
}

/// Dueling leaders: repeatedly force the follower to usurp leadership
/// while the old leader is still alive. Nacks + matchmaker refusals must
/// keep the system safe.
#[test]
fn safety_under_dueling_leaders() {
    property("dueling leaders", 8, |seed| {
        let mut cluster = Cluster::builder().clients(3).seed(seed).build();
        let p1 = cluster.layout.proposers[1];
        for i in 0..5u64 {
            cluster.sim.schedule(msec(150 + i * 150), move |s| {
                s.with_node::<Leader, _>(p1, |l, now, fx| l.become_leader(now, fx));
            });
        }
        cluster.sim.run_until(secs(2));
        cluster.assert_safe();
        assert_replicas_prefix_consistent(&mut cluster);
    });
}

/// Leader crash + election under message loss.
#[test]
fn safety_under_leader_failover_with_loss() {
    property("leader failover + loss", 6, |seed| {
        let net = NetworkModel { drop_prob: 0.02, ..NetworkModel::default() };
        let mut cluster = Cluster::builder().clients(3).seed(seed).net(net).build();
        let p0 = cluster.layout.proposers[0];
        let p1 = cluster.layout.proposers[1];
        if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
            l.timing.election_timeout = msec(300);
        }
        cluster.sim.schedule(msec(500), move |s| s.crash(p0));
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();
        let samples = cluster.samples();
        assert!(
            samples.iter().any(|(t, _)| *t > secs(2)),
            "no progress after failover (seed {seed})"
        );
    });
}

/// Matchmaker reconfiguration storms compose with acceptor
/// reconfigurations without violating safety.
#[test]
fn safety_under_matchmaker_reconfig_storm() {
    property("mm reconfig storm", 6, |seed| {
        let mut cluster = Cluster::builder().clients(2).seed(seed).build();
        let leader = cluster.initial_leader();
        for i in 0..6u64 {
            let mms = cluster.random_matchmakers();
            cluster.sim.schedule(msec(200 + i * 200), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure_matchmakers(mms.clone(), now, fx)
                });
            });
            let cfg = cluster.random_config(i + 1);
            cluster.sim.schedule(msec(300 + i * 200), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();
        assert_replicas_prefix_consistent(&mut cluster);
    });
}

/// Phase 2 batching tentpole property: under a reconfiguration storm,
/// every batched command is decided exactly once and executed in
/// per-client FIFO order with no gaps — with and without Optimizations
/// 1/2 (proactive matchmaking, Phase 1 bypassing), i.e. both when
/// batches keep flowing to `C_old` during matchmaking and when they
/// stall and drain through the full Phase 1 path.
#[test]
fn batching_exactly_once_fifo_across_reconfig() {
    for (proactive, bypass) in [(true, true), (false, false)] {
        let name = format!("batching exactly-once (opt1={proactive}, opt2={bypass})");
        property(&name, 5, |seed| {
            let mut opts = OptFlags::default().with_batching(8, 500 * matchmaker::US);
            opts.proactive_matchmaking = proactive;
            opts.phase1_bypass = bypass;
            let mut cluster = Cluster::builder().clients(6).opts(opts).seed(seed).build();
            let leader = cluster.initial_leader();
            // Four reconfigurations while commands stream.
            for i in 0..4u64 {
                let cfg = cluster.random_config(i + 1);
                cluster.sim.schedule(msec(250 + i * 250), move |s| {
                    s.with_node::<Leader, _>(leader, |l, now, fx| {
                        l.reconfigure(cfg.clone(), now, fx)
                    });
                });
            }
            cluster.sim.run_until(secs(2));
            cluster.assert_safe();
            assert_batched_exactly_once_fifo(&mut cluster);
            assert_replicas_prefix_consistent(&mut cluster);
            // Commands flowed throughout (no permanent stall).
            let samples = cluster.samples();
            assert!(
                samples.iter().any(|(t, _)| *t > msec(1500)),
                "no progress late in the run (seed {seed})"
            );
        });
    }
}

/// Workload-API tentpole property: open-loop and pipelined clients (a
/// pipeline window > 1, so the network can reorder a client's in-flight
/// requests) under a reconfiguration storm still yield exactly-once,
/// per-client-FIFO execution — across Optimizations 1/2 on and off,
/// i.e. both when commands keep flowing to `C_old` during matchmaking
/// and when they stall and drain through the full Phase 1 path.
#[test]
fn pipelined_and_open_loop_exactly_once_fifo_across_reconfig() {
    let workloads: [(&str, WorkloadSpec); 3] = [
        ("pipelined-4", WorkloadSpec::pipelined(4)),
        ("open-loop", WorkloadSpec::open_loop(2000.0).max_in_flight(8)),
        ("open-loop-poisson", WorkloadSpec::open_loop_poisson(1500.0).max_in_flight(8)),
    ];
    for (wl_name, spec) in &workloads {
        for (proactive, bypass) in [(true, true), (false, false)] {
            let name =
                format!("{wl_name} exactly-once FIFO (opt1={proactive}, opt2={bypass})");
            property(&name, 3, |seed| {
                let mut opts = OptFlags::default();
                opts.proactive_matchmaking = proactive;
                opts.phase1_bypass = bypass;
                let mut cluster = Cluster::builder()
                    .clients(4)
                    .workload(spec.clone())
                    .opts(opts)
                    .seed(seed)
                    .build();
                let leader = cluster.initial_leader();
                // Four reconfigurations while requests are pipelined.
                for i in 0..4u64 {
                    let cfg = cluster.random_config(i + 1);
                    cluster.sim.schedule(msec(250 + i * 250), move |s| {
                        s.with_node::<Leader, _>(leader, |l, now, fx| {
                            l.reconfigure(cfg.clone(), now, fx)
                        });
                    });
                }
                cluster.sim.run_until(secs(2));
                cluster.assert_safe();
                assert_batched_exactly_once_fifo(&mut cluster);
                assert_replicas_prefix_consistent(&mut cluster);
                // Commands flowed throughout (no permanent stall).
                let samples = cluster.samples();
                assert!(
                    samples.iter().any(|(t, _)| *t > msec(1500)),
                    "no progress late in the run (seed {seed})"
                );
            });
        }
    }
}

/// State-retention tentpole property: snapshots + log truncation +
/// snapshot catch-up never lose or reorder a chosen command. A
/// reconfiguration storm runs with snapshots enabled on a lossy network;
/// one replica crashes mid-run and a fresh machine rejoins under its id
/// (its prefix is truncated cluster-wide, forcing the snapshot-transfer
/// path). The global chosen stream must stay exactly-once per-client
/// FIFO, and replicas with equal watermarks must hold identical state —
/// including the rejoined one.
#[test]
fn truncation_and_catchup_exactly_once_fifo() {
    // Per-client kv writes: the value depends on the client, so replica
    // digests reflect which commands actually executed.
    fn kv_payload(id: NodeId) -> Vec<u8> {
        KvStore::enc_set(&id.to_le_bytes(), &(id as u64).to_le_bytes())
    }
    property("snapshot truncation + rejoin", 5, |seed| {
        let net = NetworkModel {
            drop_prob: 0.01,
            jitter: 60 * matchmaker::US,
            ..NetworkModel::default()
        };
        let mut opts = OptFlags::default();
        // A deliberately tiny interval/tail so truncation happens many
        // times within the run.
        opts.snapshot = SnapshotSpec::every(20 * matchmaker::MS, 128);
        let mut cluster = Cluster::builder()
            .clients(4)
            .workload(
                WorkloadSpec::pipelined(4)
                    .payload_with(kv_payload)
                    .stop_at(secs(2)),
            )
            .opts(opts)
            .seed(seed)
            .net(net)
            .build();
        for &r in &cluster.layout.replicas.clone() {
            if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
                rep.sm = Box::new(KvStore::new());
            }
        }
        let leader = cluster.initial_leader();
        for i in 0..4u64 {
            let cfg = cluster.random_config(i + 1);
            cluster.sim.schedule(msec(300 + i * 300), move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        // Crash replica 2 mid-storm; a fresh machine rejoins 400 ms later.
        let victim = cluster.layout.replicas[2];
        let peers = cluster.layout.replicas.clone();
        let spec = opts.snapshot;
        cluster.sim.schedule(msec(600), move |s| s.crash(victim));
        cluster.sim.schedule(msec(1000), move |s| {
            let mut rep = Replica::new(victim, Box::new(KvStore::new()));
            rep.snapshot = spec;
            rep.peers = peers;
            s.replace_node(victim, Box::new(rep));
        });
        cluster.sim.run_until(secs(3));
        cluster.assert_safe();

        // The global chosen stream (slot order) is exactly-once and
        // per-client FIFO — truncation must not have dropped or
        // reordered anything that was decided.
        assert_chosen_stream_exactly_once_fifo(&cluster);

        // Replicas with equal executed prefixes hold identical state;
        // the rejoined replica went through snapshot transfer.
        let replicas = cluster.layout.replicas.clone();
        let mut states: Vec<(NodeId, Slot, u64, u64)> = Vec::new();
        for &r in &replicas {
            let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
            states.push((r, rep.exec_watermark, rep.sm.digest(), rep.snapshots_installed));
        }
        for i in 1..states.len() {
            if states[0].1 == states[i].1 {
                assert_eq!(
                    states[0].2, states[i].2,
                    "equal watermarks, different state: {:?} vs {:?} (seed {seed})",
                    states[0], states[i]
                );
            }
        }
        let rejoined = states.iter().find(|(r, ..)| *r == victim).unwrap();
        assert!(
            rejoined.3 >= 1,
            "rejoined replica never installed a snapshot (seed {seed}): {rejoined:?}"
        );
        assert!(rejoined.1 > 0, "rejoined replica made no progress (seed {seed})");
    });
}

/// Flatten the globally chosen stream (from the simulator's `Chosen`
/// announcements, deduplicated by slot — `assert_safe` already proved
/// per-slot uniqueness) and check exactly-once per-client FIFO. Unlike
/// [`assert_batched_exactly_once_fifo`] this does not read replica logs,
/// so it works when truncation has already dropped the prefix.
fn assert_chosen_stream_exactly_once_fifo(cluster: &Cluster) {
    let mut by_slot: BTreeMap<Slot, &Value> = BTreeMap::new();
    for (_, _, a) in &cluster.sim.announces {
        if let Announce::Chosen { slot, value, .. } = a {
            by_slot.entry(*slot).or_insert(value);
        }
    }
    let mut seen: BTreeSet<(NodeId, u64)> = BTreeSet::new();
    let mut next: BTreeMap<NodeId, u64> = BTreeMap::new();
    let mut check = |c: &matchmaker::msg::Command| {
        assert!(seen.insert((c.client, c.seq)), "command {:?} chosen twice", c.id());
        let e = next.entry(c.client).or_insert(1);
        assert_eq!(c.seq, *e, "client {} chosen out of FIFO order", c.client);
        *e += 1;
    };
    for value in by_slot.values() {
        match value {
            Value::Cmd(c) => check(c),
            Value::Batch(cmds) => cmds.iter().for_each(&mut check),
            Value::Noop | Value::Reconfig(_) => {}
        }
    }
}

/// Walk each replica's executed log in slot order, flattening batches:
/// no (client, seq) may appear twice, each client's commands must appear
/// in contiguous FIFO order (1, 2, 3, ...), and the replica's execution
/// counter must equal the number of distinct commands.
fn assert_batched_exactly_once_fifo(cluster: &mut Cluster) {
    for &r in &cluster.layout.replicas.clone() {
        let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
        let mut flat: Vec<(NodeId, u64)> = Vec::new();
        for slot in 0..rep.exec_watermark {
            match rep.log.get(&slot) {
                Some(Value::Cmd(c)) => flat.push((c.client, c.seq)),
                Some(Value::Batch(cmds)) => {
                    assert!(cmds.len() >= 2, "degenerate batch in slot {slot}");
                    flat.extend(cmds.iter().map(|c| (c.client, c.seq)));
                }
                _ => {}
            }
        }
        let mut seen: BTreeSet<(NodeId, u64)> = BTreeSet::new();
        for p in &flat {
            assert!(seen.insert(*p), "command {p:?} decided twice on replica {r}");
        }
        let mut next: BTreeMap<NodeId, u64> = BTreeMap::new();
        for (client, seq) in flat {
            let e = next.entry(client).or_insert(1);
            assert_eq!(
                seq, *e,
                "client {client} executed out of FIFO order on replica {r}"
            );
            *e += 1;
        }
        assert_eq!(
            rep.executed as usize,
            seen.len(),
            "replica {r} executed a command more or less than once"
        );
    }
}

/// Replica logs agree on every slot both have executed (prefix
/// consistency), and state digests match across equal prefixes.
fn assert_replicas_prefix_consistent(cluster: &mut Cluster) {
    let replicas = cluster.layout.replicas.clone();
    let mut logs = Vec::new();
    for &r in &replicas {
        let rep = cluster.sim.node_mut::<Replica>(r).expect("replica");
        logs.push((rep.exec_watermark, rep.log.clone(), rep.sm.digest()));
    }
    for i in 1..logs.len() {
        let common = logs[0].0.min(logs[i].0);
        for s in 0..common {
            assert_eq!(
                logs[0].1.get(&s),
                logs[i].1.get(&s),
                "replica logs diverge at slot {s}"
            );
        }
        if logs[0].0 == logs[i].0 {
            assert_eq!(logs[0].2, logs[i].2, "equal prefixes, different digests");
        }
    }
}

// =========================================================================
// Quorum-system properties
// =========================================================================

/// Randomized quorum systems: `intersects()` agrees with brute force, and
/// any acked set accepted as P1/P2 actually contains a quorum.
#[test]
fn quorum_intersection_matches_bruteforce() {
    property("quorum intersection", 200, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.gen_range(6) as usize;
        let acceptors: Vec<NodeId> = (0..n as NodeId).collect();
        let spec = random_spec(&mut rng, n);
        // Brute force: enumerate all subsets, find minimal P1/P2 quorums.
        let subsets: Vec<BTreeSet<NodeId>> = (0u32..(1 << n))
            .map(|mask| {
                (0..n)
                    .filter(|&i| mask & (1 << i) != 0)
                    .map(|i| acceptors[i])
                    .collect()
            })
            .collect();
        let p1s: Vec<&BTreeSet<NodeId>> =
            subsets.iter().filter(|s| spec.is_p1_quorum(&acceptors, s)).collect();
        let p2s: Vec<&BTreeSet<NodeId>> =
            subsets.iter().filter(|s| spec.is_p2_quorum(&acceptors, s)).collect();
        let brute = !p1s.is_empty()
            && !p2s.is_empty()
            && p1s.iter().all(|a| p2s.iter().all(|b| a.intersection(b).next().is_some()));
        assert_eq!(
            spec.intersects(n),
            brute,
            "spec {spec:?} over {n}: intersects() disagrees with brute force"
        );
    });
}

/// Thrifty sampling always returns a P2 quorum, for every spec kind.
#[test]
fn thrifty_sample_always_p2() {
    property("thrifty sample", 200, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.gen_range(6) as usize;
        let acceptors: Vec<NodeId> = (0..n as NodeId).collect();
        let spec = random_spec(&mut rng, n);
        if !spec.intersects(n) {
            return;
        }
        let picked: BTreeSet<NodeId> =
            spec.sample_p2(&acceptors, &mut rng).into_iter().collect();
        assert!(
            spec.is_p2_quorum(&acceptors, &picked),
            "sample {picked:?} not a P2 quorum of {spec:?}"
        );
    });
}

fn random_spec(rng: &mut Rng, n: usize) -> QuorumSpec {
    match rng.gen_range(4) {
        0 => QuorumSpec::Majority,
        1 => QuorumSpec::Flexible {
            p1: 1 + rng.gen_range(n as u64) as usize,
            p2: 1 + rng.gen_range(n as u64) as usize,
        },
        2 => QuorumSpec::FastUnanimous,
        _ => {
            let mut mk = |rng: &mut Rng| -> Vec<BTreeSet<usize>> {
                (0..1 + rng.gen_range(3))
                    .map(|_| {
                        (0..n).filter(|_| rng.chance(0.5)).collect::<BTreeSet<usize>>()
                    })
                    .filter(|s| !s.is_empty())
                    .collect()
            };
            QuorumSpec::Explicit { p1: mk(rng), p2: mk(rng) }
        }
    }
}

// =========================================================================
// Codec properties
// =========================================================================

/// Randomized mutation fuzz: flipping bytes of valid encodings must never
/// panic, and exact encodings always roundtrip.
#[test]
fn codec_mutation_fuzz() {
    property("codec fuzz", 50, |seed| {
        let mut rng = Rng::new(seed);
        for msg in sample_messages() {
            let bytes = Envelope { from: 1, to: 2, msg: msg.clone() }.encode();
            let back = Envelope::decode(&bytes).unwrap();
            assert_eq!(back.msg, msg);
            // Mutate a few bytes: decode must not panic (Err is fine).
            let mut mutated = bytes.clone();
            for _ in 0..4 {
                let idx = rng.gen_range(mutated.len() as u64) as usize;
                mutated[idx] ^= (1 + rng.gen_range(255)) as u8;
            }
            let _ = Envelope::decode(&mutated);
        }
    });
}

/// Encodings are canonical: encode(decode(encode(x))) == encode(x).
#[test]
fn codec_canonical() {
    for msg in sample_messages() {
        let bytes = msg.encode();
        let back = Msg::decode(&bytes).unwrap();
        assert_eq!(back.encode(), bytes);
    }
}

// =========================================================================
// Matchmaker log invariants
// =========================================================================

/// Random MatchA/GarbageA interleavings: once a matchmaker answers round
/// i, it never again answers any round ≤ i with a different configuration;
/// the GC watermark is monotone; H_i never contains a GC'd round.
#[test]
fn matchmaker_log_invariants() {
    use matchmaker::node::{Effects, Node};
    use matchmaker::roles::Matchmaker;
    use matchmaker::round::Round;

    property("matchmaker log", 100, |seed| {
        let mut rng = Rng::new(seed);
        let mut mm = Matchmaker::new(0);
        let mut highest_answered: Option<Round> = None;
        let mut watermark: Option<Round> = None;
        for step in 0..60 {
            let round = Round { epoch: rng.gen_range(6), proposer: 0, seq: rng.gen_range(6) };
            let mut fx = Effects::new();
            if rng.chance(0.2) {
                mm.on_msg(step, 9, Msg::GarbageA { round }, &mut fx);
                if watermark.map_or(true, |w| round > w) {
                    watermark = Some(round);
                }
                continue;
            }
            let cfg = Configuration::majority(rng.next_u64(), vec![1, 2, 3]);
            mm.on_msg(step, 9, Msg::MatchA { round, config: cfg }, &mut fx);
            for (_, reply) in fx.msgs {
                match reply {
                    Msg::MatchB { round: r, gc_watermark, prior } => {
                        // Refusal discipline: must be a fresh high round
                        // (or an identical resend, which our generator
                        // never produces since config ids are random).
                        assert!(
                            highest_answered.map_or(true, |h| r > h),
                            "answered non-increasing round {r:?} after {highest_answered:?}"
                        );
                        highest_answered = Some(r);
                        assert_eq!(gc_watermark, watermark, "watermark mismatch");
                        if let Some(w) = watermark {
                            assert!(
                                prior.keys().all(|pr| *pr >= w),
                                "H_i contains a GC'd round"
                            );
                        }
                    }
                    Msg::MatchNack { .. } => {}
                    other => panic!("unexpected reply {other:?}"),
                }
            }
        }
    });
}

/// Determinism: identical seeds produce byte-identical experiment results.
#[test]
fn simulation_is_deterministic() {
    let run = |seed: u64| {
        let mut cluster = Cluster::builder().seed(seed).build();
        let leader = cluster.initial_leader();
        let cfg = cluster.random_config(1);
        cluster.sim.schedule(msec(300), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        cluster.sim.run_until(secs(1));
        let samples = cluster.samples();
        (samples.len(), samples.last().copied(), cluster.sim.delivered)
    };
    assert_eq!(run(11), run(11));
    assert_eq!(run(12), run(12));
    assert_ne!(run(11).2, run(13).2); // different seeds actually differ
}
