//! Checker-checks: the model checker's own regression suite.
//!
//! A checker that never fires is indistinguishable from one that works,
//! so this suite proves the negative space (DESIGN.md §Model checking):
//! every invariant in the catalog demonstrably fires on a known-bad
//! history, the explorer actually finds a seeded protocol bug and
//! shrinks it to a locally minimal schedule, and the checked-in
//! regression trace keeps reproducing its violation deterministically.

use matchmaker::check::{
    explore, instances, replay, trace, InvariantSet, Replayed,
};
use matchmaker::config::Configuration;
use matchmaker::msg::{Command, MmLog, Value};
use matchmaker::node::Announce;
use matchmaker::quorum::QuorumSpec;
use matchmaker::round::Round;
use matchmaker::{NodeId, Time};
use std::collections::BTreeMap;

fn r(epoch: u64) -> Round {
    Round { epoch, proposer: 0, seq: 0 }
}

fn chosen(slot: u64, client: NodeId, seq: u64, payload: &[u8]) -> (Time, NodeId, Announce) {
    (
        1,
        6,
        Announce::Chosen {
            group: 0,
            slot,
            round: r(1),
            value: Value::Cmd(Command { client, seq, payload: payload.to_vec() }),
        },
    )
}

/// Every invariant in the standard catalog fires on a crafted known-bad
/// announcement stream — no invariant is dead weight, and each violation
/// is attributed to the right name.
#[test]
fn every_invariant_in_the_catalog_fires() {
    let nonintersecting = Configuration {
        id: 9,
        acceptors: vec![0, 1, 2],
        quorum: QuorumSpec::Explicit {
            p1: vec![[0, 1].into_iter().collect()],
            p2: vec![[2].into_iter().collect()],
        },
    };
    let mut dropped_log: MmLog = BTreeMap::new();
    dropped_log
        .entry(0)
        .or_default()
        .insert(r(1), Configuration::majority(1, vec![0, 1, 2]));
    let bad_histories: Vec<(&str, Vec<(Time, NodeId, Announce)>)> = vec![
        (
            "chosen-unique",
            vec![chosen(0, 90, 1, b"a"), chosen(0, 91, 1, b"b")],
        ),
        (
            "quorum-intersection",
            vec![(
                1,
                6,
                Announce::QuorumConfig { group: 0, round: r(1), config: nonintersecting },
            )],
        ),
        (
            "matchmaker-monotonic",
            vec![
                (1, 3, Announce::MatchAnswered { group: 0, round: r(5) }),
                (2, 3, Announce::MatchAnswered { group: 0, round: r(3) }),
            ],
        ),
        (
            "mm-merge",
            vec![(
                1,
                6,
                // Merge that silently drops an entry with no watermark excuse.
                Announce::MmMerged {
                    inputs: vec![(dropped_log, BTreeMap::new())],
                    merged: BTreeMap::new(),
                    watermarks: BTreeMap::new(),
                },
            )],
        ),
        (
            "lease-fence",
            vec![
                (10, 6, Announce::LeaseGranted { round: r(1), valid_until: 100 }),
                (50, 7, Announce::FenceLifted { round: r(2) }),
            ],
        ),
        (
            "lease-disjoint-under-skew",
            // The old grant *is* expired at the fence lift (lease-fence
            // passes), but only by 400ns — inside the catalog's drift
            // envelope, so a clock running behind could still consider
            // the old lease valid while the new leader starts writing.
            vec![
                (10, 6, Announce::LeaseGranted { round: r(1), valid_until: 100 }),
                (500, 7, Announce::FenceLifted { round: r(2) }),
            ],
        ),
        (
            "watermark-order",
            vec![(1, 8, Announce::ReplicaTruncated { replica: 8, below: 10, exec: 5 })],
        ),
        (
            "client-fifo",
            // Same (client, seq) chosen with two different payloads.
            vec![chosen(0, 90, 1, b"a"), chosen(1, 90, 1, b"b")],
        ),
        (
            "recovery-sound",
            // An acceptor durably acks a promise, crashes, and replays
            // to a lower round — the "un-promise" a fsync'd WAL exists
            // to make impossible.
            vec![
                (1, 2, Announce::DurablePromise { node: 2, round: r(5) }),
                (2, 2, Announce::NodeRestarted { node: 2 }),
                (
                    3,
                    2,
                    Announce::AcceptorRecovered {
                        node: 2,
                        round: Some(r(3)),
                        watermark: 0,
                        votes: vec![],
                    },
                ),
            ],
        ),
    ];
    let catalog = InvariantSet::standard().names();
    for name in &catalog {
        assert!(
            bad_histories.iter().any(|(n, _)| n == name),
            "no known-bad history exercises invariant {name}"
        );
    }
    assert_eq!(bad_histories.len(), catalog.len());
    for (name, events) in &bad_histories {
        let v = InvariantSet::check_all(events)
            .expect_err(&format!("known-bad history for {name} did not fire"));
        assert_eq!(&v.invariant, name, "wrong invariant fired: {v}");
    }
}

/// The explorer finds the seeded non-intersecting-quorum bug on its own:
/// exhaustive exploration of `badquorum` produces a `chosen-unique`
/// violation with a minimized schedule that (a) reproduces on replay and
/// (b) is 1-minimal — removing any single action loses the violation,
/// i.e. `shrink` reached its fixpoint.
#[test]
fn explorer_finds_seeded_quorum_bug_and_shrinks_it() {
    let inst = instances::badquorum();
    let report = explore(&inst, inst.depth, 50_000);
    let v = report.violation.as_ref().expect("seeded bug not found");
    assert_eq!(v.invariant, "chosen-unique", "wrong violation: {v}");
    assert!(!report.trace.is_empty());

    // The minimized schedule reproduces, with the violation on its last
    // action (no dead tail).
    match replay(&inst, &report.trace) {
        Replayed::Violation(rv, consumed) => {
            assert_eq!(rv.invariant, "chosen-unique");
            assert_eq!(consumed, report.trace.len(), "minimized trace has a dead tail");
        }
        Replayed::State(..) => panic!("minimized trace no longer violates"),
        Replayed::Invalid(e) => panic!("minimized trace does not replay: {e}"),
    }

    // 1-minimality: every action is load-bearing.
    for i in 0..report.trace.len() {
        let mut cand = report.trace.clone();
        let removed = cand.remove(i);
        let still_violates = matches!(
            replay(&inst, &cand),
            Replayed::Violation(rv, _) if rv.invariant == "chosen-unique"
        );
        assert!(
            !still_violates,
            "trace not minimal: removing action {i} ({removed:?}) still violates"
        );
    }
}

/// The explorer's emitted trace round-trips through the serializer and
/// replays under the trace runner's expectation checking.
#[test]
fn emitted_trace_roundtrips_through_serializer() {
    let inst = instances::badquorum();
    let report = explore(&inst, inst.depth, 50_000);
    assert!(report.violation.is_some());
    let text = trace::serialize(inst.name, Some("chosen-unique"), &report.trace);
    let parsed = trace::parse(&text).expect("emitted trace does not parse");
    assert_eq!(parsed.instance, "badquorum");
    let summary = trace::run(&inst, &parsed).expect("emitted trace does not replay");
    assert!(summary.contains("reproduced"), "unexpected summary: {summary}");
}

/// The checked-in regression trace (wildcard-seq form, authored in
/// protocol-message terms) keeps reproducing its violation. If this
/// fails, either the bug the trace pins has been hidden or the warmup
/// schedule changed — re-minimize with
/// `repro check badquorum --emit-trace rust/traces/badquorum.trace`.
#[test]
fn checked_in_badquorum_trace_replays() {
    let text = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/traces/badquorum.trace"
    ));
    let parsed = trace::parse(text).expect("checked-in trace does not parse");
    let inst = instances::find(&parsed.instance)
        .unwrap_or_else(|| panic!("unknown instance {:?}", parsed.instance));
    let summary = trace::run(&inst, &parsed).expect("regression trace failed");
    assert!(summary.contains("reproduced"), "unexpected summary: {summary}");
}

/// Replaying the checked-in trace twice gives byte-identical summaries —
/// the determinism the whole replay-based explorer rests on.
#[test]
fn trace_replay_is_deterministic() {
    let text = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/traces/badquorum.trace"
    ));
    let parsed = trace::parse(text).unwrap();
    let inst = instances::find(&parsed.instance).unwrap();
    let a = trace::run(&inst, &parsed).unwrap();
    let b = trace::run(&inst, &parsed).unwrap();
    assert_eq!(a, b);
}

/// Bounded exhaustive exploration of the mandated f=1 / two-proposer /
/// one-reconfiguration instance: zero violations, and fingerprint dedup
/// collapses the raw schedule tree by well over the required 10x (the
/// commuting-delivery diamonds compound multiplicatively with depth).
#[test]
fn base_exploration_is_clean_and_dedups_10x() {
    let inst = instances::base();
    let report = explore(&inst, 8, 150_000);
    assert!(report.violation.is_none(), "violation: {:?}", report.violation);
    assert!(report.unique_states > 10, "suspiciously small: {report:?}");
    let ratio = report.dedup_ratio();
    assert!(
        ratio >= 10.0,
        "dedup ratio {ratio:.1} < 10 (raw {:.3e}, unique {})",
        report.raw_states,
        report.unique_states
    );
}

/// The lossy instance (drop budget 1) stays safe at smoke depth: losing
/// a message may lose liveness, never safety.
#[test]
fn lossy_exploration_is_clean() {
    let inst = instances::lossy();
    let report = explore(&inst, inst.smoke_depth, 50_000);
    assert!(report.violation.is_none(), "violation: {:?}", report.violation);
    assert!(report.unique_states > 10);
}
