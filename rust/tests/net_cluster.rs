//! Real-network integration: a full Matchmaker MultiPaxos cluster over
//! loopback TCP (the `net` runtime, threads + std::net), exercising the
//! same role code that the simulator drives.

use matchmaker::config::DeploymentConfig;
use matchmaker::net::{local_addrs, spawn_node, NodeHandle};
use matchmaker::roles::{Acceptor, Client, Leader, Matchmaker, Replica};
use matchmaker::statemachine::Noop;
use matchmaker::NodeId;
use std::time::Duration;

/// Spin up a whole f=1 cluster in one process (one thread per node), run
/// closed-loop clients briefly, and check commands were executed.
#[test]
fn tcp_cluster_serves_commands() {
    let cfg = DeploymentConfig::standard(1, 2);
    let layout = cfg.layout.clone();
    // Distinct port range to avoid collisions with other tests.
    let addrs = local_addrs(layout.total_nodes(), 21100);

    let mut handles: Vec<NodeHandle> = Vec::new();
    for &a in &layout.acceptor_pool {
        handles.push(spawn_node(a, Box::new(Acceptor::new(a)), addrs.clone()).unwrap());
    }
    for (i, &m) in layout.matchmaker_pool.iter().enumerate() {
        let node = if i < 3 { Matchmaker::new(m) } else { Matchmaker::new_standby(m) };
        handles.push(spawn_node(m, Box::new(node), addrs.clone()).unwrap());
    }
    for &r in &layout.replicas {
        let mut replica = Replica::new(r, Box::new(Noop));
        replica.announce_execs = true; // we count executions below
        handles.push(spawn_node(r, Box::new(replica), addrs.clone()).unwrap());
    }
    for &p in &layout.proposers {
        let leader = Leader::new(
            p,
            1,
            layout.initial_config(),
            layout.initial_matchmakers(),
            layout.replicas.clone(),
            layout.proposers.clone(),
            cfg.opts,
            p as u64,
        );
        handles.push(spawn_node(p, Box::new(leader), addrs.clone()).unwrap());
    }

    // Clients run the deployment's workload spec (closed loop here, as
    // `DeploymentConfig::standard` configures).
    let mut client_handles = Vec::new();
    for &c in &layout.clients {
        let client = Client::new(c, layout.proposers.clone(), cfg.workload.clone());
        client_handles.push(spawn_node(c, Box::new(client), addrs.clone()).unwrap());
    }

    // Let the cluster run for a bit of wall-clock time.
    std::thread::sleep(Duration::from_millis(1500));

    // The leader announces Chosen via its announce channel; count replica
    // executions through announce streams of replicas.
    let mut executed = 0usize;
    for h in &handles {
        while let Ok((_, a)) = h.announces.try_recv() {
            if matches!(a, matchmaker::node::Announce::Executed { .. }) {
                executed += 1;
            }
        }
    }
    for h in handles.iter().chain(client_handles.iter()) {
        h.shutdown();
    }
    assert!(
        executed > 50,
        "TCP cluster executed only {executed} commands in 1.5 s"
    );
}

/// Two nodes exchange frames over TCP: basic transport sanity with the
/// binary codec in the loop.
#[test]
fn tcp_transport_roundtrip() {
    use matchmaker::node::{Effects, Node, Timer};
    use matchmaker::msg::Msg;
    use matchmaker::Time;

    /// Minimal counting echo node.
    struct Echo {
        peer: NodeId,
        limit: u64,
        count: u64,
    }
    impl Node for Echo {
        fn on_start(&mut self, _now: Time, fx: &mut Effects) {
            if self.peer == 1 {
                // node 0 initiates
                fx.send(self.peer, Msg::Heartbeat { epoch: 0 });
            }
        }
        fn on_msg(&mut self, _now: Time, from: NodeId, _msg: Msg, fx: &mut Effects) {
            self.count += 1;
            fx.announce(matchmaker::node::Announce::Executed { slot: self.count, replica: 0 });
            if self.count < self.limit {
                fx.send(from, Msg::Heartbeat { epoch: self.count });
            }
        }
        fn on_timer(&mut self, _now: Time, _t: Timer, _fx: &mut Effects) {}
        fn role(&self) -> &'static str {
            "echo"
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    let addrs = local_addrs(2, 21400);
    let h0 = spawn_node(0, Box::new(Echo { peer: 1, limit: 20, count: 0 }), addrs.clone()).unwrap();
    let h1 = spawn_node(1, Box::new(Echo { peer: 0, limit: 20, count: 0 }), addrs).unwrap();

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut seen = 0;
    while std::time::Instant::now() < deadline && seen < 20 {
        if h0.announces.recv_timeout(Duration::from_millis(100)).is_ok() {
            seen += 1;
        }
    }
    h0.shutdown();
    h1.shutdown();
    assert!(seen >= 19, "echo round trips stalled at {seen}");
}

/// `Msg::Busy` pushback propagates through the TCP runtime: a pushback
/// frame produced on one node traverses the codec + framing and lands
/// in the real `Client` role's handler on another node, which counts
/// it, sheds, and moves on. Regression for the `repro run --role
/// client` path, which wires `admission = ..,shed:1` into
/// `Client::shed_on_busy`.
#[test]
fn tcp_busy_pushback_reaches_client() {
    use matchmaker::msg::Msg;
    use matchmaker::node::{Announce, Effects, Node, Timer};
    use matchmaker::workload::WorkloadSpec;
    use matchmaker::Time;

    /// A "leader" that is permanently overloaded: every client request
    /// gets admission pushback instead of a reply.
    struct AlwaysBusy;
    impl Node for AlwaysBusy {
        fn on_msg(&mut self, _now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
            if let Msg::ClientRequest { group, cmd, .. } = msg {
                fx.send(from, Msg::Busy { group, seq: cmd.seq, retry_after_us: 100 });
            }
        }
        fn on_timer(&mut self, _now: Time, _t: Timer, _fx: &mut Effects) {}
        fn role(&self) -> &'static str {
            "always-busy"
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    let addrs = local_addrs(2, 21600);
    let h0 = spawn_node(0, Box::new(AlwaysBusy), addrs.clone()).unwrap();
    let mut client = Client::new(1, vec![0], WorkloadSpec::closed_loop());
    client.shed_on_busy = true;
    let h1 = spawn_node(1, Box::new(client), addrs).unwrap();

    // Shedding refills the closed-loop window, so pushback keeps the
    // request/Busy cycle spinning: several observations must land fast.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut busy_seen = 0;
    while std::time::Instant::now() < deadline && busy_seen < 5 {
        if let Ok((_, a)) = h1.announces.recv_timeout(Duration::from_millis(100)) {
            if matches!(a, Announce::BusyObserved { client: 1, .. }) {
                busy_seen += 1;
            }
        }
    }
    h0.shutdown();
    h1.shutdown();
    assert!(busy_seen >= 5, "only {busy_seen} Busy pushbacks reached the client over TCP");
}
