//! Dedicated codec round-trip coverage for the shard-tagged messages
//! (MatchA/B/Nack, GarbageA/B, the client path, StopB/Bootstrap's
//! multi-group logs) and a backfill for the state-retention messages
//! (`CatchUp`/`SnapshotRequest`/`SnapshotResp`, tags 32–34), which until
//! now were only covered incidentally via `sample_messages`.

use matchmaker::codec::Wire;
use matchmaker::config::Configuration;
use matchmaker::msg::{Command, Envelope, MmLog, Msg, Value};
use matchmaker::round::Round;
use matchmaker::{GroupId, NodeId};
use std::collections::BTreeMap;

fn rt(msg: Msg) -> Msg {
    let env = Envelope { from: 7, to: 9, msg };
    let bytes = env.encode();
    let back = Envelope::decode(&bytes).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!((back.from, back.to), (7, 9));
    // Canonical: re-encoding the decode is byte-identical.
    assert_eq!(back.encode(), bytes);
    back.msg
}

fn r(epoch: u64, proposer: NodeId, seq: u64) -> Round {
    Round { epoch, proposer, seq }
}

fn cfg(id: u64) -> Configuration {
    Configuration::majority(id, vec![3, 4, 5])
}

#[test]
fn shard_tagged_matchmaking_roundtrips() {
    for group in [0u32, 1, 7, u32::MAX] {
        let m = Msg::MatchA { group, round: r(1, 2, 3), config: cfg(9) };
        assert_eq!(rt(m.clone()), m);
        let mut prior = BTreeMap::new();
        prior.insert(r(0, 2, 0), cfg(1));
        prior.insert(r(1, 2, 0), cfg(2));
        let m = Msg::MatchB {
            group,
            round: r(1, 2, 3),
            gc_watermark: Some(r(0, 2, 9)),
            prior,
        };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::MatchNack { group, round: r(1, 2, 3), blocking: r(2, 0, 0) };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::GarbageA { group, round: r(4, 1, 2) };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::GarbageB { group, round: r(4, 1, 2) };
        assert_eq!(rt(m.clone()), m);
    }
}

#[test]
fn shard_tagged_client_path_roundtrips() {
    let cmd = Command { client: 31, seq: 17, payload: vec![0xab; 32] };
    let m = Msg::ClientRequest { group: 5, cmd: cmd.clone(), lowest: 12 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::ClientReply { group: 5, seq: 17, result: vec![1, 2, 3] };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::NotLeader { group: 5, hint: Some(2) };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::NotLeader { group: 0, hint: None };
    assert_eq!(rt(m.clone()), m);
}

#[test]
fn multi_group_stop_and_bootstrap_roundtrip() {
    // A shared matchmaker's state: three groups at different rounds,
    // two with GC watermarks — the §6 stop-and-copy payload.
    let mut log: MmLog = BTreeMap::new();
    log.entry(0).or_default().insert(r(1, 0, 4), cfg(4));
    log.entry(1).or_default().insert(r(1, 2, 0), cfg(5));
    log.entry(1).or_default().insert(r(1, 2, 1), cfg(6));
    log.entry(9).or_default();
    let mut wms: BTreeMap<GroupId, Round> = BTreeMap::new();
    wms.insert(0, r(1, 0, 4));
    wms.insert(1, r(1, 2, 1));
    let m = Msg::StopB { log: log.clone(), gc_watermarks: wms.clone() };
    let back = rt(m.clone());
    assert_eq!(back, m);
    // The empty group-9 log survives (absent vs empty is meaningful for
    // log-merge idempotence).
    match back {
        Msg::StopB { log, .. } => {
            assert_eq!(log.len(), 3);
            assert!(log[&9].is_empty());
            assert_eq!(log[&1].len(), 2);
        }
        other => panic!("{other:?}"),
    }
    let m = Msg::Bootstrap { log, gc_watermarks: wms, generation: 42 };
    assert_eq!(rt(m.clone()), m);
}

#[test]
fn retention_messages_roundtrip() {
    // Backfill: dedicated round-trips for tags 32–34.
    let m = Msg::CatchUp { below: u64::MAX - 1, peer: 0 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::SnapshotRequest { from: 0 };
    assert_eq!(rt(m.clone()), m);
    // Empty, small, and larger snapshot payloads.
    for state in [vec![], vec![0u8], vec![0x5a; 4096]] {
        let m = Msg::SnapshotResp {
            base: 1 << 40,
            state,
            entries: vec![
                (1 << 40, Value::Noop),
                (
                    (1 << 40) + 1,
                    Value::Batch(vec![
                        Command { client: 1, seq: 2, payload: vec![9] },
                        Command { client: 2, seq: 1, payload: vec![] },
                    ]),
                ),
            ],
        };
        assert_eq!(rt(m.clone()), m);
    }
    let m = Msg::SnapshotResp { base: 0, state: vec![], entries: vec![] };
    assert_eq!(rt(m.clone()), m);
}

#[test]
fn read_and_lease_messages_roundtrip() {
    // Dedicated round-trips for the linearizable-read path (tags 35–39)
    // and the lease protocol (tags 40–42).
    for group in [0u32, 3, u32::MAX] {
        let m = Msg::Read { group, seq: 1, payload: vec![b'g', 1, b'k'] };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::Read { group, seq: u64::MAX, payload: vec![] };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::ReadReply { group, seq: 9, result: vec![0xff; 64] };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::NotLeaseholder { group, hint: Some(14) };
        assert_eq!(rt(m.clone()), m);
        let m = Msg::NotLeaseholder { group, hint: None };
        assert_eq!(rt(m.clone()), m);
    }
    let m = Msg::ReadIndexReq { id: 0 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::ReadIndexResp { id: u64::MAX, upto: 1 << 40 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::LeaseRenew { round: r(2, 1, 7), seq: 99 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::LeaseRenewAck { round: r(2, 1, 7), seq: 99 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::LeaseGrant {
        round: r(2, 1, 7),
        upto: u64::MAX,
        granted_at: 123_456_789,
        valid_until: u64::MAX - 1,
    };
    assert_eq!(rt(m.clone()), m);
}

#[test]
fn read_and_lease_messages_reject_truncation() {
    let msgs = vec![
        Msg::Read { group: 1, seq: 2, payload: vec![3, 4] },
        Msg::ReadReply { group: 1, seq: 2, result: vec![5] },
        Msg::ReadIndexReq { id: 6 },
        Msg::ReadIndexResp { id: 6, upto: 7 },
        Msg::NotLeaseholder { group: 1, hint: Some(8) },
        Msg::LeaseRenew { round: r(1, 2, 3), seq: 4 },
        Msg::LeaseRenewAck { round: r(1, 2, 3), seq: 4 },
        Msg::LeaseGrant { round: r(1, 2, 3), upto: 5, granted_at: 6, valid_until: 7 },
    ];
    for m in msgs {
        let bytes = m.encode();
        assert_eq!(Msg::decode(&bytes).unwrap(), m);
        for cut in 0..bytes.len() {
            assert!(
                Msg::decode(&bytes[..cut]).is_err(),
                "prefix of len {cut} of {m:?} decoded"
            );
        }
    }
}

#[test]
fn retention_messages_reject_truncation() {
    // Every strict prefix of an encoding must fail to decode (no panic,
    // no silent success) — the framing property the TCP runtime relies
    // on for tags 32–34.
    let msgs = vec![
        Msg::CatchUp { below: 4096, peer: 12 },
        Msg::SnapshotRequest { from: 17 },
        Msg::SnapshotResp {
            base: 64,
            state: vec![1, 2, 3],
            entries: vec![(64, Value::Cmd(Command { client: 3, seq: 4, payload: vec![5] }))],
        },
        Msg::MatchA { group: 3, round: r(0, 1, 0), config: cfg(0) },
        Msg::StopB {
            log: [(2u32, [(r(0, 1, 0), cfg(1))].into_iter().collect())]
                .into_iter()
                .collect(),
            gc_watermarks: [(2u32, r(0, 1, 0))].into_iter().collect(),
        },
    ];
    for m in msgs {
        let bytes = m.encode();
        assert_eq!(Msg::decode(&bytes).unwrap(), m);
        for cut in 0..bytes.len() {
            assert!(
                Msg::decode(&bytes[..cut]).is_err(),
                "prefix of len {cut} of {m:?} decoded"
            );
        }
    }
}

#[test]
fn snapshot_chunk_messages_roundtrip() {
    // Dedicated round-trips for the chunked/resumable snapshot transfer
    // (tags 43–44). Empty, single-byte, and chunk-sized payloads, plus
    // the boundary seq/total values.
    for bytes in [vec![], vec![0xa5u8], vec![0x3c; 256 * 1024]] {
        let m = Msg::SnapshotChunk { base: 1 << 40, seq: 0, total: 1, bytes };
        assert_eq!(rt(m.clone()), m);
    }
    let m = Msg::SnapshotChunk {
        base: u64::MAX - 1,
        seq: u32::MAX - 1,
        total: u32::MAX,
        bytes: vec![1, 2, 3],
    };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::SnapshotResume { base: 0, next: 0 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::SnapshotResume { base: u64::MAX, next: u32::MAX };
    assert_eq!(rt(m.clone()), m);
}

#[test]
fn busy_messages_roundtrip() {
    // Dedicated round-trips for the overload-control pushback (tag 45,
    // DESIGN.md §Overload), including the boundary values.
    for group in [0u32, 3, u32::MAX] {
        let m = Msg::Busy { group, seq: 1, retry_after_us: 20_000 };
        assert_eq!(rt(m.clone()), m);
    }
    let m = Msg::Busy { group: 0, seq: 0, retry_after_us: 0 };
    assert_eq!(rt(m.clone()), m);
    let m = Msg::Busy { group: u32::MAX, seq: u64::MAX, retry_after_us: u64::MAX };
    assert_eq!(rt(m.clone()), m);
}

#[test]
fn busy_messages_reject_truncation() {
    let m = Msg::Busy { group: 5, seq: 7, retry_after_us: 20_000 };
    let bytes = m.encode();
    assert_eq!(Msg::decode(&bytes).unwrap(), m);
    for cut in 0..bytes.len() {
        assert!(Msg::decode(&bytes[..cut]).is_err(), "prefix of len {cut} of {m:?} decoded");
    }
}

#[test]
fn tag_table_is_exhaustive_and_names_busy() {
    // The table must stay dense (tags exactly 0..len, no dups), cover
    // every sampled variant, and name the overload pushback at tag 45 —
    // a new variant that forgets its table entry fails here.
    use matchmaker::codec::{check_tag_table, sample_messages, MSG_TAG_TABLE};
    check_tag_table(MSG_TAG_TABLE);
    assert_eq!(MSG_TAG_TABLE.len(), 46);
    assert_eq!(sample_messages().len(), MSG_TAG_TABLE.len());
    assert!(MSG_TAG_TABLE.contains(&(45, "Busy")), "Busy missing from the tag table");
}

#[test]
fn snapshot_chunk_messages_reject_truncation() {
    let msgs = vec![
        Msg::SnapshotChunk { base: 64, seq: 2, total: 9, bytes: vec![1, 2, 3, 4] },
        Msg::SnapshotResume { base: 64, next: 3 },
    ];
    for m in msgs {
        let bytes = m.encode();
        assert_eq!(Msg::decode(&bytes).unwrap(), m);
        for cut in 0..bytes.len() {
            assert!(
                Msg::decode(&bytes[..cut]).is_err(),
                "prefix of len {cut} of {m:?} decoded"
            );
        }
    }
}
