//! Deterministic discrete-event simulator.
//!
//! This is the testbed substitute for the paper's EC2 cluster (see
//! DESIGN.md §Substitutions): nodes are sans-io [`Node`] state machines,
//! the network is a per-link delay model with optional jitter, drops,
//! partitions, and per-message-kind extra delay (used by the §8.2 WAN
//! ablation, which delays `Phase1B`/`MatchB` by 250 ms), and time is
//! virtual — a 35-second benchmark with 100 clients runs in well under a
//! second of wall-clock time, bit-for-bit reproducibly.
//!
//! Failure injection: [`Sim::crash`] silently discards a node's traffic
//! and timers (fail-stop); [`Sim::replace_node`] models a fresh machine
//! joining. Scheduled control closures ([`Sim::schedule`]) script the
//! experiment timelines (reconfigure at t, fail at t, ...).

use crate::msg::{Envelope, Msg, MsgKind};
use crate::node::{Announce, Effects, Node, Timer};
use crate::util::{Fnv, Rng};
use crate::{NodeId, Time, MS, US};
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

/// Per-link network model. Defaults approximate the paper's single-AZ
/// deployment (~0.1 ms one-way with modest jitter).
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Base one-way delay.
    pub base_delay: Time,
    /// Uniform extra delay in `[0, jitter)`.
    pub jitter: Time,
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// Extra delay per message kind (§8.2: +250 ms on Phase1B/MatchB).
    pub per_kind_extra: BTreeMap<MsgKind, Time>,
    /// Delay for self-addressed messages.
    pub local_delay: Time,
    /// Sender-side serialization cost per message (NIC/CPU egress): a
    /// node's outbound messages depart one `tx_overhead` apart, so a
    /// node emitting many messages queues behind itself. `0` (default)
    /// models infinite egress bandwidth — the pre-batching behavior.
    /// This is the resource Phase 2 batching trades against: fewer,
    /// larger messages per chosen command.
    pub tx_overhead: Time,
    /// Directed severed links (`from → to` only): the nemesis one-way
    /// cuts ([`crate::nemesis`]). Symmetric partitions live on
    /// [`Sim::set_link`]; this matrix is what asymmetric partitions use.
    pub cut_oneway: BTreeSet<(NodeId, NodeId)>,
    /// Per-node link-delay multiplier in percent (`100` = nominal).
    /// Every message a listed node sends or receives has its link delay
    /// scaled — the "gray failure" slow-but-alive node. Applied with
    /// pure arithmetic (no RNG draw), so an empty map is byte-identical
    /// to the pre-nemesis model.
    pub node_slow_pct: BTreeMap<NodeId, u64>,
    /// Per-node clock skew in nanoseconds (may be negative): the offset
    /// a node's local clock reads relative to global virtual time. Only
    /// observed timestamps shift — event *scheduling* stays global, so
    /// replayability is untouched.
    pub clock_skew_ns: BTreeMap<NodeId, i64>,
    /// Per-node clock drift in parts-per-million, compounding with skew:
    /// a node with drift `d` observes `now * (1 + d/1e6) + skew`.
    pub clock_drift_ppm: BTreeMap<NodeId, i64>,
    /// Probability an in-flight message is duplicated (a second copy is
    /// enqueued at the same arrival time, fresh seq).
    pub dup_prob: f64,
    /// Probability a message takes `reorder_extra` additional delay,
    /// overtaking later traffic on the same link.
    pub reorder_prob: f64,
    /// The extra delay a reordered message incurs.
    pub reorder_extra: Time,
    /// Probability a message is corrupted at the codec boundary: the
    /// message is encoded, one byte is flipped, and the frame is decoded
    /// again. An undecodable frame is dropped (what the TCP runtime's
    /// length-checked framing would do); a decodable mutation is
    /// delivered as-is — exactly the bytes a flaky NIC could hand the
    /// codec.
    pub corrupt_prob: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            base_delay: 100 * US,
            jitter: 20 * US,
            drop_prob: 0.0,
            per_kind_extra: BTreeMap::new(),
            local_delay: 5 * US,
            tx_overhead: 0,
            cut_oneway: BTreeSet::new(),
            node_slow_pct: BTreeMap::new(),
            clock_skew_ns: BTreeMap::new(),
            clock_drift_ppm: BTreeMap::new(),
            dup_prob: 0.0,
            reorder_prob: 0.0,
            reorder_extra: 0,
            corrupt_prob: 0.0,
        }
    }
}

impl NetworkModel {
    /// The paper's single-AZ LAN (the default model, by its experiment
    /// name): ~0.1 ms one-way with modest jitter, no drops.
    pub fn lan() -> NetworkModel {
        NetworkModel::default()
    }

    /// The §8.2 WAN ablation: matchmakers/acceptors delay their MatchB and
    /// Phase1B responses by `extra` (paper: 250 ms).
    pub fn with_wan_phase1(mut self, extra: Time) -> NetworkModel {
        self.per_kind_extra.insert(MsgKind::Phase1B, extra);
        self.per_kind_extra.insert(MsgKind::MatchB, extra);
        self
    }
}

enum EventKind {
    // Boxed: Msg is a large enum; keeping heap elements small makes the
    // event queue's sift operations cheap (profiled: memmove was 27% of a
    // 100-client run with the envelope inline).
    Deliver(Box<Envelope>),
    Timer(NodeId, Timer),
    Control(u64),
}

struct Event {
    at: Time,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap via reverse: earliest (at, seq) first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl Event {
    /// Short content signature, excluding the scheduled time: what trace
    /// files record and replays validate. Deliberately coarse (message
    /// *kind*, not payload) so traces stay readable and survive payload
    /// tweaks; full-payload identity is the fingerprint's job.
    fn sig(&self) -> String {
        match &self.kind {
            EventKind::Deliver(env) => format!("d{}->{}:{:?}", env.from, env.to, env.msg.kind()),
            EventKind::Timer(id, t) => format!("t{id}:{t:?}"),
            EventKind::Control(cid) => format!("c{cid}"),
        }
    }

    /// Full content signature for state fingerprints: unlike [`Event::sig`]
    /// this includes the entire message payload, so two in-flight
    /// `Phase2A`s carrying different values never collapse into one
    /// fingerprint bucket (which would make dedup unsound).
    fn content_sig(&self) -> String {
        match &self.kind {
            EventKind::Deliver(env) => format!("d{}->{}:{:?}", env.from, env.to, env.msg),
            EventKind::Timer(id, t) => format!("t{id}:{t:?}"),
            EventKind::Control(cid) => format!("c{cid}"),
        }
    }
}

/// A pending (scheduled but not yet executed) event, as enumerated by
/// [`Sim::pending`] for the model checker ([`crate::check`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PendingEvent {
    /// Scheduler sequence number — the stable identity used by
    /// [`Sim::fire`] / [`Sim::drop_event`] / [`Sim::duplicate_event`].
    /// Seqs are assigned deterministically in creation order, so replaying
    /// the same action prefix on a rebuilt instance yields the same seqs.
    pub seq: u64,
    /// Scheduled execution time. The explorer ignores it (it explores
    /// *orders*, not timings) but replays respect it for the clock.
    pub at: Time,
    /// Short content signature (see trace format in DESIGN.md).
    pub sig: String,
    pub kind: PendingKind,
}

/// Discriminant of a [`PendingEvent`], with the routing the explorer's
/// enabled-action filter needs (channel FIFO, timer filtering).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PendingKind {
    /// An in-flight message.
    Deliver { from: NodeId, to: NodeId },
    /// An armed timer.
    Timer { node: NodeId, timer: Timer },
    /// A scheduled control closure (experiment script step).
    Control,
}

type Control = Box<dyn FnOnce(&mut Sim) + Send>;

/// The simulator.
pub struct Sim {
    nodes: Vec<Option<Box<dyn Node>>>,
    crashed: Vec<bool>,
    clock: Time,
    heap: BinaryHeap<Event>,
    seq: u64,
    rng: Rng,
    pub net: NetworkModel,
    controls: BTreeMap<u64, Control>,
    next_control: u64,
    /// Severed node pairs (unordered).
    cut_links: BTreeSet<(NodeId, NodeId)>,
    /// Per-node egress-busy horizon (only used when `net.tx_overhead > 0`).
    tx_busy: BTreeMap<NodeId, Time>,
    /// All announcements, timestamped: the harness's metrics feed and the
    /// test suite's safety-invariant feed.
    pub announces: Vec<(Time, NodeId, Announce)>,
    /// Total messages delivered (perf metrics).
    pub delivered: u64,
    /// Total messages dropped by the model.
    pub dropped: u64,
}

impl Sim {
    pub fn new(seed: u64, net: NetworkModel) -> Sim {
        Sim {
            nodes: Vec::new(),
            crashed: Vec::new(),
            clock: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            rng: Rng::new(seed),
            net,
            controls: BTreeMap::new(),
            next_control: 0,
            cut_links: BTreeSet::new(),
            tx_busy: BTreeMap::new(),
            announces: Vec::new(),
            delivered: 0,
            dropped: 0,
        }
    }

    pub fn now(&self) -> Time {
        self.clock
    }

    /// Install a node with the given id (ids must be dense-ish; the vector
    /// grows to fit). The node's `on_start` runs at the current time.
    pub fn add_node(&mut self, id: NodeId, node: Box<dyn Node>) {
        let idx = id as usize;
        if self.nodes.len() <= idx {
            self.nodes.resize_with(idx + 1, || None);
            self.crashed.resize(idx + 1, false);
        }
        self.nodes[idx] = Some(node);
        self.crashed[idx] = false;
        let mut fx = Effects::new();
        let now = self.clock;
        if let Some(n) = self.nodes[idx].as_mut() {
            n.on_start(now, &mut fx);
        }
        self.apply_effects(id, fx);
    }

    /// Fail-stop crash: all future traffic and timers are discarded.
    pub fn crash(&mut self, id: NodeId) {
        if let Some(c) = self.crashed.get_mut(id as usize) {
            *c = true;
        }
    }

    /// Replace a crashed node with a fresh instance (recovery/new machine).
    ///
    /// Emits [`Announce::NodeRestarted`] so per-node monotonicity
    /// invariants ([`crate::check`]) reset their cursors: a fresh
    /// incarnation legitimately restarts its snapshot/truncation
    /// watermarks from zero.
    pub fn replace_node(&mut self, id: NodeId, node: Box<dyn Node>) {
        if self.nodes.get(id as usize).is_some_and(|n| n.is_some()) {
            self.announces
                .push((self.clock, id, Announce::NodeRestarted { node: id }));
        }
        self.add_node(id, node);
    }

    pub fn is_crashed(&self, id: NodeId) -> bool {
        self.crashed.get(id as usize).copied().unwrap_or(true)
    }

    /// Sever / restore the link between `a` and `b` (both directions).
    pub fn set_link(&mut self, a: NodeId, b: NodeId, up: bool) {
        let key = (a.min(b), a.max(b));
        if up {
            self.cut_links.remove(&key);
        } else {
            self.cut_links.insert(key);
        }
    }

    /// Sever / restore only the `from → to` direction (asymmetric
    /// partition: `to` still reaches `from`).
    pub fn set_link_oneway(&mut self, from: NodeId, to: NodeId, up: bool) {
        if up {
            self.net.cut_oneway.remove(&(from, to));
        } else {
            self.net.cut_oneway.insert((from, to));
        }
    }

    /// Is the `from → to` direction currently deliverable? (Either a
    /// symmetric cut or a directed cut blocks it.)
    pub fn link_open(&self, from: NodeId, to: NodeId) -> bool {
        self.link_up(from, to) && !self.net.cut_oneway.contains(&(from, to))
    }

    /// Ids of every installed (ever-added) node.
    pub fn node_ids(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|_| i as NodeId))
            .collect()
    }

    /// Gray-slow a node: all its link delays are scaled by `pct`/100
    /// (`100` restores nominal speed).
    pub fn set_node_slow(&mut self, node: NodeId, pct: u64) {
        if pct == 100 {
            self.net.node_slow_pct.remove(&node);
        } else {
            self.net.node_slow_pct.insert(node, pct);
        }
    }

    /// Skew a node's local clock by `skew_ns` (what its `now` reads,
    /// relative to global virtual time; negative = behind).
    pub fn set_clock_skew(&mut self, node: NodeId, skew_ns: i64) {
        if skew_ns == 0 {
            self.net.clock_skew_ns.remove(&node);
        } else {
            self.net.clock_skew_ns.insert(node, skew_ns);
        }
    }

    /// Set a node's clock drift rate in parts-per-million (`0` restores
    /// a true-rate clock). Compounds with skew in [`Sim::local_now`].
    pub fn set_clock_drift(&mut self, node: NodeId, ppm: i64) {
        if ppm == 0 {
            self.net.clock_drift_ppm.remove(&node);
        } else {
            self.net.clock_drift_ppm.insert(node, ppm);
        }
    }

    /// The virtual time `node` observes: global clock adjusted by its
    /// configured skew and drift. Identity when the node has neither
    /// (the common case costs two empty-map probes and no arithmetic).
    pub fn local_now(&self, node: NodeId) -> Time {
        if self.net.clock_skew_ns.is_empty() && self.net.clock_drift_ppm.is_empty() {
            return self.clock;
        }
        let skew = self.net.clock_skew_ns.get(&node).copied().unwrap_or(0);
        let ppm = self.net.clock_drift_ppm.get(&node).copied().unwrap_or(0);
        if skew == 0 && ppm == 0 {
            return self.clock;
        }
        let drifted = self.clock as i128 + (self.clock as i128 * ppm as i128) / 1_000_000
            + skew as i128;
        drifted.clamp(0, u64::MAX as i128) as Time
    }

    /// Schedule a control closure at absolute time `at` (experiment
    /// scripting: reconfigure, crash, start clients, ...).
    pub fn schedule(&mut self, at: Time, f: impl FnOnce(&mut Sim) + Send + 'static) {
        let id = self.next_control;
        self.next_control += 1;
        self.controls.insert(id, Box::new(f));
        self.push(at, EventKind::Control(id));
    }

    /// Run a closure against a concrete node type (control plane: e.g.
    /// `leader.reconfigure(...)`), applying any effects it produces.
    pub fn with_node<T: 'static, R>(
        &mut self,
        id: NodeId,
        f: impl FnOnce(&mut T, Time, &mut Effects) -> R,
    ) -> Option<R> {
        let now = self.clock;
        let mut fx = Effects::new();
        let r = {
            let node = self.nodes.get_mut(id as usize)?.as_mut()?;
            let t = node.as_any_mut().downcast_mut::<T>()?;
            Some(f(t, now, &mut fx))
        };
        self.apply_effects(id, fx);
        r
    }

    /// Immutable-ish peek at a node (metrics harvesting).
    pub fn node_mut<T: 'static>(&mut self, id: NodeId) -> Option<&mut T> {
        self.nodes
            .get_mut(id as usize)?
            .as_mut()?
            .as_any_mut()
            .downcast_mut::<T>()
    }

    fn push(&mut self, at: Time, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { at, seq, kind });
    }

    fn link_up(&self, a: NodeId, b: NodeId) -> bool {
        !self.cut_links.contains(&(a.min(b), a.max(b)))
    }

    fn apply_effects(&mut self, from: NodeId, fx: Effects) {
        for a in fx.announces {
            self.announces.push((self.clock, from, a));
        }
        for (delay, timer) in fx.timers {
            self.push(self.clock + delay, EventKind::Timer(from, timer));
        }
        for (to, msg) in fx.msgs {
            // Fault checks that never draw from the RNG come first, so a
            // run with every nemesis knob disabled consumes the exact
            // same RNG stream as the pre-nemesis model (baselines,
            // traces, and sweep pins stay byte-identical).
            if !self.link_up(from, to) || self.net.cut_oneway.contains(&(from, to)) {
                self.dropped += 1;
                continue;
            }
            if self.net.drop_prob > 0.0 && self.rng.chance(self.net.drop_prob) {
                self.dropped += 1;
                continue;
            }
            let msg = if self.net.corrupt_prob > 0.0 && self.rng.chance(self.net.corrupt_prob)
            {
                match corrupt_at_codec(&msg, &mut self.rng) {
                    Some(m) => m,
                    None => {
                        // Undecodable frame: the framing layer drops it.
                        self.dropped += 1;
                        continue;
                    }
                }
            } else {
                msg
            };
            let kind_extra = self
                .net
                .per_kind_extra
                .get(&msg.kind())
                .copied()
                .unwrap_or(0);
            let mut delay = if to == from {
                self.net.local_delay
            } else {
                let jitter = if self.net.jitter > 0 {
                    self.rng.gen_range(self.net.jitter)
                } else {
                    0
                };
                self.net.base_delay + jitter
            } + kind_extra;
            if !self.net.node_slow_pct.is_empty() {
                // Gray-slow scaling: pure arithmetic, endpoints compound.
                for end in [from, to] {
                    if let Some(pct) = self.net.node_slow_pct.get(&end) {
                        delay = delay.saturating_mul(*pct) / 100;
                    }
                }
            }
            if self.net.reorder_prob > 0.0 && self.rng.chance(self.net.reorder_prob) {
                delay += self.net.reorder_extra;
            }
            if self.net.tx_overhead > 0 {
                // Egress serialization: this message departs only after
                // the sender's previous messages have left the NIC.
                let free = self.tx_busy.get(&from).copied().unwrap_or(0).max(self.clock);
                let depart = free + self.net.tx_overhead;
                self.tx_busy.insert(from, depart);
                delay += depart - self.clock;
            }
            let dup = self.net.dup_prob > 0.0 && self.rng.chance(self.net.dup_prob);
            let at = self.clock + delay;
            if dup {
                self.push(
                    at,
                    EventKind::Deliver(Box::new(Envelope { from, to, msg: msg.clone() })),
                );
            }
            self.push(at, EventKind::Deliver(Box::new(Envelope { from, to, msg })));
        }
    }

    /// Execute one already-dequeued event against the current state.
    /// The clock must already be advanced to (at least) the event's time;
    /// callers ([`Sim::run_until`], [`Sim::fire`]) own that policy.
    fn execute(&mut self, ev: Event) {
        match ev.kind {
            EventKind::Deliver(env) => {
                let idx = env.to as usize;
                if self.crashed.get(idx).copied().unwrap_or(true) {
                    return;
                }
                let mut fx = Effects::new();
                // Skewed/drifting nodes observe their local clock; event
                // scheduling stays on the global clock.
                let now = self.local_now(env.to);
                if let Some(Some(node)) = self.nodes.get_mut(idx) {
                    node.on_msg(now, env.from, env.msg, &mut fx);
                    self.delivered += 1;
                } else {
                    return;
                }
                self.apply_effects(env.to, fx);
            }
            EventKind::Timer(id, timer) => {
                let idx = id as usize;
                if self.crashed.get(idx).copied().unwrap_or(true) {
                    return;
                }
                let mut fx = Effects::new();
                let now = self.local_now(id);
                if let Some(Some(node)) = self.nodes.get_mut(idx) {
                    node.on_timer(now, timer, &mut fx);
                } else {
                    return;
                }
                self.apply_effects(id, fx);
            }
            EventKind::Control(cid) => {
                if let Some(f) = self.controls.remove(&cid) {
                    f(self);
                }
            }
        }
    }

    /// Run until the virtual clock reaches `until` (events at exactly
    /// `until` are processed) or the event queue drains.
    pub fn run_until(&mut self, until: Time) {
        while let Some(ev) = self.heap.peek() {
            if ev.at > until {
                break;
            }
            let ev = self.heap.pop().unwrap();
            self.clock = self.clock.max(ev.at);
            self.execute(ev);
        }
        self.clock = self.clock.max(until);
    }

    /// Execute the single earliest pending event (timestamp order, the
    /// same policy as [`Sim::run_until`]). Returns `false` when the queue
    /// is empty. This is the step primitive the invariant layer uses to
    /// evaluate the catalog after *every* event rather than per run.
    pub fn step(&mut self) -> bool {
        match self.heap.pop() {
            Some(ev) => {
                self.clock = self.clock.max(ev.at);
                self.execute(ev);
                true
            }
            None => false,
        }
    }

    /// Scheduled time of the earliest pending event, if any.
    pub fn next_event_at(&self) -> Option<Time> {
        self.heap.peek().map(|ev| ev.at)
    }

    // ---- Model-checker surface (crate::check) -------------------------
    //
    // The explorer treats the simulator as a transition system: `pending`
    // enumerates the frontier, `fire`/`drop_event`/`duplicate_event`
    // apply one transition by seq, and `fingerprint` names the resulting
    // state for dedup. Seqs are assigned deterministically, so replaying
    // an action prefix on a freshly built instance reproduces them.

    /// Inject a message as if `from` had sent it now (bypassing the
    /// network model's delay/drop machinery — it lands on the frontier as
    /// a normal pending Deliver). Checker instances use this to introduce
    /// client traffic at branch points.
    pub fn inject(&mut self, from: NodeId, to: NodeId, msg: Msg) {
        let at = self.clock;
        self.push(at, EventKind::Deliver(Box::new(Envelope { from, to, msg })));
    }

    /// Snapshot of every pending event, sorted by seq (creation order).
    pub fn pending(&self) -> Vec<PendingEvent> {
        let mut v: Vec<PendingEvent> = self
            .heap
            .iter()
            .map(|ev| PendingEvent {
                seq: ev.seq,
                at: ev.at,
                sig: ev.sig(),
                kind: match &ev.kind {
                    EventKind::Deliver(env) => {
                        PendingKind::Deliver { from: env.from, to: env.to }
                    }
                    EventKind::Timer(id, t) => PendingKind::Timer { node: *id, timer: *t },
                    EventKind::Control(_) => PendingKind::Control,
                },
            })
            .collect();
        v.sort_by_key(|p| p.seq);
        v
    }

    /// Remove the event with the given seq from the queue (linear scan +
    /// heap rebuild; checker frontiers are tens of events, not millions).
    fn take_event(&mut self, seq: u64) -> Option<Event> {
        let mut found = None;
        let mut rest = Vec::with_capacity(self.heap.len());
        for ev in self.heap.drain() {
            if ev.seq == seq && found.is_none() {
                found = Some(ev);
            } else {
                rest.push(ev);
            }
        }
        self.heap = rest.into();
        found
    }

    /// Execute the pending event with the given seq *now*, regardless of
    /// its position in the timestamp order (the explorer's reordering
    /// lever). The clock still advances to at least the event's scheduled
    /// time, so `now` never runs backwards. Returns the event's signature,
    /// or `None` if no such seq is pending.
    pub fn fire(&mut self, seq: u64) -> Option<String> {
        let ev = self.take_event(seq)?;
        self.clock = self.clock.max(ev.at);
        let sig = ev.sig();
        self.execute(ev);
        Some(sig)
    }

    /// Discard a pending *message* (models a network drop at a point of
    /// the explorer's choosing). Timers and controls cannot be dropped —
    /// the event is left in place and `None` is returned.
    pub fn drop_event(&mut self, seq: u64) -> Option<String> {
        let ev = self.take_event(seq)?;
        if !matches!(ev.kind, EventKind::Deliver(_)) {
            self.heap.push(ev);
            return None;
        }
        let sig = ev.sig();
        self.dropped += 1;
        Some(sig)
    }

    /// Re-enqueue a copy of a pending *message* (models network
    /// duplication). The copy gets a fresh seq. Returns the signature, or
    /// `None` if the seq is missing or not a Deliver.
    pub fn duplicate_event(&mut self, seq: u64) -> Option<String> {
        let (at, env) = {
            let ev = self.heap.iter().find(|ev| ev.seq == seq)?;
            match &ev.kind {
                EventKind::Deliver(env) => (ev.at, env.clone()),
                _ => return None,
            }
        };
        let sig = format!("d{}->{}:{:?}", env.from, env.to, env.msg.kind());
        self.push(at, EventKind::Deliver(env));
        Some(sig)
    }

    /// FNV-1a fingerprint of the explorable state: crash flags, every
    /// node's [`Node::state_repr`], the pending in-flight messages as
    /// per-channel *ordered sequences* (scheduled times excluded — the
    /// explorer quotients over timing), pending timers/controls as a
    /// sorted multiset, the network RNG state, and a caller-supplied
    /// `extra` (the invariant layer folds its own digest in so two paths
    /// with different violation-relevant history never merge).
    ///
    /// Per-channel ORDER matters: the explorer delivers each `(from,
    /// to)` channel in FIFO order, so two states whose channels hold the
    /// same messages in different orders have different future behavior
    /// and must not merge. Timers and controls are order-insensitive
    /// (controls fire in deterministic id order; timers are identified
    /// by content).
    ///
    /// Deliberately excluded: the clock and `tx_busy` (pure timing),
    /// `delivered`/`dropped`/`announces` (history, not behavior — the
    /// behaviorally relevant part of history is `extra`'s job).
    pub fn fingerprint(&self, extra: u64) -> u64 {
        let mut h = Fnv::new();
        h.write_u64(extra);
        for (i, c) in self.crashed.iter().enumerate() {
            h.write_u64(i as u64);
            h.write(&[*c as u8]);
        }
        for (i, slot) in self.nodes.iter().enumerate() {
            if let Some(node) = slot {
                h.write_u64(i as u64);
                match node.state_repr() {
                    Some(r) => h.write_str(&r),
                    // A node without a repr makes dedup unsound; fold in
                    // its role so at least distinct topologies differ.
                    None => h.write_str(node.role()),
                }
            }
        }
        let mut evs: Vec<&Event> = self.heap.iter().collect();
        evs.sort_by_key(|ev| ev.seq);
        let mut channels: BTreeMap<(NodeId, NodeId), Vec<String>> = BTreeMap::new();
        let mut others: Vec<String> = Vec::new();
        for ev in &evs {
            match &ev.kind {
                EventKind::Deliver(env) => channels
                    .entry((env.from, env.to))
                    .or_default()
                    .push(format!("{:?}", env.msg)),
                _ => others.push(ev.content_sig()),
            }
        }
        for ((from, to), msgs) in &channels {
            h.write_u64(*from as u64);
            h.write_u64(*to as u64);
            h.write_u64(msgs.len() as u64);
            for m in msgs {
                h.write_str(m);
            }
        }
        others.sort();
        h.write_u64(others.len() as u64);
        for s in &others {
            h.write_str(s);
        }
        // Partition state changes future behavior: two states that differ
        // only in which links are cut must not merge in the explorer's
        // dedup table (the `partition` event class, DESIGN.md §Nemesis).
        h.write_u64(self.cut_links.len() as u64);
        for (a, b) in &self.cut_links {
            h.write_u64(*a as u64);
            h.write_u64(*b as u64);
        }
        h.write_u64(self.net.cut_oneway.len() as u64);
        for (a, b) in &self.net.cut_oneway {
            h.write_u64(*a as u64);
            h.write_u64(*b as u64);
        }
        for w in self.rng.state() {
            h.write_u64(w);
        }
        h.finish()
    }

    /// Run until the queue is empty or `max_t` is reached. Returns the
    /// final clock.
    pub fn run_to_quiescence(&mut self, max_t: Time) -> Time {
        self.run_until(max_t);
        self.clock
    }

    /// Safety invariant from the §3/§5/§6 proofs: for every `(group,
    /// slot)`, at most one distinct value is ever announced chosen
    /// (across all rounds and all nodes). Slot numbers are per consensus
    /// group — independent shards legitimately reuse the same slot
    /// indices. Returns the violating slot if any.
    pub fn check_chosen_safety(&self) -> Result<(), String> {
        let mut by_slot: BTreeMap<(crate::GroupId, crate::Slot), &crate::msg::Value> =
            BTreeMap::new();
        for (t, node, a) in &self.announces {
            if let Announce::Chosen { group, slot, value, .. } = a {
                match by_slot.get(&(*group, *slot)) {
                    None => {
                        by_slot.insert((*group, *slot), value);
                    }
                    Some(prev) if *prev == value => {}
                    Some(prev) => {
                        return Err(format!(
                            "group {group} slot {slot}: two distinct values chosen: \
                             {prev:?} then {value:?} (second at t={t} by node {node})"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// The set of `(group, slot)` pairs announced chosen (distinct slots
    /// may repeat across announcers; used by tests).
    pub fn chosen_slots(&self) -> BTreeSet<(crate::GroupId, crate::Slot)> {
        self.announces
            .iter()
            .filter_map(|(_, _, a)| match a {
                Announce::Chosen { group, slot, .. } => Some((*group, *slot)),
                _ => None,
            })
            .collect()
    }
}

/// Corrupt one message at the codec boundary: encode, flip one random
/// byte, decode. `None` means the mutated frame no longer decodes (the
/// deliverer drops it); `Some` is a decodable mutation — the protocol
/// must tolerate it or an invariant will say why not.
fn corrupt_at_codec(msg: &Msg, rng: &mut Rng) -> Option<Msg> {
    use crate::codec::Wire;
    let mut bytes = msg.encode();
    if bytes.is_empty() {
        return None;
    }
    let idx = rng.gen_range(bytes.len() as u64) as usize;
    let bit = 1u8 << (rng.gen_range(8) as u8);
    bytes[idx] ^= bit;
    Msg::decode(&bytes).ok()
}

/// Convenience: a default single-AZ model with a given seed.
pub fn lan_sim(seed: u64) -> Sim {
    Sim::new(seed, NetworkModel::default())
}

/// A lossy network for adversarial tests.
pub fn lossy_sim(seed: u64, drop_prob: f64) -> Sim {
    let net = NetworkModel { drop_prob, ..NetworkModel::default() };
    Sim::new(seed, net)
}

/// Milliseconds helper for experiment scripts.
pub fn ms(x: u64) -> Time {
    x * MS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Msg;
    use crate::node::{Effects, Node, Timer};

    /// A node that echoes every message back and counts deliveries.
    struct Echo {
        pub count: u64,
        pub peer: NodeId,
        pub max: u64,
    }

    impl Node for Echo {
        fn on_start(&mut self, _now: Time, fx: &mut Effects) {
            fx.send(self.peer, Msg::StopA);
        }
        fn on_msg(&mut self, _now: Time, from: NodeId, _msg: Msg, fx: &mut Effects) {
            self.count += 1;
            if self.count < self.max {
                fx.send(from, Msg::StopA);
            }
        }
        fn on_timer(&mut self, _now: Time, _t: Timer, _fx: &mut Effects) {}
        fn role(&self) -> &'static str {
            "echo"
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn ping_pong_terminates_and_is_deterministic() {
        let run = |seed| {
            let mut sim = lan_sim(seed);
            sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 10 }));
            sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 10 }));
            sim.run_to_quiescence(crate::SEC);
            (sim.delivered, sim.now())
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b);
        assert!(a.0 >= 19);
    }

    #[test]
    fn crash_discards_traffic() {
        let mut sim = lan_sim(1);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 1000 }));
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 1000 }));
        sim.schedule(ms(1), |s| s.crash(1));
        sim.run_to_quiescence(ms(100));
        let n0 = sim.node_mut::<Echo>(0).unwrap().count;
        assert!(n0 < 1000, "crash should halt the ping-pong, got {n0}");
        assert!(sim.is_crashed(1));
    }

    #[test]
    fn link_cut_blocks_messages() {
        let mut sim = lan_sim(1);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 10_000 }));
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 10_000 }));
        sim.schedule(ms(1), |s| s.set_link(0, 1, false));
        sim.run_to_quiescence(ms(50));
        assert!(sim.dropped > 0 || sim.node_mut::<Echo>(0).unwrap().count < 10_000);
    }

    #[test]
    fn per_kind_delay_applies() {
        // A StopA (MmReconfig kind) with +10ms extra arrives later.
        let mut net = NetworkModel::default();
        net.jitter = 0;
        net.per_kind_extra.insert(MsgKind::MmReconfig, ms(10));
        let mut sim = Sim::new(3, net);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 1 }));
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.run_to_quiescence(crate::SEC);
        // Delivery time = base (0.1ms) + extra (10ms).
        assert!(sim.now() >= ms(10));
    }

    #[test]
    fn tx_overhead_serializes_egress() {
        // A node emitting N messages at once with tx_overhead T delivers
        // the last one ~N*T later than the first.
        let mut net = NetworkModel::default();
        net.jitter = 0;
        net.tx_overhead = ms(1);
        let mut sim = Sim::new(5, net);
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.add_node(2, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 0 }));
        // Node 0's on_start sends one message; queue 4 more by hand.
        sim.schedule(0, |s| {
            s.with_node::<Echo, _>(0, |_, _, fx| {
                for _ in 0..4 {
                    fx.send(2, Msg::StopA);
                }
            });
        });
        // Node 0's egress carries 5 messages (its on_start send + 4
        // scheduled) serialized at 1 ms each: departures at 1..=5 ms,
        // arrivals ~0.1 ms later. (Nodes 1 and 2 also send one startup
        // message each, arriving at ~0.1 ms: 7 deliveries total.)
        sim.run_until(ms(3));
        assert_eq!(sim.delivered, 4, "expected 2 startup + 2 serialized by 3 ms");
        sim.run_until(ms(10));
        assert_eq!(sim.delivered, 7);
    }

    #[test]
    fn zero_tx_overhead_matches_legacy_timing() {
        // Default model: a burst of messages all arrive ~base_delay later
        // (no egress queueing), preserving pre-existing behavior.
        let mut net = NetworkModel::default();
        net.jitter = 0;
        let mut sim = Sim::new(5, net);
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 0 }));
        sim.schedule(0, |s| {
            s.with_node::<Echo, _>(0, |_, _, fx| {
                for _ in 0..10 {
                    fx.send(1, Msg::StopA);
                }
            });
        });
        // All 12 messages (2 startup + 10 burst) land within base_delay:
        // no egress queueing by default.
        sim.run_until(ms(1));
        assert_eq!(sim.delivered, 12, "burst should land within base_delay");
    }

    #[test]
    fn control_closures_run_in_order() {
        let mut sim = lan_sim(1);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.schedule(ms(5), |s| s.crash(0));
        sim.schedule(ms(2), |s| assert!(!s.is_crashed(0)));
        sim.run_to_quiescence(ms(10));
        assert!(sim.is_crashed(0));
    }

    #[test]
    fn oneway_cut_blocks_only_one_direction() {
        let mut sim = lan_sim(2);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 10_000 }));
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 10_000 }));
        // Cut 0 → 1 only: node 1 still reaches node 0, so node 0 keeps
        // receiving while node 1 hears nothing after the cut.
        sim.schedule(ms(1), |s| s.set_link_oneway(0, 1, false));
        sim.run_to_quiescence(ms(50));
        assert!(sim.dropped > 0, "directed cut should drop 0->1 traffic");
        assert!(!sim.link_open(0, 1));
        assert!(sim.link_open(1, 0));
        sim.set_link_oneway(0, 1, true);
        assert!(sim.link_open(0, 1));
    }

    #[test]
    fn gray_slow_node_delays_its_links() {
        // Same topology, same seed: the slowed run's single round trip
        // takes ~20x the nominal link delay.
        let run = |pct| {
            let mut net = NetworkModel::default();
            net.jitter = 0;
            let mut sim = Sim::new(9, net);
            sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 2 }));
            sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 2 }));
            sim.set_node_slow(1, pct);
            sim.run_to_quiescence(crate::SEC);
            sim.now()
        };
        let nominal = run(100);
        let slowed = run(2000);
        assert!(
            slowed >= nominal * 10,
            "20x gray-slow should dominate the run: {nominal} vs {slowed}"
        );
    }

    #[test]
    fn clock_skew_shifts_only_observed_time() {
        let mut sim = lan_sim(4);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.run_until(ms(10));
        assert_eq!(sim.local_now(0), sim.now());
        sim.set_clock_skew(0, ms(3) as i64);
        assert_eq!(sim.local_now(0), sim.now() + ms(3));
        sim.set_clock_skew(0, -(ms(2) as i64));
        assert_eq!(sim.local_now(0), sim.now() - ms(2));
        // Another node's clock is untouched.
        assert_eq!(sim.local_now(1), sim.now());
        sim.set_clock_skew(0, 0);
        assert_eq!(sim.local_now(0), sim.now());
    }

    #[test]
    fn duplication_redelivers_frames() {
        let mut net = NetworkModel::default();
        net.jitter = 0;
        net.dup_prob = 1.0;
        let mut sim = Sim::new(6, net);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 1 }));
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 0 }));
        sim.run_to_quiescence(crate::SEC);
        // Every send lands twice.
        assert_eq!(sim.delivered % 2, 0);
        assert!(sim.delivered >= 4);
    }

    #[test]
    fn corruption_drops_or_mutates_but_keeps_running() {
        let mut net = NetworkModel::default();
        net.jitter = 0;
        net.corrupt_prob = 0.5;
        let mut sim = Sim::new(8, net);
        sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 200 }));
        sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 200 }));
        sim.run_to_quiescence(crate::SEC);
        // Undecodable mutations count as drops; the run still terminates.
        assert!(sim.delivered > 0);
    }

    #[test]
    fn disabled_nemesis_preserves_rng_stream() {
        // The determinism contract behind every committed baseline: a sim
        // with all nemesis knobs at their defaults fingerprints exactly
        // like one built before the knobs existed (same RNG draw order).
        let fp = |tweak: bool| {
            let mut sim = lossy_sim(11, 0.05);
            sim.add_node(0, Box::new(Echo { count: 0, peer: 1, max: 50 }));
            sim.add_node(1, Box::new(Echo { count: 0, peer: 0, max: 50 }));
            if tweak {
                // Toggling a knob on and back off mid-run must also
                // restore the stream (maps empty again).
                sim.set_node_slow(0, 2000);
                sim.set_node_slow(0, 100);
                sim.set_clock_skew(1, 500);
                sim.set_clock_skew(1, 0);
            }
            sim.run_to_quiescence(ms(100));
            sim.fingerprint(0)
        };
        assert_eq!(fp(false), fp(true));
    }

    #[test]
    fn wan_model_targets_phase1b() {
        let net = NetworkModel::default().with_wan_phase1(ms(250));
        assert_eq!(net.per_kind_extra[&MsgKind::Phase1B], ms(250));
        assert_eq!(net.per_kind_extra[&MsgKind::MatchB], ms(250));
    }
}
