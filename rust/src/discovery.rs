//! An eventually consistent discovery service (§2.1).
//!
//! The paper assumes "a discovery service that nodes can use to find each
//! other, but [does] not require that this service be strongly consistent.
//! A node can safely communicate with outdated nodes. A system like DNS
//! would suffice." This registry models exactly that: a last-writer-wins
//! map from node id to (role, address, incarnation), with stale reads
//! explicitly permitted. The TCP runtime uses it to resolve peers; the
//! simulator doesn't need it (ids are addresses) but the tests exercise
//! the staleness contract.

use crate::NodeId;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// A registered node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Registration {
    pub id: NodeId,
    pub role: String,
    pub addr: String,
    /// Monotonic incarnation: a restarted/replaced node re-registers with a
    /// higher incarnation; lower-incarnation writes are ignored (LWW).
    pub incarnation: u64,
}

/// A shared, eventually consistent registry. Cheap to clone (Arc).
#[derive(Clone, Default)]
pub struct Discovery {
    inner: Arc<RwLock<BTreeMap<NodeId, Registration>>>,
}

impl Discovery {
    pub fn new() -> Discovery {
        Discovery::default()
    }

    /// Register (or refresh) a node. Returns false if a newer incarnation
    /// already exists (the write is ignored).
    pub fn register(&self, reg: Registration) -> bool {
        let mut map = self.inner.write().unwrap();
        match map.get(&reg.id) {
            Some(cur) if cur.incarnation > reg.incarnation => false,
            _ => {
                map.insert(reg.id, reg);
                true
            }
        }
    }

    /// Remove a node (best-effort; readers may still see it briefly in a
    /// real deployment — callers must tolerate staleness).
    pub fn deregister(&self, id: NodeId) {
        self.inner.write().unwrap().remove(&id);
    }

    /// Look up one node.
    pub fn lookup(&self, id: NodeId) -> Option<Registration> {
        self.inner.read().unwrap().get(&id).cloned()
    }

    /// All nodes currently registered under `role`.
    pub fn by_role(&self, role: &str) -> Vec<Registration> {
        self.inner
            .read()
            .unwrap()
            .values()
            .filter(|r| r.role == role)
            .cloned()
            .collect()
    }

    /// Snapshot of the whole registry.
    pub fn snapshot(&self) -> BTreeMap<NodeId, Registration> {
        self.inner.read().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg(id: NodeId, role: &str, inc: u64) -> Registration {
        Registration { id, role: role.into(), addr: format!("127.0.0.1:{}", 7000 + id), incarnation: inc }
    }

    #[test]
    fn register_and_lookup() {
        let d = Discovery::new();
        assert!(d.register(reg(1, "acceptor", 0)));
        assert_eq!(d.lookup(1).unwrap().role, "acceptor");
        assert!(d.lookup(2).is_none());
    }

    #[test]
    fn incarnation_lww() {
        let d = Discovery::new();
        d.register(reg(1, "acceptor", 5));
        // Older incarnation ignored.
        assert!(!d.register(reg(1, "acceptor", 3)));
        assert_eq!(d.lookup(1).unwrap().incarnation, 5);
        // Newer wins.
        assert!(d.register(reg(1, "acceptor", 6)));
        assert_eq!(d.lookup(1).unwrap().incarnation, 6);
    }

    #[test]
    fn by_role() {
        let d = Discovery::new();
        d.register(reg(1, "acceptor", 0));
        d.register(reg(2, "acceptor", 0));
        d.register(reg(3, "matchmaker", 0));
        assert_eq!(d.by_role("acceptor").len(), 2);
        assert_eq!(d.by_role("matchmaker").len(), 1);
        assert_eq!(d.by_role("replica").len(), 0);
    }

    #[test]
    fn deregister() {
        let d = Discovery::new();
        d.register(reg(1, "x", 0));
        d.deregister(1);
        assert!(d.lookup(1).is_none());
    }

    #[test]
    fn shared_between_clones() {
        let d = Discovery::new();
        let d2 = d.clone();
        d.register(reg(9, "replica", 1));
        assert_eq!(d2.lookup(9).unwrap().id, 9);
    }
}
