//! Configurations (quorum systems over acceptor sets) and deployment
//! descriptions.
//!
//! A [`Configuration`] is the paper's `C = (A; P1; P2)`: the unit of
//! reconfiguration. A [`DeploymentConfig`] describes a whole cluster — which
//! node ids play which role, the fault-tolerance parameter `f`, protocol
//! option flags — and is what the CLI launcher and the simulator harness
//! both consume (TOML on disk for real deployments).

use crate::quorum::QuorumSpec;
use crate::workload::{PayloadSpec, WorkloadMode, WorkloadSpec};
use crate::{NodeId, Time, MS, US};
use std::collections::BTreeSet;

/// A configuration of acceptors: the paper's `C = (A; P1; P2)`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Configuration {
    /// Monotonic identifier, for logging/metrics only (safety never depends
    /// on it — rounds identify configurations in the protocol).
    pub id: u64,
    /// Ordered acceptor list `A`.
    pub acceptors: Vec<NodeId>,
    /// The quorum system `(P1, P2)`.
    pub quorum: QuorumSpec,
}

impl Configuration {
    /// A majority-quorum configuration over `acceptors`.
    pub fn majority(id: u64, acceptors: Vec<NodeId>) -> Configuration {
        Configuration {
            id,
            acceptors,
            quorum: QuorumSpec::Majority,
        }
    }

    /// Validate acceptor-set well-formedness and the quorum system:
    /// Flexible specs must satisfy `p1 + p2 > |A|` (the Flexible-Paxos
    /// intersection property), Explicit specs must index inside the
    /// acceptor list and pairwise intersect. Errors are descriptive so a
    /// bad deployment config fails loudly at load time instead of
    /// silently treating quorums as unsatisfiable.
    pub fn validate(&self) -> Result<(), String> {
        if self.acceptors.is_empty() {
            return Err("configuration has no acceptors".into());
        }
        let uniq: BTreeSet<_> = self.acceptors.iter().collect();
        if uniq.len() != self.acceptors.len() {
            return Err("duplicate acceptor in configuration".into());
        }
        if let Err(e) = self.quorum.validate(self.acceptors.len()) {
            return Err(format!(
                "configuration {} has an invalid quorum system: {e}",
                self.id
            ));
        }
        Ok(())
    }

    /// Is `acked` a Phase 1 quorum of this configuration?
    pub fn is_p1_quorum(&self, acked: &BTreeSet<NodeId>) -> bool {
        self.quorum.is_p1_quorum(&self.acceptors, acked)
    }

    /// Is `acked` a Phase 2 quorum of this configuration?
    pub fn is_p2_quorum(&self, acked: &BTreeSet<NodeId>) -> bool {
        self.quorum.is_p2_quorum(&self.acceptors, acked)
    }
}

/// Snapshotting / log-truncation policy for the state-retention
/// subsystem. Replicas snapshot their state machine every `interval` of
/// virtual time, truncate the chosen log below the snapshot watermark
/// (keeping a retained tail of `tail` entries for incremental catch-up),
/// and serve snapshot-plus-tail catch-up to lagging or freshly joined
/// replicas. The leader mirrors the policy: it truncates its own log and
/// command→slot map at the f+1-durable watermark minus `tail`, and
/// continuously propagates that watermark to the acceptors
/// ([`crate::msg::Msg::PrefixPersisted`]) so voted state below it is
/// dropped in steady state, not only at reconfiguration barriers.
///
/// Disabled by default: the paper's experiments retain the full log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotSpec {
    /// Whether replicas snapshot and truncate at all.
    pub enabled: bool,
    /// Virtual time between snapshot ticks.
    pub interval: Time,
    /// Chosen log entries retained below the snapshot watermark. The
    /// tail is also the *retry horizon*: a client retry arriving more
    /// than `tail` slots after its command executed is treated as
    /// settled (no re-reply — the result cache was retired with the
    /// log). Clamped to at least [`crate::workload::MAX_IN_FLIGHT`] by
    /// the constructors; on lossy networks size it to cover at least the
    /// client resend timeout times the expected slot rate.
    pub tail: u64,
}

impl Default for SnapshotSpec {
    fn default() -> Self {
        SnapshotSpec { enabled: false, interval: 100 * MS, tail: 1024 }
    }
}

impl SnapshotSpec {
    /// An enabled policy: snapshot every `interval` (clamped to ≥ 1 µs so
    /// the config text format, which serializes microseconds, round-trips),
    /// retain `tail` chosen entries below the watermark.
    pub fn every(interval: Time, tail: u64) -> SnapshotSpec {
        SnapshotSpec {
            enabled: true,
            interval: interval.max(US),
            tail: tail.max(crate::workload::MAX_IN_FLIGHT as u64),
        }
    }
}

/// Read-lease policy for linearizable reads served by replicas
/// (DESIGN.md §Reads). While enabled, the leader keeps a **leadership
/// lease** alive by round-fenced renewals acknowledged by a P2 quorum of
/// the active configuration, and forwards it to the replicas as
/// [`crate::msg::Msg::LeaseGrant`]s carrying the chosen watermark.
/// A replica holding an active grant serves a read without contacting
/// the leader: it waits for the first grant issued *after* the read
/// arrived (grants are pushed continuously, so this costs no extra
/// messages), then answers once its applied prefix covers the grant's
/// watermark. Lapsed leases fall back to a one-message ReadIndex.
///
/// Fencing: any new round's Phase 1 quorum intersects every P2 quorum
/// of the prior configurations, so a deposed leader's renewals are
/// nacked from the new round's Phase 1 onward; the new leader
/// additionally waits `duration + drift` after completing Phase 1
/// before choosing commands, which outlives every grant the old leader
/// could still have issued. Reconfigurations by the *same* leader keep
/// the same watermark lineage and need no fence; matchmaker migrations
/// conservatively pause renewals so outstanding leases lapse.
///
/// Disabled by default: the paper routes every operation through
/// Phase 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaseSpec {
    /// Whether the leader grants read leases at all.
    pub enabled: bool,
    /// Lease validity measured from the renewal's *send* time. Also the
    /// length of the post-election fence.
    pub duration: Time,
    /// Renewal cadence (must be well under `duration` or the lease
    /// flaps between renewals).
    pub refresh: Time,
    /// Conservative clock-drift bound: subtracted from the validity the
    /// leader advertises to replicas and added to the new-leader fence.
    /// The simulator's clock is global, so this models the real-world
    /// bound rather than compensating for an actual skew.
    pub drift: Time,
}

impl Default for LeaseSpec {
    fn default() -> Self {
        LeaseSpec { enabled: false, duration: 50 * MS, refresh: 2 * MS, drift: 100 * US }
    }
}

impl LeaseSpec {
    /// An enabled policy with the given validity window. Refresh is
    /// clamped to at most `duration / 4` (a lease that expires between
    /// renewals serves no reads), and everything is kept ≥ 1 µs so the
    /// config text format (microseconds) round-trips.
    pub fn every(duration: Time, refresh: Time, drift: Time) -> LeaseSpec {
        let duration = duration.max(4 * US);
        LeaseSpec {
            enabled: true,
            duration,
            refresh: refresh.clamp(US, duration / 4),
            drift: drift.max(US),
        }
    }

    /// Minimum gap between watermark-advance grant pushes (throttles
    /// the per-chosen-slot broadcast; see the leader's `push_grant`).
    pub fn push_gap(&self) -> Time {
        (self.refresh / 8).max(50 * US)
    }
}

/// Leader-side overload-control policy (DESIGN.md §Overload; ROADMAP
/// X9). While enabled, the leader:
///
/// * **bounds its proposal inbox** — when the number of admitted-but-
///   unchosen commands (in-flight proposals plus the batch buffer and
///   the stalled queue) reaches `inbox`, further client requests are
///   shed with an explicit [`crate::msg::Msg::Busy`] instead of being
///   queued. A shed request never touches the per-client FIFO
///   sequencer (a Busy is a drop, not an ack), so the client retries
///   it later without risking reordering or duplicate execution.
/// * **adapts its batching** — a windowed p99 estimate of
///   proposal→chosen latency steers the *effective*
///   `batch_size`/`batch_delay` between the configured `batch_size`
///   (the floor is 1, the ceiling the configured value) to hold the
///   `target_p99_us` SLO: over target, batch harder (fewer slots per
///   second, more commands per quorum round trip); under target, relax
///   toward low-latency small batches.
///
/// Clients honor the pushback per `shed`: `true` drops the request on
/// Busy (counted in the client's `abandoned` counter — load shedding);
/// `false` schedules a delayed retry after the Busy's `retry_after_us`.
///
/// Disabled by default: the paper's experiments (and the saturation
/// baselines in the harness tests) run with an unbounded inbox.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionSpec {
    /// Whether the leader bounds its inbox and adapts batching at all.
    pub enabled: bool,
    /// Proposal-inbox bound: admitted-but-unchosen commands the leader
    /// will hold before shedding with `Busy`.
    pub inbox: usize,
    /// SLO target for the windowed p99 of proposal→chosen latency, in
    /// microseconds. Drives the adaptive batch tuner and the
    /// `retry_after_us` hint carried in `Busy`.
    pub target_p99_us: u64,
    /// Client policy on Busy: shed (drop, count abandoned) when true,
    /// delayed retry after `retry_after_us` when false.
    pub shed: bool,
}

impl Default for AdmissionSpec {
    fn default() -> Self {
        AdmissionSpec { enabled: false, inbox: 1024, target_p99_us: 20_000, shed: false }
    }
}

impl AdmissionSpec {
    /// An enabled policy: inbox bound `inbox` (clamped to ≥ 1), p99
    /// target `target_p99_us` µs (clamped to ≥ 1), client shedding per
    /// `shed`.
    pub fn slo(inbox: usize, target_p99_us: u64, shed: bool) -> AdmissionSpec {
        AdmissionSpec {
            enabled: true,
            inbox: inbox.max(1),
            target_p99_us: target_p99_us.max(1),
            shed,
        }
    }

    /// The retry-after hint a `Busy` carries: one SLO target's worth of
    /// backoff — long enough for the inbox to drain at the target
    /// latency, short enough that a recovered leader sees the retry
    /// promptly.
    pub fn retry_after(&self) -> Time {
        self.target_p99_us.max(1) * US
    }
}

/// Durable-storage policy for the TCP runtime (DESIGN.md §Durability).
/// When enabled — and `repro run` is given a `--data-dir` — every role
/// opens a [`crate::storage::wal::WalStorage`] under
/// `<data-dir>/<role>-<id>` and persists its critical state (acceptor
/// promises/votes, matchmaker logs, leader epochs, replica chosen
/// entries + snapshots) *before* acknowledging, then replays it on
/// restart. The simulator and model checker ignore this spec entirely:
/// they attach [`crate::storage::MemStorage`] (or nothing) directly in
/// tests, keeping the sim hot path allocation-identical to a
/// storage-free build.
///
/// Disabled by default: the paper's experiments measure the in-memory
/// protocol; durability is the X10 extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StorageSpec {
    /// Whether roles attach WALs at all (also requires `--data-dir`).
    pub enabled: bool,
    /// fsync every append before the role acks. This is what makes
    /// `kill -9` recovery sound — a promise/vote that reached a quorum
    /// member's ack must survive its crash, or the P1∩P2 intersection
    /// argument silently loses votes. Turning it off is for benchmarks
    /// only (the micro-bench measures the gap).
    pub fsync: bool,
    /// WAL segment rotation threshold in bytes.
    pub segment_bytes: u64,
    /// Every `full_every`'th replica snapshot is stored in full; the
    /// ones between are byte-deltas against the last full.
    pub full_every: u32,
}

impl Default for StorageSpec {
    fn default() -> Self {
        let d = crate::storage::wal::WalOptions::default();
        StorageSpec {
            enabled: false,
            fsync: d.fsync,
            segment_bytes: d.segment_bytes,
            full_every: d.full_every,
        }
    }
}

impl StorageSpec {
    /// An enabled policy with the safe defaults (fsync on). Segment
    /// size is clamped to ≥ 4 KiB so rotation stays coarser than
    /// individual records.
    pub fn wal() -> StorageSpec {
        StorageSpec { enabled: true, ..StorageSpec::default() }
    }

    /// The [`crate::storage::wal::WalOptions`] this spec describes.
    pub fn wal_options(&self) -> crate::storage::wal::WalOptions {
        crate::storage::wal::WalOptions {
            fsync: self.fsync,
            segment_bytes: self.segment_bytes.max(4 << 10),
            full_every: self.full_every.max(1),
        }
    }
}

/// Protocol optimization flags (§3.4, §8.2 ablation). All on by default;
/// the ablation experiment (Figure 17) toggles subsets off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptFlags {
    /// Optimization 1: run the Matchmaking phase before hearing from
    /// clients; during a reconfiguration, keep processing commands in the
    /// old round while matchmaking for the new one.
    pub proactive_matchmaking: bool,
    /// Optimization 2: skip Phase 1 for empty log suffixes when advancing
    /// `(r, id, s) → (r, id, s+1)`.
    pub phase1_bypass: bool,
    /// Optimization 3: garbage-collect retired configurations (§5).
    pub garbage_collection: bool,
    /// Optimization 4: prune configurations below the largest vote round
    /// seen in Phase 1.
    pub round_pruning: bool,
    /// Thriftiness (§8.1): send Phase2A to a sampled P2 quorum rather than
    /// all acceptors.
    pub thrifty: bool,
    /// Optimization 5: on a leader change, run the Matchmaking phase and
    /// Phase 1 concurrently against the leader's configuration guess,
    /// saving one round trip when the guess matches H_i (the common case
    /// when leaders rarely change the acceptors during an election).
    pub concurrent_phase1: bool,
    /// Phase 2 batching: maximum number of client commands the leader
    /// packs into one slot (`Value::Batch`). `1` disables batching (every
    /// command gets its own slot, the paper's §8 configuration). One
    /// quorum round trip then chooses up to `batch_size` commands, which
    /// is the dominant throughput lever under heavy load.
    pub batch_size: usize,
    /// Maximum time a partially filled batch may wait for more commands
    /// before the leader flushes it (bounds added latency at low load).
    pub batch_delay: Time,
    /// Snapshotting + log truncation policy (off by default; see
    /// [`SnapshotSpec`]).
    pub snapshot: SnapshotSpec,
    /// Read-lease policy for replica-served linearizable reads (off by
    /// default; see [`LeaseSpec`]).
    pub leases: LeaseSpec,
    /// Durable-storage policy for the TCP runtime (off by default; see
    /// [`StorageSpec`]).
    pub storage: StorageSpec,
    /// Leader-side overload control: bounded proposal inbox with `Busy`
    /// pushback plus latency-targeted adaptive batching (off by
    /// default; see [`AdmissionSpec`]).
    pub admission: AdmissionSpec,
}

impl Default for OptFlags {
    fn default() -> Self {
        OptFlags {
            proactive_matchmaking: true,
            phase1_bypass: true,
            garbage_collection: true,
            round_pruning: true,
            thrifty: true,
            concurrent_phase1: false,
            batch_size: 1,
            batch_delay: MS,
            snapshot: SnapshotSpec::default(),
            leases: LeaseSpec::default(),
            storage: StorageSpec::default(),
            admission: AdmissionSpec::default(),
        }
    }
}

impl OptFlags {
    /// No optimizations: the stop-the-world baseline of the §8.2 ablation.
    pub fn none() -> OptFlags {
        OptFlags {
            proactive_matchmaking: false,
            phase1_bypass: false,
            garbage_collection: false,
            round_pruning: false,
            thrifty: false,
            concurrent_phase1: false,
            batch_size: 1,
            batch_delay: MS,
            snapshot: SnapshotSpec::default(),
            leases: LeaseSpec::default(),
            storage: StorageSpec::default(),
            admission: AdmissionSpec::default(),
        }
    }

    /// Enable Phase 2 batching with the given knobs (builder-style).
    pub fn with_batching(mut self, batch_size: usize, batch_delay: Time) -> OptFlags {
        self.batch_size = batch_size.max(1);
        self.batch_delay = batch_delay;
        self
    }

    /// Enable snapshotting + log truncation (builder-style).
    pub fn with_snapshots(mut self, spec: SnapshotSpec) -> OptFlags {
        self.snapshot = spec;
        self
    }

    /// Enable read leases (builder-style).
    pub fn with_leases(mut self, spec: LeaseSpec) -> OptFlags {
        self.leases = spec;
        self
    }

    /// Enable durable storage for the TCP runtime (builder-style).
    pub fn with_storage(mut self, spec: StorageSpec) -> OptFlags {
        self.storage = spec;
        self
    }

    /// Enable leader-side overload control (builder-style).
    pub fn with_admission(mut self, spec: AdmissionSpec) -> OptFlags {
        self.admission = spec;
        self
    }
}

/// Role assignment for a deployment: which node ids are proposers,
/// acceptors, matchmakers, and replicas. Clients get ids above all of
/// these. Mirrors the paper's deployment: `f+1` proposers, a pool of
/// acceptors (`2·(2f+1)` for the reconfiguration experiments), `2f+1`
/// matchmakers (pool of `2·(2f+1)` for §8.4), and `2f+1` replicas
/// (§5.3 requires `2f+1`, not `f+1`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterLayout {
    /// Fault-tolerance parameter.
    pub f: usize,
    /// Proposer ids (`>= f+1`; every proposer runs the Leader role).
    pub proposers: Vec<NodeId>,
    /// Pool of acceptors that configurations may draw from.
    pub acceptor_pool: Vec<NodeId>,
    /// Pool of matchmakers; the first `2f+1` form the initial active set.
    pub matchmaker_pool: Vec<NodeId>,
    /// Replica ids (`>= f+1`; the paper deploys `2f+1`).
    pub replicas: Vec<NodeId>,
    /// Workload client ids.
    pub clients: Vec<NodeId>,
}

impl ClusterLayout {
    /// Standard paper layout: `f+1` proposers, `pool_factor·(2f+1)`
    /// acceptors, `pool_factor·(2f+1)` matchmakers, `2f+1` replicas and
    /// `n_clients` clients, with dense ids assigned in role order.
    pub fn standard(f: usize, pool_factor: usize, n_clients: usize) -> ClusterLayout {
        let mut next: NodeId = 0;
        let mut take = |n: usize| -> Vec<NodeId> {
            let ids: Vec<NodeId> = (next..next + n as NodeId).collect();
            next += n as NodeId;
            ids
        };
        ClusterLayout {
            f,
            proposers: take(f + 1),
            acceptor_pool: take(pool_factor * (2 * f + 1)),
            matchmaker_pool: take(pool_factor * (2 * f + 1)),
            replicas: take(2 * f + 1),
            clients: take(n_clients),
        }
    }

    /// The initially active matchmakers (first `2f+1` of the pool).
    pub fn initial_matchmakers(&self) -> Vec<NodeId> {
        self.matchmaker_pool[..(2 * self.f + 1).min(self.matchmaker_pool.len())].to_vec()
    }

    /// Validate that this layout can be partitioned into `shards`
    /// independent consensus groups sharing the matchmaker pool: every
    /// per-group role list must divide evenly and each group's share
    /// must still satisfy the single-group minimums (`≥ f+1` proposers
    /// and replicas, `≥ 2f+1` acceptors). Errors are descriptive, in the
    /// style of [`crate::quorum::QuorumSpec::validate`], so a bad
    /// `shards =` line fails loudly at load time.
    pub fn validate_shards(&self, shards: usize) -> Result<(), String> {
        if shards == 0 {
            return Err("shards must be >= 1 (got 0; use 1 for an unsharded deployment)".into());
        }
        let check = |name: &str, len: usize, per_group_min: usize| -> Result<(), String> {
            if len % shards != 0 {
                return Err(format!(
                    "{name} count {len} does not divide evenly into {shards} shard(s) \
                     — each group needs its own {name} set"
                ));
            }
            let per = len / shards;
            if per < per_group_min {
                return Err(format!(
                    "{name} count {len} over {shards} shard(s) leaves {per} per group; \
                     each group needs >= {per_group_min}"
                ));
            }
            Ok(())
        };
        check("proposer", self.proposers.len(), self.f + 1)?;
        check("acceptor", self.acceptor_pool.len(), 2 * self.f + 1)?;
        check("replica", self.replicas.len(), self.f + 1)?;
        // The matchmaker pool is shared, not partitioned: the
        // single-group minimum (checked by `validate`) is all that is
        // required regardless of shard count (§6).
        Ok(())
    }

    /// Partition the layout into `shards` groups: contiguous equal
    /// slices of the proposer/acceptor/replica lists, with the
    /// matchmaker pool shared by all groups. Group `g` is the `g`'th
    /// slice of each list.
    pub fn partition(&self, shards: usize) -> Result<Vec<GroupLayout>, String> {
        self.validate_shards(shards)?;
        let slice = |ids: &[NodeId], g: usize| -> Vec<NodeId> {
            let per = ids.len() / shards;
            ids[g * per..(g + 1) * per].to_vec()
        };
        Ok((0..shards)
            .map(|g| GroupLayout {
                proposers: slice(&self.proposers, g),
                acceptor_pool: slice(&self.acceptor_pool, g),
                replicas: slice(&self.replicas, g),
            })
            .collect())
    }

    /// The initial acceptor configuration (first `2f+1` of the pool,
    /// majority quorums).
    pub fn initial_config(&self) -> Configuration {
        Configuration::majority(
            0,
            self.acceptor_pool[..(2 * self.f + 1).min(self.acceptor_pool.len())].to_vec(),
        )
    }

    /// Total number of node ids in the layout (nodes are dense `0..total`).
    pub fn total_nodes(&self) -> usize {
        self.proposers.len()
            + self.acceptor_pool.len()
            + self.matchmaker_pool.len()
            + self.replicas.len()
            + self.clients.len()
    }

    /// Validate role counts (`>= f+1` proposers/replicas, `>= 2f+1`
    /// acceptors/matchmakers) and that no node id serves two roles.
    pub fn validate(&self) -> Result<(), String> {
        if self.proposers.len() < self.f + 1 {
            return Err(format!("need >= f+1 = {} proposers", self.f + 1));
        }
        if self.acceptor_pool.len() < 2 * self.f + 1 {
            return Err(format!("need >= 2f+1 = {} acceptors", 2 * self.f + 1));
        }
        if self.matchmaker_pool.len() < 2 * self.f + 1 {
            return Err(format!("need >= 2f+1 = {} matchmakers", 2 * self.f + 1));
        }
        if self.replicas.len() < self.f + 1 {
            return Err(format!("need >= f+1 = {} replicas", self.f + 1));
        }
        let mut all: Vec<NodeId> = Vec::new();
        all.extend(&self.proposers);
        all.extend(&self.acceptor_pool);
        all.extend(&self.matchmaker_pool);
        all.extend(&self.replicas);
        all.extend(&self.clients);
        let uniq: BTreeSet<_> = all.iter().collect();
        if uniq.len() != all.len() {
            return Err("node id assigned to two roles".into());
        }
        Ok(())
    }
}

/// One consensus group's role slice of a sharded deployment (see
/// [`ClusterLayout::partition`]). The matchmaker pool is deliberately
/// absent: it is shared across all groups (§6).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupLayout {
    /// The group's proposers (each runs the Leader role for this group).
    pub proposers: Vec<NodeId>,
    /// The group's private acceptor pool.
    pub acceptor_pool: Vec<NodeId>,
    /// The group's replicas.
    pub replicas: Vec<NodeId>,
}

/// A full deployment description: layout + protocol flags + network
/// addresses (for the TCP runtime). Serialized as a simple `key = value`
/// text format for `repro run` (the build is dependency-free; no TOML
/// crate — the format below is a TOML subset).
#[derive(Clone, Debug)]
pub struct DeploymentConfig {
    /// Which node ids play which role.
    pub layout: ClusterLayout,
    /// Number of independent consensus groups the proposer/acceptor/
    /// replica lists are partitioned into (`shards =` line). All groups
    /// share the matchmaker pool. `1` (the default) is the classic
    /// unsharded deployment. Validated by
    /// [`ClusterLayout::validate_shards`] at load time.
    pub shards: usize,
    /// Protocol optimization flags + batching/snapshot knobs.
    pub opts: OptFlags,
    /// node id → "host:port" for the TCP runtime. Unused by the simulator.
    pub addrs: std::collections::BTreeMap<NodeId, String>,
    /// Which state machine replicas run: "noop", "kv", "register",
    /// "counter", or "tensor" (XLA-backed; requires `artifacts/`).
    pub state_machine: String,
    /// What the deployment's clients do (`workload =` line; the
    /// `repro run --role client` flags override it). Only fixed payloads
    /// are representable in the text format.
    pub workload: WorkloadSpec,
    /// Scripted fault schedule for the TCP runtime (`nemesis =` line,
    /// [`crate::nemesis::NemesisPlan`] text form; `repro run --nemesis`
    /// overrides it). `None` injects nothing.
    pub nemesis: Option<crate::nemesis::NemesisPlan>,
}

fn default_sm() -> String {
    "noop".to_string()
}

fn fmt_ids(ids: &[NodeId]) -> String {
    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
}

fn parse_ids(s: &str) -> Result<Vec<NodeId>, String> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|x| x.trim().parse::<NodeId>().map_err(|e| format!("bad id {x:?}: {e}")))
        .collect()
}

impl DeploymentConfig {
    /// The paper's standard deployment shape ([`ClusterLayout::standard`]
    /// with a pool factor of 2) with default options and workload.
    pub fn standard(f: usize, n_clients: usize) -> DeploymentConfig {
        DeploymentConfig {
            layout: ClusterLayout::standard(f, 2, n_clients),
            shards: 1,
            opts: OptFlags::default(),
            addrs: Default::default(),
            state_machine: default_sm(),
            workload: WorkloadSpec::closed_loop(),
            nemesis: None,
        }
    }

    /// Serialize to the cluster config text format.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let l = &self.layout;
        out.push_str("# matchmaker-paxos cluster config\n");
        out.push_str(&format!("f = {}\n", l.f));
        out.push_str(&format!("proposers = {}\n", fmt_ids(&l.proposers)));
        out.push_str(&format!("acceptor_pool = {}\n", fmt_ids(&l.acceptor_pool)));
        out.push_str(&format!("matchmaker_pool = {}\n", fmt_ids(&l.matchmaker_pool)));
        out.push_str(&format!("replicas = {}\n", fmt_ids(&l.replicas)));
        out.push_str(&format!("clients = {}\n", fmt_ids(&l.clients)));
        if self.shards != 1 {
            out.push_str(&format!("shards = {}\n", self.shards));
        }
        out.push_str(&format!("state_machine = {}\n", self.state_machine));
        let o = &self.opts;
        out.push_str(&format!(
            "opts = proactive:{},bypass:{},gc:{},pruning:{},thrifty:{},concurrent_p1:{}\n",
            o.proactive_matchmaking, o.phase1_bypass, o.garbage_collection, o.round_pruning, o.thrifty, o.concurrent_phase1
        ));
        out.push_str(&format!(
            "batch = size:{},delay_us:{}\n",
            o.batch_size,
            o.batch_delay / US
        ));
        if o.snapshot.enabled {
            out.push_str(&format!(
                "snapshot = interval_us:{},tail:{}\n",
                o.snapshot.interval / US,
                o.snapshot.tail
            ));
        }
        if o.leases.enabled {
            out.push_str(&format!(
                "leases = duration_us:{},refresh_us:{},drift_us:{}\n",
                o.leases.duration / US,
                o.leases.refresh / US,
                o.leases.drift / US
            ));
        }
        if o.storage.enabled {
            out.push_str(&format!(
                "storage = fsync:{},segment_kb:{},full_every:{}\n",
                o.storage.fsync,
                o.storage.segment_bytes / 1024,
                o.storage.full_every
            ));
        }
        if o.admission.enabled {
            out.push_str(&format!(
                "admission = inbox:{},target_p99_us:{},shed:{}\n",
                o.admission.inbox, o.admission.target_p99_us, o.admission.shed
            ));
        }
        let w = &self.workload;
        let mut wl = String::from("workload = ");
        match w.mode {
            WorkloadMode::ClosedLoop { window } => {
                wl.push_str(&format!("mode:closed,window:{window}"));
            }
            WorkloadMode::OpenLoop { interval, poisson, max_in_flight, queue_cap } => {
                wl.push_str(&format!(
                    "mode:open,interval_ns:{interval},poisson:{poisson},inflight:{max_in_flight}"
                ));
                if queue_cap != crate::workload::DEFAULT_QUEUE_CAP {
                    wl.push_str(&format!(",queue_cap:{queue_cap}"));
                }
            }
        }
        let payload_bytes = match &w.payload {
            PayloadSpec::Fixed(b) => b.len(),
            PayloadSpec::PerClient(_) => 1,
        };
        wl.push_str(&format!(
            ",payload_bytes:{payload_bytes},resend_ms:{}",
            w.resend_after / MS
        ));
        if w.read_fraction > 0.0 {
            wl.push_str(&format!(",read_fraction:{}", w.read_fraction));
        }
        if w.keys != 1024 {
            wl.push_str(&format!(",keys:{}", w.keys));
        }
        if w.start_at != 0 {
            wl.push_str(&format!(",start_ms:{}", w.start_at / MS));
        }
        if w.stop_at != u64::MAX {
            wl.push_str(&format!(",stop_ms:{}", w.stop_at / MS));
        }
        wl.push('\n');
        out.push_str(&wl);
        if let Some(plan) = &self.nemesis {
            if !plan.is_empty() {
                out.push_str(&format!("nemesis = {}\n", plan.to_text()));
            }
        }
        for (id, addr) in &self.addrs {
            out.push_str(&format!("addr.{id} = {addr}\n"));
        }
        out
    }

    /// Parse the cluster config text format. Unknown keys are errors;
    /// missing role lines are errors; opts/addrs/state_machine default.
    pub fn from_text(s: &str) -> Result<DeploymentConfig, String> {
        let mut cfg = DeploymentConfig {
            layout: ClusterLayout {
                f: 0,
                proposers: vec![],
                acceptor_pool: vec![],
                matchmaker_pool: vec![],
                replicas: vec![],
                clients: vec![],
            },
            shards: 1,
            opts: OptFlags::default(),
            addrs: Default::default(),
            state_machine: default_sm(),
            workload: WorkloadSpec::closed_loop(),
            nemesis: None,
        };
        for (lineno, line) in s.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "f" => cfg.layout.f = value.parse().map_err(|e| format!("f: {e}"))?,
                "proposers" => cfg.layout.proposers = parse_ids(value)?,
                "acceptor_pool" => cfg.layout.acceptor_pool = parse_ids(value)?,
                "matchmaker_pool" => cfg.layout.matchmaker_pool = parse_ids(value)?,
                "replicas" => cfg.layout.replicas = parse_ids(value)?,
                "clients" => cfg.layout.clients = parse_ids(value)?,
                "shards" => {
                    cfg.shards = value.parse().map_err(|e| format!("shards: {e}"))?
                }
                "state_machine" => cfg.state_machine = value.to_string(),
                "nemesis" => {
                    let plan = crate::nemesis::NemesisPlan::parse(value)
                        .map_err(|e| format!("nemesis: {e}"))?;
                    cfg.nemesis = (!plan.is_empty()).then_some(plan);
                }
                "opts" => {
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("opts: expected k:v in {part:?}"))?;
                        let b: bool =
                            v.trim().parse().map_err(|e| format!("opts {k}: {e}"))?;
                        match k.trim() {
                            "proactive" => cfg.opts.proactive_matchmaking = b,
                            "bypass" => cfg.opts.phase1_bypass = b,
                            "gc" => cfg.opts.garbage_collection = b,
                            "pruning" => cfg.opts.round_pruning = b,
                            "thrifty" => cfg.opts.thrifty = b,
                            "concurrent_p1" => cfg.opts.concurrent_phase1 = b,
                            other => return Err(format!("unknown opt {other:?}")),
                        }
                    }
                }
                "batch" => {
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("batch: expected k:v in {part:?}"))?;
                        let v = v.trim();
                        match k.trim() {
                            "size" => {
                                cfg.opts.batch_size =
                                    v.parse().map_err(|e| format!("batch size: {e}"))?;
                                if cfg.opts.batch_size == 0 {
                                    return Err("batch size must be >= 1".into());
                                }
                            }
                            "delay_us" => {
                                let us: u64 =
                                    v.parse().map_err(|e| format!("batch delay_us: {e}"))?;
                                cfg.opts.batch_delay = us * US;
                            }
                            other => return Err(format!("unknown batch key {other:?}")),
                        }
                    }
                }
                "snapshot" => {
                    let mut interval = cfg.opts.snapshot.interval;
                    let mut tail = cfg.opts.snapshot.tail;
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("snapshot: expected k:v in {part:?}"))?;
                        let v = v.trim();
                        match k.trim() {
                            "interval_us" => {
                                let us: u64 = v
                                    .parse()
                                    .map_err(|e| format!("snapshot interval_us: {e}"))?;
                                interval = us * US;
                            }
                            "interval_ms" => {
                                let ms: u64 = v
                                    .parse()
                                    .map_err(|e| format!("snapshot interval_ms: {e}"))?;
                                interval = ms * MS;
                            }
                            "tail" => {
                                tail =
                                    v.parse().map_err(|e| format!("snapshot tail: {e}"))?;
                            }
                            other => return Err(format!("unknown snapshot key {other:?}")),
                        }
                    }
                    if interval == 0 {
                        return Err("snapshot interval must be positive".into());
                    }
                    cfg.opts.snapshot = SnapshotSpec::every(interval, tail);
                }
                "leases" => {
                    let mut duration = cfg.opts.leases.duration;
                    let mut refresh = cfg.opts.leases.refresh;
                    let mut drift = cfg.opts.leases.drift;
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("leases: expected k:v in {part:?}"))?;
                        let v = v.trim();
                        let us: u64 = v.parse().map_err(|e| format!("leases {}: {e}", k.trim()))?;
                        match k.trim() {
                            "duration_us" => duration = us * US,
                            "duration_ms" => duration = us * MS,
                            "refresh_us" => refresh = us * US,
                            "refresh_ms" => refresh = us * MS,
                            "drift_us" => drift = us * US,
                            other => return Err(format!("unknown leases key {other:?}")),
                        }
                    }
                    if duration == 0 {
                        return Err("leases duration must be positive".into());
                    }
                    cfg.opts.leases = LeaseSpec::every(duration, refresh, drift);
                }
                "storage" => {
                    let mut spec = StorageSpec::wal();
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("storage: expected k:v in {part:?}"))?;
                        let v = v.trim();
                        match k.trim() {
                            "fsync" => {
                                spec.fsync =
                                    v.parse().map_err(|e| format!("storage fsync: {e}"))?;
                            }
                            "segment_kb" => {
                                let kb: u64 = v
                                    .parse()
                                    .map_err(|e| format!("storage segment_kb: {e}"))?;
                                spec.segment_bytes = kb * 1024;
                            }
                            "full_every" => {
                                spec.full_every = v
                                    .parse()
                                    .map_err(|e| format!("storage full_every: {e}"))?;
                            }
                            other => return Err(format!("unknown storage key {other:?}")),
                        }
                    }
                    if spec.segment_bytes == 0 {
                        return Err("storage segment_kb must be positive".into());
                    }
                    if spec.full_every == 0 {
                        return Err("storage full_every must be positive".into());
                    }
                    cfg.opts.storage = spec;
                }
                "admission" => {
                    let mut inbox = AdmissionSpec::default().inbox;
                    let mut target_p99_us = AdmissionSpec::default().target_p99_us;
                    let mut shed = false;
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("admission: expected k:v in {part:?}"))?;
                        let v = v.trim();
                        match k.trim() {
                            "inbox" => {
                                inbox =
                                    v.parse().map_err(|e| format!("admission inbox: {e}"))?;
                            }
                            "target_p99_us" => {
                                target_p99_us = v
                                    .parse()
                                    .map_err(|e| format!("admission target_p99_us: {e}"))?;
                            }
                            "shed" => {
                                shed =
                                    v.parse().map_err(|e| format!("admission shed: {e}"))?;
                            }
                            other => return Err(format!("unknown admission key {other:?}")),
                        }
                    }
                    if inbox == 0 {
                        return Err("admission inbox must be >= 1".into());
                    }
                    if target_p99_us == 0 {
                        return Err("admission target_p99_us must be positive".into());
                    }
                    cfg.opts.admission = AdmissionSpec::slo(inbox, target_p99_us, shed);
                }
                "workload" => {
                    let mut mode = "closed".to_string();
                    let mut window = 1usize;
                    let mut interval: Option<Time> = None;
                    let mut poisson = false;
                    let mut inflight = 64usize;
                    let mut queue_cap = crate::workload::DEFAULT_QUEUE_CAP;
                    let mut payload_bytes = 1usize;
                    let mut resend_ms: u64 = 100;
                    let mut start_ms: u64 = 0;
                    let mut stop_ms: Option<u64> = None;
                    let mut keys: u64 = 1024;
                    let mut read_fraction: f64 = 0.0;
                    for part in value.split(',') {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| format!("workload: expected k:v in {part:?}"))?;
                        let v = v.trim();
                        match k.trim() {
                            "mode" => mode = v.to_string(),
                            "window" => {
                                window =
                                    v.parse().map_err(|e| format!("workload window: {e}"))?
                            }
                            "interval_ns" => {
                                interval = Some(
                                    v.parse().map_err(|e| format!("workload interval_ns: {e}"))?,
                                )
                            }
                            "rate" => {
                                let r: f64 =
                                    v.parse().map_err(|e| format!("workload rate: {e}"))?;
                                if !(r.is_finite() && r > 0.0) {
                                    return Err(format!("workload rate must be positive: {v}"));
                                }
                                interval = Some(((1e9 / r) as Time).max(1));
                            }
                            "poisson" => {
                                poisson =
                                    v.parse().map_err(|e| format!("workload poisson: {e}"))?
                            }
                            "inflight" => {
                                inflight =
                                    v.parse().map_err(|e| format!("workload inflight: {e}"))?
                            }
                            "queue_cap" => {
                                queue_cap = v
                                    .parse()
                                    .map_err(|e| format!("workload queue_cap: {e}"))?;
                                if queue_cap == 0 {
                                    return Err("workload queue_cap must be >= 1".into());
                                }
                            }
                            "payload_bytes" => {
                                payload_bytes = v
                                    .parse()
                                    .map_err(|e| format!("workload payload_bytes: {e}"))?
                            }
                            "resend_ms" => {
                                resend_ms =
                                    v.parse().map_err(|e| format!("workload resend_ms: {e}"))?
                            }
                            "start_ms" => {
                                start_ms =
                                    v.parse().map_err(|e| format!("workload start_ms: {e}"))?
                            }
                            "stop_ms" => {
                                stop_ms = Some(
                                    v.parse().map_err(|e| format!("workload stop_ms: {e}"))?,
                                )
                            }
                            "keys" => {
                                keys = v.parse().map_err(|e| format!("workload keys: {e}"))?;
                                if keys == 0 {
                                    return Err("workload keys must be >= 1".into());
                                }
                            }
                            "read_fraction" => {
                                read_fraction = v
                                    .parse()
                                    .map_err(|e| format!("workload read_fraction: {e}"))?;
                                if !(0.0..=1.0).contains(&read_fraction) {
                                    return Err(format!(
                                        "workload read_fraction must be in [0, 1]: {v}"
                                    ));
                                }
                            }
                            other => return Err(format!("unknown workload key {other:?}")),
                        }
                    }
                    let clamp =
                        |k: usize| k.clamp(1, crate::workload::MAX_IN_FLIGHT);
                    let mode = match mode.as_str() {
                        "closed" => WorkloadMode::ClosedLoop { window: clamp(window) },
                        "open" => WorkloadMode::OpenLoop {
                            interval: match interval {
                                Some(0) | None => {
                                    return Err(
                                        "workload: open mode needs a positive rate: or \
                                         interval_ns:"
                                            .to_string(),
                                    )
                                }
                                Some(i) => i,
                            },
                            poisson,
                            max_in_flight: clamp(inflight),
                            queue_cap,
                        },
                        other => {
                            return Err(format!(
                                "unknown workload mode {other:?} (closed|open)"
                            ))
                        }
                    };
                    cfg.workload = WorkloadSpec {
                        mode,
                        payload: PayloadSpec::Fixed(vec![0u8; payload_bytes.max(1)]),
                        read_payload: PayloadSpec::Fixed(Vec::new()),
                        read_fraction,
                        start_at: start_ms * MS,
                        stop_at: stop_ms.map_or(u64::MAX, |s| s * MS),
                        resend_after: resend_ms.max(1) * MS,
                        keys,
                    };
                }
                k if k.starts_with("addr.") => {
                    let id: NodeId = k[5..]
                        .parse()
                        .map_err(|e| format!("addr key {k:?}: {e}"))?;
                    cfg.addrs.insert(id, value.to_string());
                }
                other => return Err(format!("line {}: unknown key {other:?}", lineno + 1)),
            }
        }
        cfg.layout.validate()?;
        cfg.layout.validate_shards(cfg.shards)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_layout_counts() {
        let l = ClusterLayout::standard(1, 2, 4);
        assert_eq!(l.proposers.len(), 2);
        assert_eq!(l.acceptor_pool.len(), 6);
        assert_eq!(l.matchmaker_pool.len(), 6);
        assert_eq!(l.replicas.len(), 3);
        assert_eq!(l.clients.len(), 4);
        l.validate().unwrap();
        assert_eq!(l.initial_matchmakers().len(), 3);
        assert_eq!(l.initial_config().acceptors.len(), 3);
        assert_eq!(l.total_nodes(), 2 + 6 + 6 + 3 + 4);
    }

    #[test]
    fn layout_f2() {
        let l = ClusterLayout::standard(2, 2, 8);
        assert_eq!(l.proposers.len(), 3);
        assert_eq!(l.acceptor_pool.len(), 10);
        assert_eq!(l.initial_config().acceptors.len(), 5);
        l.validate().unwrap();
    }

    #[test]
    fn config_validation() {
        Configuration::majority(0, vec![1, 2, 3]).validate().unwrap();
        assert!(Configuration::majority(0, vec![]).validate().is_err());
        assert!(Configuration::majority(0, vec![1, 1, 2]).validate().is_err());
        let bad = Configuration {
            id: 0,
            acceptors: vec![1, 2, 3, 4],
            quorum: QuorumSpec::Flexible { p1: 2, p2: 2 },
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn config_rejects_non_intersecting_flexible_quorums() {
        // p1 + p2 <= |A|: the silent-unsafety case the load-time check
        // exists for.
        let bad = Configuration {
            id: 7,
            acceptors: vec![1, 2, 3, 4, 5],
            quorum: QuorumSpec::Flexible { p1: 2, p2: 3 },
        };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("invalid quorum system"), "{err}");
        assert!(err.contains("must exceed"), "{err}");
        // The boundary case p1 + p2 = |A| + 1 is valid.
        let ok = Configuration {
            id: 8,
            acceptors: vec![1, 2, 3, 4, 5],
            quorum: QuorumSpec::Flexible { p1: 3, p2: 3 },
        };
        ok.validate().unwrap();
    }

    #[test]
    fn config_rejects_out_of_bounds_explicit_quorums() {
        // Index 3 into a 3-acceptor list: previously silently treated as
        // an unsatisfiable quorum (quorum.rs membership test), now a
        // descriptive load-time error.
        let bad = Configuration {
            id: 9,
            acceptors: vec![1, 2, 3],
            quorum: QuorumSpec::Explicit {
                p1: vec![[0usize, 3].into_iter().collect()],
                p2: vec![[1usize, 2].into_iter().collect()],
            },
        };
        let err = bad.validate().unwrap_err();
        assert!(err.contains("out of bounds"), "{err}");
    }

    #[test]
    fn text_config_roundtrip() {
        let mut cfg = DeploymentConfig::standard(1, 2);
        cfg.addrs.insert(0, "127.0.0.1:7000".into());
        cfg.opts.thrifty = false;
        cfg.opts.batch_size = 16;
        cfg.opts.batch_delay = 750 * US;
        cfg.opts.snapshot = SnapshotSpec::every(250 * MS, 2048);
        cfg.state_machine = "kv".into();
        cfg.workload = WorkloadSpec::open_loop(2000.0)
            .max_in_flight(16)
            .payload_bytes(8)
            .start_at(500 * MS)
            .stop_at(30_000 * MS)
            .resend_after(50 * MS);
        let s = cfg.to_text();
        let back = DeploymentConfig::from_text(&s).unwrap();
        assert_eq!(back.layout, cfg.layout);
        assert_eq!(back.opts, cfg.opts);
        assert_eq!(back.state_machine, "kv");
        assert_eq!(back.addrs, cfg.addrs);
        assert_eq!(back.workload, cfg.workload);
    }

    #[test]
    fn text_config_nemesis_line_roundtrips() {
        let mut cfg = DeploymentConfig::standard(1, 2);
        // No plan (or an empty one): no `nemesis =` line at all.
        assert!(!cfg.to_text().contains("nemesis ="));
        cfg.nemesis = Some(crate::nemesis::NemesisPlan::none());
        assert!(!cfg.to_text().contains("nemesis ="));
        let plan = crate::nemesis::NemesisPlan::parse(
            "100:part(0,1|2,3);300:heal;400:oneway(6>7);600:slow(10,2000);800:skew(6,5000)",
        )
        .unwrap();
        cfg.nemesis = Some(plan.clone());
        let text = cfg.to_text();
        assert!(text.contains("nemesis = 100:part(0,1|2,3);"), "{text}");
        let back = DeploymentConfig::from_text(&text).unwrap();
        assert_eq!(back.nemesis, Some(plan));
        // A malformed plan is a load-time error naming the fault.
        let bad = format!("{}nemesis = 10:wat(1)\n", DeploymentConfig::standard(1, 1).to_text());
        let err = DeploymentConfig::from_text(&bad).unwrap_err();
        assert!(err.contains("unknown kind"), "{err}");
    }

    #[test]
    fn text_config_workload_knobs() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        // Pipelined closed loop.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}workload = mode:closed,window:8\n"
        ))
        .unwrap();
        assert_eq!(cfg.workload.mode, WorkloadMode::ClosedLoop { window: 8 });
        // Open loop via the human-friendly rate: key.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,rate:1000,poisson:true,inflight:32\n"
        ))
        .unwrap();
        match cfg.workload.mode {
            WorkloadMode::OpenLoop { interval, poisson, max_in_flight, queue_cap } => {
                assert_eq!(interval, 1_000_000);
                assert!(poisson);
                assert_eq!(max_in_flight, 32);
                assert_eq!(queue_cap, crate::workload::DEFAULT_QUEUE_CAP);
            }
            other => panic!("{other:?}"),
        }
        // A queue_cap key parses and round-trips; zero is rejected.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,rate:1000,queue_cap:256\n"
        ))
        .unwrap();
        assert!(matches!(
            cfg.workload.mode,
            WorkloadMode::OpenLoop { queue_cap: 256, .. }
        ));
        let back = DeploymentConfig::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.workload.mode, cfg.workload.mode);
        assert!(DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,rate:1000,queue_cap:0\n"
        ))
        .is_err());
        // Open mode without a rate is an error; so are unknown keys/modes.
        assert!(DeploymentConfig::from_text(&format!("{base}workload = mode:open\n")).is_err());
        assert!(
            DeploymentConfig::from_text(&format!("{base}workload = mode:weird\n")).is_err()
        );
        assert!(
            DeploymentConfig::from_text(&format!("{base}workload = bogus:1\n")).is_err()
        );
        assert!(DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,rate:0\n"
        ))
        .is_err());
        // interval_ns:0 would mean an arrival every nanosecond — rejected
        // like rate:0.
        assert!(DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,interval_ns:0\n"
        ))
        .is_err());
        // Oversized in-flight windows clamp to the replica result cache.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,rate:100,inflight:99999\n"
        ))
        .unwrap();
        assert_eq!(cfg.workload.in_flight_bound(), crate::workload::MAX_IN_FLIGHT);
    }

    #[test]
    fn text_config_batch_knobs() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        let with_batch = format!("{base}# override\nbatch = size:32,delay_us:200\n");
        let cfg = DeploymentConfig::from_text(&with_batch).unwrap();
        assert_eq!(cfg.opts.batch_size, 32);
        assert_eq!(cfg.opts.batch_delay, 200 * US);
        assert!(DeploymentConfig::from_text(&format!("{base}batch = size:0\n")).is_err());
        assert!(DeploymentConfig::from_text(&format!("{base}batch = bogus:1\n")).is_err());
    }

    #[test]
    fn text_config_snapshot_knobs() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        // Default: disabled (no snapshot line emitted).
        assert!(!base.contains("snapshot ="));
        assert!(!DeploymentConfig::from_text(&base).unwrap().opts.snapshot.enabled);
        // A snapshot line enables it.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}snapshot = interval_ms:50,tail:4096\n"
        ))
        .unwrap();
        assert!(cfg.opts.snapshot.enabled);
        assert_eq!(cfg.opts.snapshot.interval, 50 * MS);
        assert_eq!(cfg.opts.snapshot.tail, 4096);
        // Tiny tails clamp up to the in-flight bound (retry re-replies
        // must stay answerable).
        let cfg = DeploymentConfig::from_text(&format!("{base}snapshot = tail:1\n")).unwrap();
        assert_eq!(cfg.opts.snapshot.tail, crate::workload::MAX_IN_FLIGHT as u64);
        // Sub-microsecond intervals clamp to 1 µs so `to_text` (which
        // serializes microseconds) always round-trips.
        let spec = SnapshotSpec::every(500, 1024);
        assert_eq!(spec.interval, US);
        let mut clamped = DeploymentConfig::standard(1, 1);
        clamped.opts.snapshot = spec;
        let back = DeploymentConfig::from_text(&clamped.to_text()).unwrap();
        assert_eq!(back.opts.snapshot, spec);
        // Bad keys / zero interval rejected.
        assert!(DeploymentConfig::from_text(&format!("{base}snapshot = bogus:1\n")).is_err());
        assert!(DeploymentConfig::from_text(&format!(
            "{base}snapshot = interval_us:0\n"
        ))
        .is_err());
    }

    #[test]
    fn text_config_lease_knobs() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        // Default: disabled (no leases line emitted).
        assert!(!base.contains("leases ="));
        assert!(!DeploymentConfig::from_text(&base).unwrap().opts.leases.enabled);
        // A leases line enables it; ms and us spellings both parse.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}leases = duration_ms:40,refresh_ms:2,drift_us:200\n"
        ))
        .unwrap();
        assert!(cfg.opts.leases.enabled);
        assert_eq!(cfg.opts.leases.duration, 40 * MS);
        assert_eq!(cfg.opts.leases.refresh, 2 * MS);
        assert_eq!(cfg.opts.leases.drift, 200 * US);
        // Round trip through to_text.
        let mut with = DeploymentConfig::standard(1, 1);
        with.opts.leases = LeaseSpec::every(40 * MS, 2 * MS, 200 * US);
        let back = DeploymentConfig::from_text(&with.to_text()).unwrap();
        assert_eq!(back.opts.leases, with.opts.leases);
        // Refresh clamps to duration / 4 (a lease that expires between
        // renewals serves no reads).
        let clamped = LeaseSpec::every(8 * MS, 100 * MS, US);
        assert_eq!(clamped.refresh, 2 * MS);
        // Bad keys / zero duration rejected.
        assert!(DeploymentConfig::from_text(&format!("{base}leases = bogus:1\n")).is_err());
        assert!(
            DeploymentConfig::from_text(&format!("{base}leases = duration_us:0\n")).is_err()
        );
    }

    #[test]
    fn text_config_storage_knobs() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        // Default: disabled (no storage line emitted).
        assert!(!base.contains("storage ="));
        assert!(!DeploymentConfig::from_text(&base).unwrap().opts.storage.enabled);
        // A storage line enables it; omitted knobs keep the safe
        // defaults (fsync on).
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}storage = segment_kb:64,full_every:2\n"
        ))
        .unwrap();
        assert!(cfg.opts.storage.enabled);
        assert!(cfg.opts.storage.fsync);
        assert_eq!(cfg.opts.storage.segment_bytes, 64 * 1024);
        assert_eq!(cfg.opts.storage.full_every, 2);
        // fsync:false parses (benchmark mode).
        let cfg = DeploymentConfig::from_text(&format!("{base}storage = fsync:false\n")).unwrap();
        assert!(cfg.opts.storage.enabled && !cfg.opts.storage.fsync);
        // Round trip through to_text.
        let mut with = DeploymentConfig::standard(1, 1);
        with.opts.storage =
            StorageSpec { enabled: true, fsync: true, segment_bytes: 256 * 1024, full_every: 8 };
        let back = DeploymentConfig::from_text(&with.to_text()).unwrap();
        assert_eq!(back.opts.storage, with.opts.storage);
        // wal_options clamps pathological values rather than erroring.
        let opts = StorageSpec { segment_bytes: 1, full_every: 1, ..StorageSpec::wal() }
            .wal_options();
        assert_eq!(opts.segment_bytes, 4 << 10);
        // Bad keys / zero knobs rejected.
        assert!(DeploymentConfig::from_text(&format!("{base}storage = bogus:1\n")).is_err());
        assert!(
            DeploymentConfig::from_text(&format!("{base}storage = segment_kb:0\n")).is_err()
        );
        assert!(
            DeploymentConfig::from_text(&format!("{base}storage = full_every:0\n")).is_err()
        );
    }

    #[test]
    fn text_config_admission_knobs() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        // Default: disabled (no admission line emitted).
        assert!(!base.contains("admission ="));
        assert!(!DeploymentConfig::from_text(&base).unwrap().opts.admission.enabled);
        // An admission line enables it.
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}admission = inbox:256,target_p99_us:5000,shed:true\n"
        ))
        .unwrap();
        assert!(cfg.opts.admission.enabled);
        assert_eq!(cfg.opts.admission.inbox, 256);
        assert_eq!(cfg.opts.admission.target_p99_us, 5000);
        assert!(cfg.opts.admission.shed);
        assert_eq!(cfg.opts.admission.retry_after(), 5000 * US);
        // Round trip through to_text.
        let mut with = DeploymentConfig::standard(1, 1);
        with.opts.admission = AdmissionSpec::slo(128, 10_000, false);
        let back = DeploymentConfig::from_text(&with.to_text()).unwrap();
        assert_eq!(back.opts.admission, with.opts.admission);
        // Bad keys / zero knobs rejected.
        assert!(DeploymentConfig::from_text(&format!("{base}admission = bogus:1\n")).is_err());
        assert!(DeploymentConfig::from_text(&format!("{base}admission = inbox:0\n")).is_err());
        assert!(DeploymentConfig::from_text(&format!(
            "{base}admission = target_p99_us:0\n"
        ))
        .is_err());
    }

    #[test]
    fn text_config_read_fraction_knob() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}workload = mode:open,rate:1000,read_fraction:0.9\n"
        ))
        .unwrap();
        assert!((cfg.workload.read_fraction - 0.9).abs() < 1e-9);
        // Default zero; out-of-range rejected; round-trips when set.
        assert_eq!(DeploymentConfig::from_text(&base).unwrap().workload.read_fraction, 0.0);
        assert!(DeploymentConfig::from_text(&format!(
            "{base}workload = mode:closed,read_fraction:1.5\n"
        ))
        .is_err());
        let mut with = DeploymentConfig::standard(1, 1);
        with.workload = WorkloadSpec::closed_loop().read_fraction(0.25);
        let back = DeploymentConfig::from_text(&with.to_text()).unwrap();
        assert!((back.workload.read_fraction - 0.25).abs() < 1e-9);
    }

    #[test]
    fn shards_validation_descriptive_errors() {
        // Satellite fix: shard-count validation in the style of
        // quorum::validate — loud, descriptive, at load time.
        let l = ClusterLayout::standard(1, 2, 4); // 2 proposers, 6 acc, 3 rep
        assert!(l.validate_shards(1).is_ok());
        // 0 shards: rejected with a hint.
        let err = l.validate_shards(0).unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        // 2 shards over the standard layout: 2 proposers / 2 shards = 1
        // per group < f+1 (proposers are checked first).
        let err = l.validate_shards(2).unwrap_err();
        assert!(err.contains("proposer") && err.contains(">= 2"), "{err}");
        // With enough proposers/acceptors, the 3 replicas still don't
        // divide into 2 groups: a divisibility error naming the role.
        let mut odd = ClusterLayout::standard(1, 2, 4);
        odd.proposers = (100..104).collect();
        odd.acceptor_pool = (104..116).collect();
        let err = odd.validate_shards(2).unwrap_err();
        assert!(err.contains("replica") && err.contains("divide"), "{err}");
    }

    #[test]
    fn partition_slices_roles_per_group() {
        // A 2-shard-capable layout: double every per-group role list.
        let mut l = ClusterLayout::standard(1, 2, 4);
        l.proposers = (0..4).collect();
        l.acceptor_pool = (4..16).collect();
        l.replicas = (16..22).collect();
        l.matchmaker_pool = (22..28).collect();
        l.clients = (28..32).collect();
        l.validate().unwrap();
        let groups = l.partition(2).unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].proposers, vec![0, 1]);
        assert_eq!(groups[1].proposers, vec![2, 3]);
        assert_eq!(groups[0].acceptor_pool.len(), 6);
        assert_eq!(groups[1].acceptor_pool, (10..16).collect::<Vec<_>>());
        assert_eq!(groups[0].replicas, (16..19).collect::<Vec<_>>());
        assert_eq!(groups[1].replicas, (19..22).collect::<Vec<_>>());
        // Groups are disjoint.
        for a in &groups[0].proposers {
            assert!(!groups[1].proposers.contains(a));
        }
        assert!(l.partition(3).is_err());
    }

    #[test]
    fn text_config_shards_knob() {
        let base = DeploymentConfig::standard(1, 2);
        // Default: no shards line emitted; parses back to 1.
        let text = base.to_text();
        assert!(!text.contains("shards ="));
        assert_eq!(DeploymentConfig::from_text(&text).unwrap().shards, 1);
        // A shardable layout round-trips its shards line.
        let mut cfg = DeploymentConfig::standard(1, 2);
        cfg.shards = 2;
        cfg.layout.proposers = (0..4).collect();
        cfg.layout.acceptor_pool = (4..16).collect();
        cfg.layout.matchmaker_pool = (16..22).collect();
        cfg.layout.replicas = (22..28).collect();
        cfg.layout.clients = (28..30).collect();
        let text = cfg.to_text();
        assert!(text.contains("shards = 2"));
        let back = DeploymentConfig::from_text(&text).unwrap();
        assert_eq!(back.shards, 2);
        // The standard (indivisible) layout with shards = 2 is rejected
        // at load time with a descriptive error.
        let bad = format!("{}shards = 2\n", base.to_text());
        let err = DeploymentConfig::from_text(&bad).unwrap_err();
        assert!(err.contains("divide") || err.contains("needs"), "{err}");
        // shards = 0 likewise.
        let zero = format!("{}shards = 0\n", base.to_text());
        assert!(DeploymentConfig::from_text(&zero).is_err());
    }

    #[test]
    fn text_config_workload_keys_knob() {
        let base = DeploymentConfig::standard(1, 1).to_text();
        let cfg = DeploymentConfig::from_text(&format!(
            "{base}workload = mode:closed,window:2,keys:64\n"
        ))
        .unwrap();
        assert_eq!(cfg.workload.keys, 64);
        // Default key space when unspecified; zero rejected.
        assert_eq!(DeploymentConfig::from_text(&base).unwrap().workload.keys, 1024);
        assert!(DeploymentConfig::from_text(&format!(
            "{base}workload = mode:closed,keys:0\n"
        ))
        .is_err());
        // Non-default key spaces round-trip through to_text.
        let mut cfg = DeploymentConfig::standard(1, 1);
        cfg.workload = WorkloadSpec::closed_loop().keys(77);
        let back = DeploymentConfig::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.workload.keys, 77);
    }

    #[test]
    fn text_config_rejects_garbage() {
        assert!(DeploymentConfig::from_text("nonsense").is_err());
        assert!(DeploymentConfig::from_text("bogus_key = 3").is_err());
        // Valid keys but invalid layout (no proposers).
        assert!(DeploymentConfig::from_text("f = 1").is_err());
    }

    #[test]
    fn invalid_layout_rejected() {
        let mut l = ClusterLayout::standard(1, 2, 1);
        l.proposers = vec![0];
        assert!(l.validate().is_err());
        let mut l2 = ClusterLayout::standard(1, 2, 1);
        l2.clients = vec![l2.proposers[0]];
        assert!(l2.validate().is_err());
    }

    #[test]
    fn opt_flags() {
        let all = OptFlags::default();
        assert!(all.proactive_matchmaking && all.phase1_bypass && all.garbage_collection);
        let none = OptFlags::none();
        assert!(!none.proactive_matchmaking && !none.phase1_bypass && !none.garbage_collection);
    }
}
