//! Runtime bridge for the AOT-compiled JAX/Pallas programs.
//!
//! Python runs once at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 JAX model (which calls the L1 Pallas kernel) to HLO
//! **text** — not a serialized `HloModuleProto`, because jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids. With the `pjrt` cargo feature this module loads that
//! text with `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
//! client, and executes it from the Rust request path; Python is never on
//! the request path.
//!
//! The `pjrt` feature requires the external `xla` crate, which is not
//! vendored (the default build is fully offline). Without it,
//! [`crate::statemachine::TensorStateMachine`] executes the identical
//! math through its pure-Rust reference backend, so the tensor path —
//! and everything built on it, like the Phase 2 batching experiments —
//! works in every environment. The artifact-location helpers below are
//! available either way.

use std::path::PathBuf;

/// Locate the artifacts directory: `$MATCHMAKER_ARTIFACTS`, else
/// `./artifacts`, else `<repo>/artifacts` relative to the manifest.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("MATCHMAKER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    // Fall back to the manifest-relative path (tests run from target/).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("apply_batch_b8.hlo.txt").exists()
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{Context, Result};
    use std::path::{Path, PathBuf};

    /// A PJRT execution engine (one per process).
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Engine { client })
        }

        /// Platform description (logs/metrics).
        pub fn platform(&self) -> String {
            format!(
                "{} ({} devices)",
                self.client.platform_name(),
                self.client.device_count()
            )
        }

        /// Load an HLO-text artifact and compile it into an executable
        /// program.
        pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Program> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            Ok(Program { exe, path: path.to_path_buf() })
        }
    }

    /// A compiled program with f32 tensor inputs and a tuple of f32 tensor
    /// outputs (the shape of all our AOT artifacts; `aot.py` lowers with
    /// `return_tuple=True`).
    pub struct Program {
        exe: xla::PjRtLoadedExecutable,
        path: PathBuf,
    }

    impl Program {
        /// Execute with f32 inputs (`(data, dims)` pairs). Returns each
        /// output leaf as a flat f32 vector.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data)
                    .reshape(dims)
                    .with_context(|| format!("reshape input to {dims:?}"))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute {}", self.path.display()))?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True: the output is a tuple.
            let leaves = result.to_tuple().context("untuple program output")?;
            let mut out = Vec::with_capacity(leaves.len());
            for leaf in leaves {
                out.push(leaf.to_vec::<f32>().context("read f32 output leaf")?);
            }
            Ok(out)
        }

        /// Artifact path (diagnostics).
        pub fn path(&self) -> &Path {
            &self.path
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Engine, Program};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_is_resolvable() {
        // The helper must return *some* path without panicking whether or
        // not artifacts are built; availability simply reflects the
        // marker file's existence.
        let dir = artifacts_dir();
        assert!(!dir.as_os_str().is_empty());
        assert_eq!(artifacts_available(), dir.join("apply_batch_b8.hlo.txt").exists());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn engine_creates() {
        let e = Engine::cpu().expect("PJRT CPU client");
        let p = e.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("host"), "platform = {p}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn missing_artifact_is_error() {
        let e = Engine::cpu().unwrap();
        assert!(e.load_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}
