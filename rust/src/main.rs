//! `repro` — the Matchmaker Paxos launcher.
//!
//! Subcommands:
//! * `repro exp <id> [--seed N]` — regenerate a paper table/figure on the
//!   simulator (`f9`, `t1`, `f10`, `f11`, `f12`, `f14`, `f15`, `f16`,
//!   `f17`, `f18`, `f19`, `f20`, `f21`, `t2`, `x2`, or `all`).
//! * `repro run --role <role> --id <id> --config cluster.conf` — run one
//!   node of a real TCP deployment.
//! * `repro gen-config [--f N] [--clients N] [--base-port P]` — emit a
//!   cluster config template.
//! * `repro smoke` — runtime smoke test: load + execute the AOT artifacts.
//! * `repro check [list | replay FILE | NAME]` — exhaustive model
//!   checking of the protocol on small instances (DESIGN.md §Model
//!   checking).
//! * `repro sweep [--mode smoke|full] [--compare DIR]` — deterministic
//!   parameter-space sweep + perf-regression gate (DESIGN.md §Sweeps).

use anyhow::{Context, Result};
use matchmaker::config::{Configuration, DeploymentConfig};
use matchmaker::harness::experiments as exp;
use matchmaker::roles::{Acceptor, Client, Leader, Matchmaker, Replica, ShardClient};
use matchmaker::statemachine;
use matchmaker::workload::WorkloadSpec;
use matchmaker::{GroupId, NodeId};

/// Minimal flag parser: `--key value` pairs after positional args.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let value = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), value.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    fn required(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("missing required flag --{key}"))
    }
}

const USAGE: &str = "usage:
  repro exp <id> [--seed N] [--bench-json PATH]
      regenerate a paper experiment (f9 t1 f10 f11 f12 f14 f15 f16 f17 f18 f19 f20 f21 t2 x2 x3 x4 x5 x6 x7 x9 x10 x12 all)
      --bench-json PATH   write a machine-readable BENCH_<id>.json row set
                          (x3-x7, x9, x10, and x12; purpose-built short runs, schema in DESIGN.md)
      x9: leader overload control — offered-load sweep past saturation under
          admission off / Busy-retry / Busy-shed policies (DESIGN.md §Overload)
      x10: kill -9 + recovery storm on a live TCP cluster with fsync'd
           WALs (needs a writable tempdir and two free local port ranges)
      x12: scripted nemesis schedule (partition/heal/gray-slow/clock-skew)
           vs its fault-free twin at the same seed (DESIGN.md §Nemesis)
  repro run --role R --id N --config FILE [--duration SECS] [--data-dir DIR]
      --data-dir DIR    open fsync'd WALs under DIR/<role>-<id>; replay
                        them on start (crash recovery, DESIGN.md §Durability)
      --nemesis PLAN    scripted fault injection around the framing layer
                        (partitions / gray failures / clock skew; overrides
                        the config's `nemesis =` line; DESIGN.md §Nemesis)
                        e.g. \"1000:part(0,1|2,3);3000:heal;4000:slow(2,2000)\"
      client role workload flags (override the config's `workload =` line):
        --workload closed|pipelined|open|open-poisson
        --rate N          open-loop arrivals/sec per client
        --window K        in-flight bound (closed-loop window / open-loop cap)
        --payload-bytes N command payload size
        --read-fraction F fraction of requests issued as linearizable reads (0..=1)
  repro gen-config [--f N] [--clients N] [--base-port P]
  repro smoke                      run the tensor state machine end to end
  repro check [NAME] [--mode smoke|full] [--depth N] [--states N] [--emit-trace FILE]
      exhaustively explore the checked protocol instances (default: all);
      exits nonzero on any unexpected invariant violation
  repro check list                 list the checked instances
  repro check replay FILE          deterministically re-execute a trace file
  repro sweep [--mode smoke|full] [--seed N] [--jobs N] [--out DIR]
              [--compare DIR] [--only LABEL]
      deterministic parameter-space sweep on the simulator: smoke = a
      seeded sample of the grid (CI fast loop), full = the whole grid
      (release job); identical --mode/--seed runs are byte-identical
      --jobs N       parallel workers (default: one per core)
      --out DIR      write BENCH_sweep_<mode>.json + SWEEP_<mode>.csv
      --compare DIR  diff against committed BENCH_*.json baselines
                     (benches/baselines); exit 1 on >10% composite-score
                     regression or a missing pinned configuration
      --only LABEL   replay one configuration in isolation and print it
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "exp" => {
            let id = args.positional.first().context("exp: missing experiment id")?;
            let seed: u64 = args.flag("seed", 42)?;
            if let Some(path) = args.flags.get("bench-json") {
                return write_bench_json(id, seed, path);
            }
            run_experiment(id, seed)
        }
        "run" => {
            let role = args.required("role")?.to_string();
            let id: NodeId = args.required("id")?.parse()?;
            let config = args.required("config")?.to_string();
            let duration: u64 = args.flag("duration", 30)?;
            run_node(&role, id, &config, duration, &args)
        }
        "gen-config" => {
            let f: usize = args.flag("f", 1)?;
            let clients: usize = args.flag("clients", 4)?;
            let base_port: u16 = args.flag("base-port", 7000)?;
            let mut cfg = DeploymentConfig::standard(f, clients);
            for i in 0..cfg.layout.total_nodes() as NodeId {
                cfg.addrs.insert(i, format!("127.0.0.1:{}", base_port + i as u16));
            }
            println!("{}", cfg.to_text());
            Ok(())
        }
        "smoke" => smoke(),
        "check" => check(&args),
        "sweep" => sweep(&args),
        other => {
            eprintln!("unknown command: {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn run_experiment(id: &str, seed: u64) -> Result<()> {
    match id {
        "f9" | "t1" => {
            let (fig, tab) = exp::figure9(seed);
            print!("{}{}", fig.render(), tab.render());
        }
        "f10" => {
            let (fig, tab) = exp::figure10(seed);
            print!("{}{}", fig.render(), tab.render());
        }
        "f11" => {
            let (fig, tab) = exp::figure11(seed);
            print!("{}{}", fig.render(), tab.render());
        }
        "f12" | "f13" => print!("{}", exp::figure12_13(seed).render()),
        "f14" => print!("{}", exp::figure14(seed).render()),
        "f15" => {
            let (fig, _) = exp::figure15(seed);
            print!("{}", fig.render());
        }
        "f16" => print!("{}", exp::figure16(seed).render()),
        "f17" => print!("{}", exp::figure17(seed).render()),
        "f18" => print!("{}", exp::figure18(seed).render()),
        "f19" => print!("{}", exp::figure19(seed).render()),
        "f20" => print!("{}", exp::figure20(seed).render()),
        "f21" | "t2" => {
            let (fig, tab) = exp::figure21(seed);
            print!("{}{}", fig.render(), tab.render());
        }
        "x2" => print!("{}", exp::fast_paxos_experiment(seed).render()),
        "x3" | "batch" => print!("{}", exp::batching_figure(seed).render()),
        "x4" | "openloop" => print!("{}", exp::open_loop_figure(seed).render()),
        "x5" | "retention" => print!("{}", exp::retention_figure(seed).render()),
        "x6" | "shards" => print!("{}", exp::sharding_figure(seed).render()),
        "x7" | "reads" => print!("{}", exp::read_scaling_figure(seed).render()),
        "x9" | "overload" => print!("{}", exp::overload_figure(seed).render()),
        "x10" | "recovery" => print!("{}", exp::crash_recovery_figure(seed).render()),
        "x12" | "nemesis" => print!("{}", exp::nemesis_figure(seed).render()),
        "all" => {
            for (name, text) in exp::run_all(seed) {
                println!("########## {name} ##########");
                print!("{text}");
            }
        }
        other => anyhow::bail!("unknown experiment id: {other} (try `repro exp all`)"),
    }
    Ok(())
}

/// `repro exp <id> --bench-json <path>`: run the experiment's
/// machine-readable row set and write it (the perf-trajectory artifact;
/// schema in DESIGN.md §Bench trajectory).
fn write_bench_json(id: &str, seed: u64, path: &str) -> Result<()> {
    let bench = exp::bench_json_for(id, seed)
        .with_context(|| format!("--bench-json supports x3..x7, x9, x10, and x12, not {id:?}"))?;
    let json = bench.to_json();
    std::fs::write(path, &json).with_context(|| format!("write {path}"))?;
    print!("{json}");
    eprintln!("wrote {path}");
    Ok(())
}

/// Resolve the client workload: the config file's `workload =` line,
/// overridden by any `repro run` CLI flags.
fn client_workload(cfg: &DeploymentConfig, args: &Args) -> Result<WorkloadSpec> {
    let mut spec = cfg.workload.clone();
    let checked_rate = |args: &Args| -> Result<f64> {
        let rate: f64 = args.flag("rate", 1000.0)?;
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "--rate must be a positive arrivals/sec value, got {rate}"
        );
        Ok(rate)
    };
    if let Some(mode) = args.flags.get("workload") {
        let rate = checked_rate(args)?;
        spec = match mode.as_str() {
            "closed" => WorkloadSpec::closed_loop(),
            "pipelined" => WorkloadSpec::pipelined(8),
            "open" => WorkloadSpec::open_loop(rate),
            "open-poisson" => WorkloadSpec::open_loop_poisson(rate),
            other => anyhow::bail!(
                "--workload {other:?}: expected closed|pipelined|open|open-poisson"
            ),
        }
        .resend_after(spec.resend_after)
        .start_at(spec.start_at)
        .stop_at(spec.stop_at);
    } else if args.flags.contains_key("rate") {
        let rate = checked_rate(args)?;
        spec = WorkloadSpec::open_loop(rate)
            .resend_after(spec.resend_after)
            .start_at(spec.start_at)
            .stop_at(spec.stop_at);
    }
    if let Some(window) = args.flags.get("window") {
        let k: usize = window
            .parse()
            .map_err(|e| anyhow::anyhow!("--window {window:?}: {e}"))?;
        spec = spec.max_in_flight(k);
    }
    if let Some(n) = args.flags.get("payload-bytes") {
        let n: usize = n
            .parse()
            .map_err(|e| anyhow::anyhow!("--payload-bytes {n:?}: {e}"))?;
        spec = spec.payload_bytes(n);
    }
    if let Some(f) = args.flags.get("read-fraction") {
        let frac: f64 = f
            .parse()
            .map_err(|e| anyhow::anyhow!("--read-fraction {f:?}: {e}"))?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&frac),
            "--read-fraction must be in [0, 1], got {frac}"
        );
        spec = spec.read_fraction(frac);
    }
    Ok(spec)
}

fn run_node(role: &str, id: NodeId, config_path: &str, duration: u64, args: &Args) -> Result<()> {
    let text = std::fs::read_to_string(config_path)
        .with_context(|| format!("read {config_path}"))?;
    let cfg = DeploymentConfig::from_text(&text).map_err(|e| anyhow::anyhow!(e))?;
    let layout = cfg.layout.clone();
    // Durable storage (DESIGN.md §Durability): with `--data-dir DIR`,
    // each role opens a WAL under `DIR/<role>-<id>`, replays whatever a
    // previous incarnation persisted, and only then starts serving. The
    // config's `storage =` line tunes fsync/segmentation; without one
    // the safe defaults (fsync on) apply.
    let data_dir = args.flags.get("data-dir").cloned();
    let wal_for = |role: &str| -> Result<Option<Box<dyn matchmaker::storage::Storage>>> {
        let Some(dir) = &data_dir else { return Ok(None) };
        let path = std::path::Path::new(dir).join(format!("{role}-{id}"));
        let wal =
            matchmaker::storage::wal::WalStorage::open(path.clone(), cfg.opts.storage.wal_options())
                .with_context(|| format!("open WAL at {}", path.display()))?;
        Ok(Some(Box::new(wal)))
    };
    // Sharded deployments (`shards = N`): the proposer/acceptor/replica
    // lists partition into N groups sharing the matchmaker pool; each
    // group-scoped role finds its slice by its node id.
    let groups = layout.partition(cfg.shards).map_err(|e| anyhow::anyhow!(e))?;
    let group_of = |ids: fn(&matchmaker::config::GroupLayout) -> &Vec<NodeId>| {
        groups
            .iter()
            .enumerate()
            .find(|(_, gl)| ids(gl).contains(&id))
            .map(|(g, gl)| (g as GroupId, gl.clone()))
    };
    let node: Box<dyn matchmaker::Node> = match role {
        "acceptor" => {
            let mut a = Acceptor::new(id);
            if let Some(wal) = wal_for("acceptor")? {
                a.attach_storage(wal);
                // Recovery predates the network; its effects (the
                // AcceptorRecovered announce) have nowhere to go yet.
                a.recover(&mut matchmaker::Effects::new());
            }
            Box::new(a)
        }
        "matchmaker" => {
            let active = layout.initial_matchmakers().contains(&id);
            let mut m =
                if active { Matchmaker::new(id) } else { Matchmaker::new_standby(id) };
            if let Some(wal) = wal_for("matchmaker")? {
                m.attach_storage(wal);
                m.recover();
            }
            Box::new(m)
        }
        "replica" => {
            let sm: Box<dyn statemachine::StateMachine> = if cfg.state_machine == "tensor" {
                Box::new(statemachine::TensorStateMachine::load()?)
            } else {
                statemachine::by_name(&cfg.state_machine)
                    .context("unknown state machine (noop|kv|register|counter|tensor)")?
            };
            let (group, gl) = group_of(|gl| &gl.replicas)
                .with_context(|| format!("node {id} is not a replica in the config"))?;
            let mut rep = Replica::new(id, sm);
            rep.group = group;
            rep.snapshot = cfg.opts.snapshot;
            rep.peers = gl.replicas.clone();
            rep.proposers = gl.proposers.clone();
            if let Some(wal) = wal_for("replica")? {
                rep.attach_storage(wal);
                rep.recover();
            }
            Box::new(rep)
        }
        "proposer" => {
            let (group, gl) = group_of(|gl| &gl.proposers)
                .with_context(|| format!("node {id} is not a proposer in the config"))?;
            let initial =
                Configuration::majority(0, gl.acceptor_pool[..2 * layout.f + 1].to_vec());
            let mut leader = Leader::new(
                id,
                layout.f,
                initial,
                layout.initial_matchmakers(),
                gl.replicas.clone(),
                gl.proposers.clone(),
                cfg.opts,
                id as u64,
            );
            leader.group = group;
            if let Some(wal) = wal_for("proposer")? {
                leader.attach_storage(wal);
                leader.recover();
            }
            Box::new(leader)
        }
        "client" => {
            let spec = client_workload(&cfg, args)?;
            if cfg.shards > 1 {
                let proposer_lists: Vec<Vec<NodeId>> =
                    groups.iter().map(|gl| gl.proposers.clone()).collect();
                let mut cl = ShardClient::new(id, proposer_lists, spec);
                cl.replicas_per_group(groups.iter().map(|gl| gl.replicas.clone()).collect());
                // The config's `admission =` policy decides what a Busy
                // pushback means here, exactly as in the sim harness:
                // shed (count abandoned) or hint-driven delayed retry.
                cl.shed_on_busy = cfg.opts.admission.enabled && cfg.opts.admission.shed;
                Box::new(cl)
            } else {
                let mut cl = Client::new(id, layout.proposers.clone(), spec);
                cl.replicas = layout.replicas.clone();
                cl.shed_on_busy = cfg.opts.admission.enabled && cfg.opts.admission.shed;
                Box::new(cl)
            }
        }
        other => anyhow::bail!("unknown role: {other}"),
    };

    // Nemesis (DESIGN.md §Nemesis): `--nemesis PLAN` overrides the
    // config's `nemesis =` line. Every process evaluates the same plan
    // against its own start time and filters its egress, so one shared
    // plan text coordinates the whole deployment.
    let plan = match args.flags.get("nemesis") {
        Some(text) => {
            let p = matchmaker::nemesis::NemesisPlan::parse(text)
                .map_err(|e| anyhow::anyhow!("--nemesis: {e}"))?;
            (!p.is_empty()).then_some(p)
        }
        None => cfg.nemesis.clone(),
    };
    let shim = plan
        .as_ref()
        .map(|p| matchmaker::net::FaultShim::new(id, 0x5eed ^ id as u64, p));
    let handle = matchmaker::net::spawn_node_with_nemesis(id, node, cfg.addrs.clone(), shim)?;
    eprintln!("node {id} ({role}) running");
    if role == "client" {
        std::thread::sleep(std::time::Duration::from_secs(duration));
        handle.shutdown();
    }
    handle.join.join().ok();
    Ok(())
}

/// `repro check` — the model checker CLI (DESIGN.md §Model checking).
fn check(args: &Args) -> Result<()> {
    use matchmaker::check::{instances, run_instance, trace};

    match args.positional.first().map(|s| s.as_str()) {
        Some("list") => {
            for inst in instances::all() {
                println!(
                    "{:<10} depth {:>2} (smoke {:>2}), {} drops, expect {:<13} {}",
                    inst.name,
                    inst.depth,
                    inst.smoke_depth,
                    inst.max_drops,
                    inst.expect_violation.unwrap_or("clean"),
                    inst.about
                );
            }
            Ok(())
        }
        Some("replay") => {
            let path = args
                .positional
                .get(1)
                .context("check replay: missing trace file path")?;
            let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
            let t = trace::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let inst = instances::find(&t.instance)
                .with_context(|| format!("{path}: unknown instance {:?}", t.instance))?;
            let summary = trace::run(&inst, &t).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            println!("{summary}");
            Ok(())
        }
        name => {
            let smoke_mode = match args.flag("mode", "smoke".to_string())?.as_str() {
                "smoke" => true,
                "full" => false,
                other => anyhow::bail!("--mode {other:?}: expected smoke|full"),
            };
            let default_cap: u64 = if smoke_mode { 20_000 } else { 300_000 };
            let max_replays: u64 = args.flag("states", default_cap)?;
            let emit = args.flags.get("emit-trace").map(std::path::PathBuf::from);
            let targets = match name {
                Some(n) => {
                    vec![instances::find(n).with_context(|| {
                        format!("unknown instance {n:?} (try `repro check list`)")
                    })?]
                }
                None => instances::all(),
            };
            let mut failed = false;
            for inst in &targets {
                let default_depth = if smoke_mode { inst.smoke_depth } else { inst.depth };
                let depth: usize = args.flag("depth", default_depth)?;
                match run_instance(inst, depth, max_replays, emit.as_deref()) {
                    Ok(_) => {}
                    Err(e) => {
                        eprintln!("FAIL: {e}");
                        failed = true;
                    }
                }
            }
            if failed {
                std::process::exit(1);
            }
            Ok(())
        }
    }
}

/// `repro sweep` — deterministic parameter-space sweep + regression
/// gate (DESIGN.md §Sweeps).
fn sweep(args: &Args) -> Result<()> {
    use matchmaker::sweep::{self, ParameterSpace, SweepMode};

    let mode_str: String = args.flag("mode", "smoke".to_string())?;
    let mode = SweepMode::parse(&mode_str)
        .with_context(|| format!("--mode {mode_str:?}: expected smoke|full"))?;
    let seed: u64 = args.flag("seed", 42)?;
    let jobs: usize = args.flag("jobs", 0)?;

    // `--only LABEL`: replay one configuration in isolation. Its seed
    // depends only on (root seed, label), so the row matches the same
    // label's row in a full sweep bit for bit.
    if let Some(label) = args.flags.get("only") {
        let cfg = ParameterSpace::default()
            .grid()
            .into_iter()
            .find(|c| &c.label() == label)
            .with_context(|| format!("--only {label:?}: no such configuration in the grid"))?;
        let row = sweep::run_config(&cfg, seed, mode.duration());
        print!("{}", sweep::to_csv(std::slice::from_ref(&row)));
        if let Some(v) = &row.violation {
            anyhow::bail!("configuration {label} violated an invariant: {v}");
        }
        return Ok(());
    }

    let configs = mode.configs(seed);
    eprintln!(
        "sweep {}: running {} configurations ({} jobs requested; 0 = per-core) ...",
        mode.name(),
        configs.len(),
        jobs
    );
    let rows = sweep::run_sweep(&configs, seed, mode.duration(), jobs);
    let bench = sweep::to_bench_json(&rows, mode, seed);
    print!("{}", sweep::render_summary(&rows, mode, seed));

    if let Some(dir) = args.flags.get("out") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
        let json_path = dir.join(format!("BENCH_{}.json", mode.name()));
        std::fs::write(&json_path, bench.to_json())
            .with_context(|| format!("write {}", json_path.display()))?;
        let csv_path = dir.join(format!("SWEEP_{}.csv", mode_str));
        std::fs::write(&csv_path, sweep::to_csv(&rows))
            .with_context(|| format!("write {}", csv_path.display()))?;
        eprintln!("wrote {} and {}", json_path.display(), csv_path.display());
    }

    let violations = rows.iter().filter(|r| r.violation.is_some()).count();

    if let Some(dir) = args.flags.get("compare") {
        match sweep::compare_dir(std::path::Path::new(dir), &bench, seed) {
            Ok(report) => print!("{report}"),
            Err(report) => {
                print!("{report}");
                anyhow::bail!("perf regression gate failed (baselines: {dir})");
            }
        }
    }

    anyhow::ensure!(violations == 0, "{violations} configuration(s) violated invariants");
    Ok(())
}

fn smoke() -> Result<()> {
    use matchmaker::statemachine::tensor::{reference_step, D};
    use matchmaker::statemachine::{StateMachine, TensorStateMachine};
    let mut sm = TensorStateMachine::load().context("initialize tensor state machine")?;
    println!("tensor SM backend: {}", sm.backend_name());
    let cmd: Vec<f32> = (0..D).map(|i| (i as f32) / 8.0).collect();
    let reply = sm.apply(&TensorStateMachine::encode(&cmd));
    let digest = f32::from_le_bytes(reply[..4].try_into().unwrap());
    let (_, ref_digest) = reference_step(&vec![0.0; D * D], &[cmd]);
    println!("tensor SM digest = {digest} (reference {})", ref_digest[0]);
    anyhow::ensure!((digest - ref_digest[0]).abs() < 1e-3, "digest mismatch");
    println!("runtime smoke OK");
    Ok(())
}
