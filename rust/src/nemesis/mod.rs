//! Nemesis: deterministic, scripted fault injection.
//!
//! A [`NemesisPlan`] is an ordered schedule of [`Fault`]s — partitions
//! (symmetric groups and asymmetric one-way cuts), gray failures
//! (slow-but-alive nodes, fsync stalls, duplicated/reordered/corrupted
//! frames at the codec boundary), and clock skew/drift on the lease
//! clock. Plans are plain data with a compact single-line text form, so
//! the same schedule drives both harnesses:
//!
//! * the simulator — [`NemesisPlan::apply_to_sim`] schedules each fault
//!   as a [`crate::sim::Sim::schedule`] control, so injection is part of
//!   the deterministic event stream and every run replays byte-for-byte
//!   from its seed;
//! * the TCP runtime — `repro run --nemesis PLAN` (or a `nemesis =`
//!   config line) parses the same text and drives a fault shim around
//!   the `net::` framing layer plus the WAL fsync path.
//!
//! Probabilities are expressed in **per-mille** (integer 0..=1000) so
//! the text form round-trips exactly — no float formatting ambiguity.
//!
//! ## Text form
//!
//! Events are `AT_MS:FAULT`, joined with `;`. Faults:
//!
//! | syntax               | meaning                                          |
//! |----------------------|--------------------------------------------------|
//! | `part(0,1\|2,3,4)`   | symmetric partition into the listed groups       |
//! | `oneway(6>7)`        | cut only the `6 → 7` direction                   |
//! | `heal`               | restore every cut link (symmetric and one-way)   |
//! | `slow(10,2000)`      | node 10's link delays scaled to 2000% (gray-slow)|
//! | `stall(2,5000)`      | node 2's WAL fsyncs stall 5000 µs (TCP runtime)  |
//! | `skew(6,5000)`       | node 6's clock reads +5000 µs (negative = behind)|
//! | `drift(6,200)`       | node 6's clock drifts +200 ppm                   |
//! | `dup(10)`            | 10‰ of frames duplicated                         |
//! | `reorder(50,2000)`   | 50‰ of frames take +2000 µs (overtaken)          |
//! | `corrupt(5)`         | 5‰ of frames get one bit flipped at the codec    |
//!
//! `slow(n,100)`, `skew(n,0)`, `drift(n,0)`, `stall(n,0)`, `dup(0)`,
//! `reorder(0,0)` and `corrupt(0)` restore the respective knob.
//!
//! See DESIGN.md §Nemesis for the fault taxonomy, the failure-detector
//! timing that tolerates these schedules, and the X12 experiment that
//! gates them.

use crate::sim::Sim;
use crate::util::{splitmix64, Rng};
use crate::{NodeId, Time, MS, US};

/// One injectable fault (or its restoration). See the module docs for
/// the text syntax of each variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Symmetric partition: every link between nodes in *different*
    /// listed groups is cut. Nodes not listed anywhere are unaffected.
    Partition { groups: Vec<Vec<NodeId>> },
    /// Asymmetric cut: only `from → to` is severed; replies still flow.
    /// This is the schedule that lets a deposed leader's stale
    /// heartbeats through one way (the satellite regression in
    /// `sim_cluster.rs`).
    OneWay { from: NodeId, to: NodeId },
    /// Restore every severed link, symmetric and one-way. Does *not*
    /// touch slow/skew/frame knobs — those restore individually.
    Heal,
    /// Gray failure: scale every link delay touching `node` to
    /// `pct`/100 of nominal (`100` restores). The node stays alive and
    /// responsive — just slow, which is harder on failure detectors
    /// than a crash.
    SlowNode { node: NodeId, pct: u64 },
    /// Gray failure on the durability path: each WAL fsync on `node`
    /// takes an extra `stall_us` microseconds (`0` restores). Only
    /// meaningful under the TCP runtime (the simulator has no WAL);
    /// [`NemesisPlan::apply_to_sim`] ignores it.
    FsyncStall { node: NodeId, stall_us: u64 },
    /// Clock skew: `node`'s local clock reads `skew_us` microseconds
    /// ahead (negative = behind) of true time. Exercises lease validity
    /// under the configured max drift.
    ClockSkew { node: NodeId, skew_us: i64 },
    /// Clock drift: `node`'s clock runs fast/slow by `ppm` parts per
    /// million, compounding over the run.
    ClockDrift { node: NodeId, ppm: i64 },
    /// Duplicate `per_mille`‰ of frames (same arrival time, both
    /// delivered).
    Dup { per_mille: u32 },
    /// Reorder `per_mille`‰ of frames by adding `extra_us` µs of delay,
    /// letting later traffic on the same link overtake them.
    Reorder { per_mille: u32, extra_us: u64 },
    /// Flip one random bit in `per_mille`‰ of frames at the codec
    /// boundary; undecodable mutations are dropped by the framing
    /// layer, decodable ones are delivered as-is.
    Corrupt { per_mille: u32 },
}

/// A fault scheduled at an absolute time (milliseconds from run start).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NemesisEvent {
    /// When the fault fires, in milliseconds of (virtual or wall) time.
    pub at_ms: u64,
    /// What happens.
    pub fault: Fault,
}

/// An ordered fault schedule. Parse one with [`NemesisPlan::parse`],
/// render it back with [`NemesisPlan::to_text`] (these round-trip
/// exactly), and inject it with [`NemesisPlan::apply_to_sim`] or the
/// TCP runtime's `--nemesis` flag.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NemesisPlan {
    /// The schedule, in firing order.
    pub events: Vec<NemesisEvent>,
}

impl NemesisPlan {
    /// An empty plan (no faults).
    pub fn none() -> NemesisPlan {
        NemesisPlan::default()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the compact text form (module docs). Whitespace around
    /// separators is tolerated; events are sorted by firing time.
    pub fn parse(text: &str) -> Result<NemesisPlan, String> {
        let mut events = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (at, fault) = part
                .split_once(':')
                .ok_or_else(|| format!("nemesis event `{part}`: expected AT_MS:FAULT"))?;
            let at_ms: u64 = at
                .trim()
                .parse()
                .map_err(|_| format!("nemesis event `{part}`: bad time `{at}`"))?;
            let fault = parse_fault(fault.trim())?;
            events.push(NemesisEvent { at_ms, fault });
        }
        events.sort_by_key(|e| e.at_ms);
        Ok(NemesisPlan { events })
    }

    /// Render the plan back to its text form. `parse(to_text(p)) == p`
    /// for any plan whose events are sorted by time.
    pub fn to_text(&self) -> String {
        let parts: Vec<String> = self
            .events
            .iter()
            .map(|e| format!("{}:{}", e.at_ms, fault_text(&e.fault)))
            .collect();
        parts.join(";")
    }

    /// Schedule every event as a simulator control. Faults mutate the
    /// [`crate::sim::NetworkModel`] through the `Sim` setters, so the
    /// whole schedule is part of the deterministic event stream:
    /// identical seed + identical plan ⇒ byte-identical run.
    pub fn apply_to_sim(&self, sim: &mut Sim) {
        for ev in &self.events {
            let fault = ev.fault.clone();
            sim.schedule(ev.at_ms * MS, move |s| apply_fault(s, &fault));
        }
    }

    /// The merged time windows (in nanoseconds, over a run ending at
    /// `run_end_ms`) during which *any* fault is active — partitions
    /// until the next `heal`, slow/stall/skew/drift/frame knobs until
    /// individually restored. X12 measures goodput *outside* these
    /// windows against the fault-free twin run.
    pub fn fault_windows(&self, run_end_ms: u64) -> Vec<(Time, Time)> {
        use std::collections::BTreeSet;
        let mut active: BTreeSet<String> = BTreeSet::new();
        let mut windows = Vec::new();
        let mut open: Option<u64> = None;
        for ev in &self.events {
            let (key, on) = match &ev.fault {
                Fault::Partition { .. } | Fault::OneWay { .. } => ("net".to_string(), true),
                Fault::Heal => ("net".to_string(), false),
                Fault::SlowNode { node, pct } => (format!("slow:{node}"), *pct != 100),
                Fault::FsyncStall { node, stall_us } => (format!("stall:{node}"), *stall_us != 0),
                Fault::ClockSkew { node, skew_us } => (format!("skew:{node}"), *skew_us != 0),
                Fault::ClockDrift { node, ppm } => (format!("drift:{node}"), *ppm != 0),
                Fault::Dup { per_mille } => ("dup".to_string(), *per_mille != 0),
                Fault::Reorder { per_mille, .. } => ("reorder".to_string(), *per_mille != 0),
                Fault::Corrupt { per_mille } => ("corrupt".to_string(), *per_mille != 0),
            };
            if on {
                active.insert(key);
                if open.is_none() {
                    open = Some(ev.at_ms);
                }
            } else {
                active.remove(&key);
                if active.is_empty() {
                    if let Some(start) = open.take() {
                        if ev.at_ms > start {
                            windows.push((start * MS, ev.at_ms * MS));
                        }
                    }
                }
            }
        }
        if let Some(start) = open {
            if run_end_ms > start {
                windows.push((start * MS, run_end_ms * MS));
            }
        }
        windows
    }

    /// A seeded storm of asymmetric one-way cuts and heals over
    /// `nodes`, for property tests: short directed outages separated by
    /// healed gaps, deterministic in `seed`. Empty when fewer than two
    /// nodes or the run is too short.
    pub fn storm(seed: u64, nodes: &[NodeId], run_ms: u64) -> NemesisPlan {
        let mut rng = Rng::new(splitmix64(seed ^ 0x6e65_6d65_7369_7321));
        let mut events = Vec::new();
        if nodes.len() >= 2 {
            let mut at = 50 + rng.gen_range(100);
            while at + 150 < run_ms {
                let i = rng.gen_range(nodes.len() as u64) as usize;
                let mut j = rng.gen_range(nodes.len() as u64) as usize;
                if j == i {
                    j = (j + 1) % nodes.len();
                }
                events.push(NemesisEvent {
                    at_ms: at,
                    fault: Fault::OneWay { from: nodes[i], to: nodes[j] },
                });
                let heal = at + 60 + rng.gen_range(80);
                events.push(NemesisEvent { at_ms: heal, fault: Fault::Heal });
                at = heal + 80 + rng.gen_range(120);
            }
        }
        NemesisPlan { events }
    }
}

/// Apply one fault to a running simulator (fires inside a scheduled
/// control, at the event's virtual time).
fn apply_fault(sim: &mut Sim, fault: &Fault) {
    match fault {
        Fault::Partition { groups } => {
            for (gi, ga) in groups.iter().enumerate() {
                for gb in groups.iter().skip(gi + 1) {
                    for &a in ga {
                        for &b in gb {
                            sim.set_link(a, b, false);
                        }
                    }
                }
            }
        }
        Fault::OneWay { from, to } => sim.set_link_oneway(*from, *to, false),
        Fault::Heal => {
            let ids = sim.node_ids();
            for (i, &a) in ids.iter().enumerate() {
                for &b in ids.iter().skip(i + 1) {
                    sim.set_link(a, b, true);
                }
            }
            sim.net.cut_oneway.clear();
        }
        Fault::SlowNode { node, pct } => sim.set_node_slow(*node, *pct),
        // The simulator has no WAL: fsync stalls only exist under the
        // TCP runtime (`storage::WalOptions::stall_us`).
        Fault::FsyncStall { .. } => {}
        Fault::ClockSkew { node, skew_us } => {
            sim.set_clock_skew(*node, skew_us.saturating_mul(US as i64))
        }
        Fault::ClockDrift { node, ppm } => sim.set_clock_drift(*node, *ppm),
        Fault::Dup { per_mille } => sim.net.dup_prob = f64::from(*per_mille) / 1000.0,
        Fault::Reorder { per_mille, extra_us } => {
            sim.net.reorder_prob = f64::from(*per_mille) / 1000.0;
            sim.net.reorder_extra = extra_us * US;
        }
        Fault::Corrupt { per_mille } => sim.net.corrupt_prob = f64::from(*per_mille) / 1000.0,
    }
}

fn fault_text(f: &Fault) -> String {
    match f {
        Fault::Partition { groups } => {
            let gs: Vec<String> = groups
                .iter()
                .map(|g| {
                    g.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(",")
                })
                .collect();
            format!("part({})", gs.join("|"))
        }
        Fault::OneWay { from, to } => format!("oneway({from}>{to})"),
        Fault::Heal => "heal".to_string(),
        Fault::SlowNode { node, pct } => format!("slow({node},{pct})"),
        Fault::FsyncStall { node, stall_us } => format!("stall({node},{stall_us})"),
        Fault::ClockSkew { node, skew_us } => format!("skew({node},{skew_us})"),
        Fault::ClockDrift { node, ppm } => format!("drift({node},{ppm})"),
        Fault::Dup { per_mille } => format!("dup({per_mille})"),
        Fault::Reorder { per_mille, extra_us } => format!("reorder({per_mille},{extra_us})"),
        Fault::Corrupt { per_mille } => format!("corrupt({per_mille})"),
    }
}

fn parse_fault(s: &str) -> Result<Fault, String> {
    if s == "heal" {
        return Ok(Fault::Heal);
    }
    let (kind, rest) = s
        .split_once('(')
        .ok_or_else(|| format!("nemesis fault `{s}`: expected KIND(ARGS)"))?;
    let args = rest
        .strip_suffix(')')
        .ok_or_else(|| format!("nemesis fault `{s}`: missing `)`"))?
        .trim();
    let two = |args: &str| -> Result<(String, String), String> {
        args.split_once(',')
            .map(|(a, b)| (a.trim().to_string(), b.trim().to_string()))
            .ok_or_else(|| format!("nemesis fault `{s}`: expected two arguments"))
    };
    match kind.trim() {
        "part" => {
            let mut groups = Vec::new();
            for g in args.split('|') {
                let mut nodes = Vec::new();
                for n in g.split(',') {
                    let n = n.trim();
                    if n.is_empty() {
                        continue;
                    }
                    nodes.push(
                        n.parse::<NodeId>()
                            .map_err(|_| format!("nemesis fault `{s}`: bad node `{n}`"))?,
                    );
                }
                if !nodes.is_empty() {
                    groups.push(nodes);
                }
            }
            if groups.len() < 2 {
                return Err(format!("nemesis fault `{s}`: a partition needs >= 2 groups"));
            }
            Ok(Fault::Partition { groups })
        }
        "oneway" => {
            let (a, b) = args
                .split_once('>')
                .ok_or_else(|| format!("nemesis fault `{s}`: expected FROM>TO"))?;
            let from = a
                .trim()
                .parse()
                .map_err(|_| format!("nemesis fault `{s}`: bad node `{a}`"))?;
            let to = b
                .trim()
                .parse()
                .map_err(|_| format!("nemesis fault `{s}`: bad node `{b}`"))?;
            Ok(Fault::OneWay { from, to })
        }
        "slow" => {
            let (n, p) = two(args)?;
            Ok(Fault::SlowNode {
                node: n.parse().map_err(|_| format!("nemesis fault `{s}`: bad node"))?,
                pct: p.parse().map_err(|_| format!("nemesis fault `{s}`: bad pct"))?,
            })
        }
        "stall" => {
            let (n, us) = two(args)?;
            Ok(Fault::FsyncStall {
                node: n.parse().map_err(|_| format!("nemesis fault `{s}`: bad node"))?,
                stall_us: us.parse().map_err(|_| format!("nemesis fault `{s}`: bad µs"))?,
            })
        }
        "skew" => {
            let (n, us) = two(args)?;
            Ok(Fault::ClockSkew {
                node: n.parse().map_err(|_| format!("nemesis fault `{s}`: bad node"))?,
                skew_us: us.parse().map_err(|_| format!("nemesis fault `{s}`: bad µs"))?,
            })
        }
        "drift" => {
            let (n, ppm) = two(args)?;
            Ok(Fault::ClockDrift {
                node: n.parse().map_err(|_| format!("nemesis fault `{s}`: bad node"))?,
                ppm: ppm.parse().map_err(|_| format!("nemesis fault `{s}`: bad ppm"))?,
            })
        }
        "dup" => Ok(Fault::Dup {
            per_mille: parse_per_mille(s, args)?,
        }),
        "reorder" => {
            let (pm, us) = two(args)?;
            Ok(Fault::Reorder {
                per_mille: parse_per_mille(s, &pm)?,
                extra_us: us.parse().map_err(|_| format!("nemesis fault `{s}`: bad µs"))?,
            })
        }
        "corrupt" => Ok(Fault::Corrupt {
            per_mille: parse_per_mille(s, args)?,
        }),
        other => Err(format!("nemesis fault `{s}`: unknown kind `{other}`")),
    }
}

fn parse_per_mille(ctx: &str, s: &str) -> Result<u32, String> {
    let pm: u32 = s
        .parse()
        .map_err(|_| format!("nemesis fault `{ctx}`: bad per-mille `{s}`"))?;
    if pm > 1000 {
        return Err(format!("nemesis fault `{ctx}`: per-mille `{pm}` > 1000"));
    }
    Ok(pm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{lan_sim, ms};
    use crate::MS;

    fn full_plan() -> NemesisPlan {
        NemesisPlan {
            events: vec![
                NemesisEvent {
                    at_ms: 10,
                    fault: Fault::Partition { groups: vec![vec![0, 1], vec![2, 3, 4]] },
                },
                NemesisEvent { at_ms: 20, fault: Fault::OneWay { from: 6, to: 7 } },
                NemesisEvent { at_ms: 30, fault: Fault::Heal },
                NemesisEvent { at_ms: 40, fault: Fault::SlowNode { node: 10, pct: 2000 } },
                NemesisEvent { at_ms: 50, fault: Fault::FsyncStall { node: 2, stall_us: 5000 } },
                NemesisEvent { at_ms: 60, fault: Fault::ClockSkew { node: 6, skew_us: -4000 } },
                NemesisEvent { at_ms: 70, fault: Fault::ClockDrift { node: 6, ppm: 200 } },
                NemesisEvent { at_ms: 80, fault: Fault::Dup { per_mille: 10 } },
                NemesisEvent { at_ms: 90, fault: Fault::Reorder { per_mille: 50, extra_us: 2000 } },
                NemesisEvent { at_ms: 95, fault: Fault::Corrupt { per_mille: 5 } },
            ],
        }
    }

    #[test]
    fn text_form_round_trips_every_fault() {
        let plan = full_plan();
        let text = plan.to_text();
        let back = NemesisPlan::parse(&text).expect("round-trip parse");
        assert_eq!(back, plan, "parse(to_text(p)) must equal p:\n{text}");
    }

    #[test]
    fn parse_tolerates_whitespace_and_sorts() {
        let plan = NemesisPlan::parse(" 30:heal ; 10:oneway( 1 > 2 ) ;; ").unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].at_ms, 10);
        assert_eq!(plan.events[0].fault, Fault::OneWay { from: 1, to: 2 });
        assert_eq!(plan.events[1].fault, Fault::Heal);
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        for bad in [
            "oops",
            "10:wat(1)",
            "10:part(0,1)",
            "x:heal",
            "10:oneway(1-2)",
            "10:dup(2000)",
            "10:slow(1)",
        ] {
            assert!(NemesisPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn fault_windows_merge_until_restored() {
        let plan = NemesisPlan::parse("10:oneway(0>1);20:slow(2,500);30:heal;40:slow(2,100);60:corrupt(5)")
            .unwrap();
        // net ∪ slow spans 10..40; corrupt is never restored, so it runs
        // to the end of the run.
        assert_eq!(plan.fault_windows(100), vec![(10 * MS, 40 * MS), (60 * MS, 100 * MS)]);
        assert_eq!(NemesisPlan::none().fault_windows(100), vec![]);
    }

    #[test]
    fn apply_to_sim_drives_the_network_model() {
        let mut sim = lan_sim(3);
        let plan = NemesisPlan::parse(
            "1:part(0|1);2:oneway(2>3);3:slow(4,900);4:skew(5,7000);5:dup(250);6:heal",
        )
        .unwrap();
        plan.apply_to_sim(&mut sim);
        sim.run_until(ms(10));
        // Partition + oneway healed at 6ms; the rest persist.
        assert!(sim.link_open(0, 1));
        assert!(sim.link_open(2, 3));
        assert_eq!(sim.net.node_slow_pct.get(&4), Some(&900));
        assert_eq!(sim.net.clock_skew_ns.get(&5), Some(&(7000 * 1000)));
        assert!((sim.net.dup_prob - 0.25).abs() < 1e-9);
    }

    #[test]
    fn storm_is_seed_deterministic() {
        let a = NemesisPlan::storm(7, &[0, 1, 2, 3], 2_000);
        let b = NemesisPlan::storm(7, &[0, 1, 2, 3], 2_000);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // Cuts and heals alternate, every cut is directed.
        assert!(a.events.iter().any(|e| matches!(e.fault, Fault::OneWay { .. })));
        assert!(a.events.iter().any(|e| e.fault == Fault::Heal));
        let c = NemesisPlan::storm(8, &[0, 1, 2, 3], 2_000);
        assert_ne!(a, c, "different seeds should give different storms");
    }
}
