//! TCP runtime: run any [`Node`] as a real networked process.
//!
//! Dependency-free (std::net + threads): frames are length-prefixed binary
//! [`Envelope`]s (see [`crate::codec`]). Each node binds its own address
//! and lazily dials peers, reconnecting on failure — the protocol layer
//! already tolerates dropped messages (resend timers), so the transport
//! stays simple. Timers are served by a dedicated timer thread with a
//! monotonic heap. One thread owns the node; messages and timer
//! expirations are serialized through a channel, preserving the sans-io
//! determinism contract per node.
//!
//! `repro run --role ... --config cluster.conf` (see `main.rs`) uses this
//! to launch a real multi-process deployment.

use crate::codec::{Enc, Wire};
use crate::msg::Envelope;
use crate::node::{Announce, Effects, Node, Timer};
use crate::{NodeId, Time};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Events multiplexed into the node thread.
enum Event {
    Msg(Envelope),
    Timer(Timer),
    Shutdown,
}

/// Encode one frame: u32 BE length + codec bytes.
pub fn encode_frame(env: &Envelope) -> Vec<u8> {
    let body = env.encode();
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_be_bytes());
    frame.extend_from_slice(&body);
    frame
}

/// Encode one frame into a reused scratch buffer: `scratch.buf` holds
/// the u32 BE length prefix + codec bytes afterwards. The per-peer
/// writer threads keep one scratch `Enc` per connection, so steady-state
/// sends allocate nothing (the hot-path allocation satellite; byte-
/// identical to [`encode_frame`]).
pub fn encode_frame_into(env: &Envelope, scratch: &mut Enc) {
    scratch.reset();
    // Reserve the length prefix, encode the body in place, then patch
    // the prefix — one pass, no body copy.
    scratch.buf.extend_from_slice(&[0u8; 4]);
    env.enc(scratch);
    let body_len = (scratch.buf.len() - 4) as u32;
    scratch.buf[..4].copy_from_slice(&body_len.to_be_bytes());
}

/// Largest frame the transport will accept. The length prefix is
/// attacker-/bug-controlled bytes off the wire, and `read_frame`
/// allocates the full body up front — without a cap, one corrupt or
/// malicious prefix is a 4 GiB allocation. 64 MiB comfortably clears
/// every protocol message (snapshot *chunks* are 256 KiB precisely so
/// state transfer never needs giant frames; see
/// [`crate::roles::Replica`]).
pub const MAX_FRAME: usize = 64 << 20;

/// Read one frame from a stream (blocking). Generic over `Read` so the
/// oversize guard is testable against in-memory buffers, not just live
/// sockets.
pub fn read_frame(stream: &mut impl Read) -> Result<Envelope> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame length {len} exceeds MAX_FRAME ({MAX_FRAME} bytes): \
         refusing to allocate — corrupt length prefix, or a message that \
         should be chunked (snapshots travel as SnapshotChunk frames)"
    );
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Envelope::decode(&body).map_err(|e| anyhow::anyhow!("decode: {e}"))
}

/// Per-peer outbound writer with lazy connect + reconnect, running on its
/// own thread. Messages are dropped when the peer is unreachable.
fn spawn_peer_writer(addr: String) -> Sender<Envelope> {
    let (tx, rx): (Sender<Envelope>, Receiver<Envelope>) = channel();
    std::thread::spawn(move || {
        let mut stream: Option<TcpStream> = None;
        // One scratch buffer per connection: frame encoding reuses its
        // allocation across the whole message stream.
        let mut scratch = Enc::new();
        while let Ok(env) = rx.recv() {
            if stream.is_none() {
                match TcpStream::connect(&addr) {
                    Ok(s) => {
                        let _ = s.set_nodelay(true);
                        stream = Some(s);
                    }
                    Err(_) => continue, // drop; resend timers recover
                }
            }
            if let Some(s) = stream.as_mut() {
                encode_frame_into(&env, &mut scratch);
                if s.write_all(&scratch.buf).is_err() {
                    stream = None;
                }
            }
        }
    });
    tx
}

/// Timer service: a thread sleeping until the next deadline.
struct TimerService {
    queue: Arc<Mutex<Vec<(Instant, Timer)>>>,
    tx: Sender<Event>,
}

impl TimerService {
    // The TCP runtime is the one place wall-clock time is allowed: it
    // exists to drive the sans-io roles in real time. Everything under
    // roles/, sim/, and check/ must stay on virtual `Time` (clippy.toml
    // disallowed-methods enforces this).
    #[allow(clippy::disallowed_methods)]
    fn new(tx: Sender<Event>) -> TimerService {
        let queue: Arc<Mutex<Vec<(Instant, Timer)>>> = Arc::new(Mutex::new(Vec::new()));
        let q = queue.clone();
        let out = tx.clone();
        std::thread::spawn(move || loop {
            let next = {
                let mut q = q.lock().unwrap();
                let now = Instant::now();
                // Fire everything due; find the next deadline.
                let mut i = 0;
                while i < q.len() {
                    if q[i].0 <= now {
                        let (_, t) = q.swap_remove(i);
                        if out.send(Event::Timer(t)).is_err() {
                            return;
                        }
                    } else {
                        i += 1;
                    }
                }
                q.iter().map(|(at, _)| *at).min()
            };
            match next {
                Some(at) => {
                    let now = Instant::now();
                    if at > now {
                        std::thread::sleep((at - now).min(std::time::Duration::from_millis(20)));
                    }
                }
                None => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        });
        TimerService { queue, tx }
    }

    #[allow(clippy::disallowed_methods)] // wall clock is this runtime's job; see `new`
    fn arm(&self, delay: Time, t: Timer) {
        self.queue
            .lock()
            .unwrap()
            .push((Instant::now() + std::time::Duration::from_nanos(delay), t));
        let _ = &self.tx; // keep the channel alive via the struct
    }
}

/// Handle for a running node.
pub struct NodeHandle {
    shutdown: Sender<Event>,
    /// Tells the accept loop to stop and release the listening socket
    /// (so a restarted incarnation can rebind the same address — the
    /// crash-recovery harness depends on this).
    stop: Arc<std::sync::atomic::AtomicBool>,
    /// Own address, used to poke the blocking accept loop awake.
    addr: String,
    /// Join handle for the node thread.
    pub join: std::thread::JoinHandle<()>,
    /// Announcements observed (metrics / tests).
    pub announces: Receiver<(Time, Announce)>,
}

impl NodeHandle {
    /// Stop the node's event loop and release its listening socket.
    ///
    /// Nothing is flushed on the way down — the event loop simply stops
    /// and every in-memory structure is dropped. Durability-wise this is
    /// indistinguishable from `kill -9`: a node with a WAL attached
    /// fsyncs each record *before* acting on it, never at exit, so the
    /// crash-recovery harness uses this as its kill switch.
    pub fn shutdown(&self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = self.shutdown.send(Event::Shutdown);
        // Wake the accept loop (blocked in `incoming()`) so it observes
        // the stop flag and drops the listener.
        let _ = TcpStream::connect(&self.addr);
    }
}

/// Start a node: bind `addrs[&id]`, dial peers lazily, run the event loop
/// on a dedicated thread.
#[allow(clippy::disallowed_methods)] // wall clock is this runtime's job; see TimerService
pub fn spawn_node(
    id: NodeId,
    mut node: Box<dyn Node>,
    addrs: BTreeMap<NodeId, String>,
) -> Result<NodeHandle> {
    let my_addr = addrs.get(&id).context("own address missing")?.clone();
    let listener = TcpListener::bind(&my_addr).with_context(|| format!("bind {my_addr}"))?;

    let (ev_tx, ev_rx) = channel::<Event>();
    let (ann_tx, ann_rx) = channel::<(Time, Announce)>();

    // Accept loop. Exits (releasing the listener, so the port can be
    // rebound by a restarted incarnation) when the stop flag is set and
    // `shutdown()` pokes it awake with a dummy connection.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let accept_stop = stop.clone();
    let accept_tx = ev_tx.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            if accept_stop.load(std::sync::atomic::Ordering::SeqCst) {
                break;
            }
            let Ok(mut stream) = stream else { break };
            let tx = accept_tx.clone();
            std::thread::spawn(move || {
                let _ = stream.set_nodelay(true);
                while let Ok(env) = read_frame(&mut stream) {
                    if tx.send(Event::Msg(env)).is_err() {
                        return;
                    }
                }
            });
        }
    });

    let timers = TimerService::new(ev_tx.clone());

    let shutdown_tx = ev_tx.clone();
    let join = std::thread::spawn(move || {
        let start = Instant::now();
        let now = move || start.elapsed().as_nanos() as Time;
        let mut peers: BTreeMap<NodeId, Sender<Envelope>> = BTreeMap::new();

        let apply = |fx: Effects, peers: &mut BTreeMap<NodeId, Sender<Envelope>>| {
            for a in fx.announces {
                let _ = ann_tx.send((now(), a));
            }
            for (delay, timer) in fx.timers {
                timers.arm(delay, timer);
            }
            for (to, msg) in fx.msgs {
                let env = Envelope { from: id, to, msg };
                if to == id {
                    let _ = ev_tx.send(Event::Msg(env));
                    continue;
                }
                let peer = peers.entry(to).or_insert_with(|| {
                    spawn_peer_writer(addrs.get(&to).cloned().unwrap_or_default())
                });
                let _ = peer.send(env);
            }
        };

        let mut fx = Effects::new();
        node.on_start(now(), &mut fx);
        apply(fx, &mut peers);

        while let Ok(ev) = ev_rx.recv() {
            let mut fx = Effects::new();
            match ev {
                Event::Msg(env) => {
                    if env.to != id {
                        continue;
                    }
                    node.on_msg(now(), env.from, env.msg, &mut fx);
                }
                Event::Timer(t) => node.on_timer(now(), t, &mut fx),
                Event::Shutdown => break,
            }
            apply(fx, &mut peers);
        }
    });

    Ok(NodeHandle { shutdown: shutdown_tx, stop, addr: my_addr, join, announces: ann_rx })
}

/// Allocate `n` consecutive loopback addresses starting at `base_port`.
pub fn local_addrs(n: usize, base_port: u16) -> BTreeMap<NodeId, String> {
    (0..n as NodeId)
        .map(|i| (i, format!("127.0.0.1:{}", base_port + i as u16)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Msg;

    #[test]
    fn frame_roundtrip() {
        let env = Envelope { from: 1, to: 2, msg: Msg::StopA };
        let frame = encode_frame(&env);
        assert_eq!(
            u32::from_be_bytes(frame[..4].try_into().unwrap()) as usize,
            frame.len() - 4
        );
        let back = Envelope::decode(&frame[4..]).unwrap();
        assert_eq!(back, env);
    }

    #[test]
    fn scratch_frame_matches_allocating_frame() {
        // The reused-buffer path is byte-identical to encode_frame for
        // every message variant, including back-to-back reuse.
        let mut scratch = Enc::new();
        for m in crate::codec::sample_messages() {
            let env = Envelope { from: 1, to: 2, msg: m };
            encode_frame_into(&env, &mut scratch);
            assert_eq!(scratch.buf, encode_frame(&env));
        }
    }

    #[test]
    fn oversized_frame_rejected_with_descriptive_error() {
        // A deliberately huge SnapshotResp — the exact message class the
        // chunked-transfer protocol exists to avoid — encodes past
        // MAX_FRAME and must be refused at the framing layer before the
        // body allocation happens.
        let env = Envelope {
            from: 1,
            to: 2,
            msg: Msg::SnapshotResp {
                base: 10,
                state: vec![7u8; MAX_FRAME],
                entries: Vec::new(),
            },
        };
        let frame = encode_frame(&env);
        assert!(frame.len() > MAX_FRAME + 4);
        let err = read_frame(&mut std::io::Cursor::new(frame)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("exceeds MAX_FRAME"), "unhelpful error: {msg}");
        assert!(msg.contains("SnapshotChunk"), "error should point at chunking: {msg}");
    }

    #[test]
    fn corrupt_length_prefix_rejected_without_allocating() {
        // A corrupt prefix claiming ~4 GiB must fail fast on the length
        // check — reading it as an allocation size would abort the
        // process long before read_exact ever ran.
        let frame = u32::MAX.to_be_bytes().to_vec();
        let err = read_frame(&mut std::io::Cursor::new(frame)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds MAX_FRAME"));
    }

    #[test]
    fn frame_at_limit_still_accepted() {
        // The guard is about the prefix, not honest big-but-legal
        // frames: just-under-limit messages round-trip.
        let env = Envelope {
            from: 1,
            to: 2,
            msg: Msg::SnapshotResp {
                base: 10,
                state: vec![7u8; 1 << 20],
                entries: Vec::new(),
            },
        };
        let frame = encode_frame(&env);
        let back = read_frame(&mut std::io::Cursor::new(frame)).unwrap();
        assert_eq!(back, env);
    }

    #[test]
    fn local_addrs_dense() {
        let a = local_addrs(3, 9000);
        assert_eq!(a[&0], "127.0.0.1:9000");
        assert_eq!(a[&2], "127.0.0.1:9002");
    }

    // Full TCP cluster round-trips are exercised in tests/net_cluster.rs.
}
