//! TCP runtime: run any [`Node`] as a real networked process.
//!
//! Dependency-free (std::net + threads): frames are length-prefixed binary
//! [`Envelope`]s (see [`crate::codec`]). Each node binds its own address
//! and lazily dials peers, reconnecting on failure — the protocol layer
//! already tolerates dropped messages (resend timers), so the transport
//! stays simple. Timers are served by a dedicated timer thread with a
//! monotonic heap. One thread owns the node; messages and timer
//! expirations are serialized through a channel, preserving the sans-io
//! determinism contract per node.
//!
//! `repro run --role ... --config cluster.conf` (see `main.rs`) uses this
//! to launch a real multi-process deployment.

use crate::codec::{Enc, Wire};
use crate::msg::Envelope;
use crate::node::{Announce, Effects, Node, Timer};
use crate::util::Rng;
use crate::{NodeId, Time};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Events multiplexed into the node thread.
enum Event {
    Msg(Envelope),
    Timer(Timer),
    Shutdown,
}

/// Encode one frame: u32 BE length + codec bytes.
pub fn encode_frame(env: &Envelope) -> Vec<u8> {
    let body = env.encode();
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_be_bytes());
    frame.extend_from_slice(&body);
    frame
}

/// Encode one frame into a reused scratch buffer: `scratch.buf` holds
/// the u32 BE length prefix + codec bytes afterwards. The per-peer
/// writer threads keep one scratch `Enc` per connection, so steady-state
/// sends allocate nothing (the hot-path allocation satellite; byte-
/// identical to [`encode_frame`]).
pub fn encode_frame_into(env: &Envelope, scratch: &mut Enc) {
    scratch.reset();
    // Reserve the length prefix, encode the body in place, then patch
    // the prefix — one pass, no body copy.
    scratch.buf.extend_from_slice(&[0u8; 4]);
    env.enc(scratch);
    let body_len = (scratch.buf.len() - 4) as u32;
    scratch.buf[..4].copy_from_slice(&body_len.to_be_bytes());
}

/// Largest frame the transport will accept. The length prefix is
/// attacker-/bug-controlled bytes off the wire, and `read_frame`
/// allocates the full body up front — without a cap, one corrupt or
/// malicious prefix is a 4 GiB allocation. 64 MiB comfortably clears
/// every protocol message (snapshot *chunks* are 256 KiB precisely so
/// state transfer never needs giant frames; see
/// [`crate::roles::Replica`]).
pub const MAX_FRAME: usize = 64 << 20;

/// Read one frame from a stream (blocking). Generic over `Read` so the
/// oversize guard is testable against in-memory buffers, not just live
/// sockets.
pub fn read_frame(stream: &mut impl Read) -> Result<Envelope> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame length {len} exceeds MAX_FRAME ({MAX_FRAME} bytes): \
         refusing to allocate — corrupt length prefix, or a message that \
         should be chunked (snapshots travel as SnapshotChunk frames)"
    );
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Envelope::decode(&body).map_err(|e| anyhow::anyhow!("decode: {e}"))
}

/// Per-peer outbound writer with lazy connect + reconnect, running on its
/// own thread. Messages are dropped when the peer is unreachable.
fn spawn_peer_writer(addr: String) -> Sender<Envelope> {
    let (tx, rx): (Sender<Envelope>, Receiver<Envelope>) = channel();
    std::thread::spawn(move || {
        let mut stream: Option<TcpStream> = None;
        // One scratch buffer per connection: frame encoding reuses its
        // allocation across the whole message stream.
        let mut scratch = Enc::new();
        while let Ok(env) = rx.recv() {
            if stream.is_none() {
                match TcpStream::connect(&addr) {
                    Ok(s) => {
                        let _ = s.set_nodelay(true);
                        stream = Some(s);
                    }
                    Err(_) => continue, // drop; resend timers recover
                }
            }
            if let Some(s) = stream.as_mut() {
                encode_frame_into(&env, &mut scratch);
                if s.write_all(&scratch.buf).is_err() {
                    stream = None;
                }
            }
        }
    });
    tx
}

/// Timer service: a thread sleeping until the next deadline.
struct TimerService {
    queue: Arc<Mutex<Vec<(Instant, Timer)>>>,
    tx: Sender<Event>,
}

impl TimerService {
    // The TCP runtime is the one place wall-clock time is allowed: it
    // exists to drive the sans-io roles in real time. Everything under
    // roles/, sim/, and check/ must stay on virtual `Time` (clippy.toml
    // disallowed-methods enforces this).
    #[allow(clippy::disallowed_methods)]
    fn new(tx: Sender<Event>) -> TimerService {
        let queue: Arc<Mutex<Vec<(Instant, Timer)>>> = Arc::new(Mutex::new(Vec::new()));
        let q = queue.clone();
        let out = tx.clone();
        std::thread::spawn(move || loop {
            let next = {
                let mut q = q.lock().unwrap();
                let now = Instant::now();
                // Fire everything due; find the next deadline.
                let mut i = 0;
                while i < q.len() {
                    if q[i].0 <= now {
                        let (_, t) = q.swap_remove(i);
                        if out.send(Event::Timer(t)).is_err() {
                            return;
                        }
                    } else {
                        i += 1;
                    }
                }
                q.iter().map(|(at, _)| *at).min()
            };
            match next {
                Some(at) => {
                    let now = Instant::now();
                    if at > now {
                        std::thread::sleep((at - now).min(std::time::Duration::from_millis(20)));
                    }
                }
                None => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        });
        TimerService { queue, tx }
    }

    #[allow(clippy::disallowed_methods)] // wall clock is this runtime's job; see `new`
    fn arm(&self, delay: Time, t: Timer) {
        self.queue
            .lock()
            .unwrap()
            .push((Instant::now() + std::time::Duration::from_nanos(delay), t));
        let _ = &self.tx; // keep the channel alive via the struct
    }
}

/// Wall-clock fault shim around the framing layer: the TCP runtime's
/// half of the nemesis subsystem (`repro run --nemesis PLAN` or a
/// `nemesis =` config line; DESIGN.md §Nemesis).
///
/// Each process evaluates the *same* plan against wall-clock offsets
/// from its own start, filtering its **egress**: a symmetric partition
/// is both endpoints cutting their own outbound direction, a one-way
/// cut is sender-side only, so one shared plan text coordinates a whole
/// deployment without any cross-process channel. Frame faults
/// (duplicate / reorder-by-delay / corrupt-at-the-codec) draw from a
/// per-process seeded [`Rng`]; clock skew shifts the `now()` the node
/// thread feeds its role (the lease clock), and fsync stalls arm the
/// WAL-side knob ([`crate::storage::wal::set_fsync_stall_us`]).
///
/// Unlike the simulator's injection this is *not* byte-replayable —
/// wall clocks and thread scheduling see to that. The determinism gate
/// (X12) runs on the sim; this shim exists so real deployments face the
/// same weather.
pub struct FaultShim {
    state: Arc<Mutex<ShimState>>,
    /// Observed-clock offset for this node (nanoseconds, may be negative).
    skew_ns: Arc<std::sync::atomic::AtomicI64>,
}

struct ShimState {
    rng: Rng,
    /// Directed cuts: egress `(from, to)` pairs currently severed.
    cut: std::collections::BTreeSet<(NodeId, NodeId)>,
    /// Per-node gray-slow percent (100 = nominal). Each affected
    /// endpoint adds `pct × 10 µs` of egress delay.
    slow_pct: BTreeMap<NodeId, u64>,
    dup_prob: f64,
    reorder_prob: f64,
    reorder_extra_us: u64,
    corrupt_prob: f64,
}

impl FaultShim {
    /// Build the shim for node `id` and start the schedule thread: each
    /// plan event fires at its `at_ms` offset from now.
    #[allow(clippy::disallowed_methods)] // wall clock is this runtime's job; see TimerService
    pub fn new(id: NodeId, seed: u64, plan: &crate::nemesis::NemesisPlan) -> FaultShim {
        use crate::nemesis::Fault;
        let state = Arc::new(Mutex::new(ShimState {
            rng: Rng::new(crate::util::splitmix64(seed ^ (0xfa17_0000 + id as u64))),
            cut: Default::default(),
            slow_pct: BTreeMap::new(),
            dup_prob: 0.0,
            reorder_prob: 0.0,
            reorder_extra_us: 0,
            corrupt_prob: 0.0,
        }));
        let skew_ns = Arc::new(std::sync::atomic::AtomicI64::new(0));
        let events = plan.events.clone();
        let st = state.clone();
        let sk = skew_ns.clone();
        std::thread::spawn(move || {
            let start = Instant::now();
            for ev in events {
                let at = std::time::Duration::from_millis(ev.at_ms);
                let elapsed = start.elapsed();
                if at > elapsed {
                    std::thread::sleep(at - elapsed);
                }
                let mut s = st.lock().unwrap();
                match ev.fault {
                    Fault::Partition { groups } => {
                        for (gi, ga) in groups.iter().enumerate() {
                            for gb in groups.iter().skip(gi + 1) {
                                for &a in ga {
                                    for &b in gb {
                                        s.cut.insert((a, b));
                                        s.cut.insert((b, a));
                                    }
                                }
                            }
                        }
                    }
                    Fault::OneWay { from, to } => {
                        s.cut.insert((from, to));
                    }
                    Fault::Heal => s.cut.clear(),
                    Fault::SlowNode { node, pct } => {
                        if pct == 100 {
                            s.slow_pct.remove(&node);
                        } else {
                            s.slow_pct.insert(node, pct);
                        }
                    }
                    Fault::FsyncStall { node, stall_us } => {
                        if node == id {
                            crate::storage::wal::set_fsync_stall_us(stall_us);
                        }
                    }
                    Fault::ClockSkew { node, skew_us } => {
                        if node == id {
                            sk.store(
                                skew_us.saturating_mul(1000),
                                std::sync::atomic::Ordering::Relaxed,
                            );
                        }
                    }
                    // Wall clocks drift on their own; the simulator is
                    // where drift is modeled precisely.
                    Fault::ClockDrift { .. } => {}
                    Fault::Dup { per_mille } => s.dup_prob = f64::from(per_mille) / 1000.0,
                    Fault::Reorder { per_mille, extra_us } => {
                        s.reorder_prob = f64::from(per_mille) / 1000.0;
                        s.reorder_extra_us = extra_us;
                    }
                    Fault::Corrupt { per_mille } => {
                        s.corrupt_prob = f64::from(per_mille) / 1000.0
                    }
                }
            }
        });
        FaultShim { state, skew_ns }
    }

    /// The node's current observed-clock offset in nanoseconds.
    fn skew_handle(&self) -> Arc<std::sync::atomic::AtomicI64> {
        self.skew_ns.clone()
    }

    /// Filter one egress envelope: `[]` = dropped (cut link or
    /// undecodable corruption), otherwise one or two (duplicated)
    /// copies, each with an extra delay in microseconds (gray-slow /
    /// reorder).
    pub fn outbound(&self, env: Envelope) -> Vec<(Envelope, u64)> {
        let mut s = self.state.lock().unwrap();
        if s.cut.contains(&(env.from, env.to)) {
            return Vec::new();
        }
        let env = if s.corrupt_prob > 0.0 && {
            let p = s.corrupt_prob;
            s.rng.chance(p)
        } {
            // One bit flipped at the codec boundary, exactly like the
            // simulator's `corrupt_at_codec`: undecodable frames die at
            // the framing layer, decodable mutations are delivered.
            let mut bytes = env.msg.encode();
            if bytes.is_empty() {
                return Vec::new();
            }
            let idx = s.rng.gen_range(bytes.len() as u64) as usize;
            let bit = 1u8 << (s.rng.gen_range(8) as u8);
            bytes[idx] ^= bit;
            match crate::msg::Msg::decode(&bytes) {
                Ok(msg) => Envelope { msg, ..env },
                Err(_) => return Vec::new(),
            }
        } else {
            env
        };
        let mut delay_us = 0u64;
        for end in [env.from, env.to] {
            if let Some(pct) = s.slow_pct.get(&end) {
                delay_us += pct.saturating_mul(10);
            }
        }
        if s.reorder_prob > 0.0 && {
            let p = s.reorder_prob;
            s.rng.chance(p)
        } {
            delay_us += s.reorder_extra_us;
        }
        let dup = s.dup_prob > 0.0 && {
            let p = s.dup_prob;
            s.rng.chance(p)
        };
        let mut out = Vec::with_capacity(if dup { 2 } else { 1 });
        if dup {
            out.push((env.clone(), delay_us));
        }
        out.push((env, delay_us));
        out
    }
}

/// Handle for a running node.
pub struct NodeHandle {
    shutdown: Sender<Event>,
    /// Tells the accept loop to stop and release the listening socket
    /// (so a restarted incarnation can rebind the same address — the
    /// crash-recovery harness depends on this).
    stop: Arc<std::sync::atomic::AtomicBool>,
    /// Own address, used to poke the blocking accept loop awake.
    addr: String,
    /// Join handle for the node thread.
    pub join: std::thread::JoinHandle<()>,
    /// Announcements observed (metrics / tests).
    pub announces: Receiver<(Time, Announce)>,
}

impl NodeHandle {
    /// Stop the node's event loop and release its listening socket.
    ///
    /// Nothing is flushed on the way down — the event loop simply stops
    /// and every in-memory structure is dropped. Durability-wise this is
    /// indistinguishable from `kill -9`: a node with a WAL attached
    /// fsyncs each record *before* acting on it, never at exit, so the
    /// crash-recovery harness uses this as its kill switch.
    pub fn shutdown(&self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = self.shutdown.send(Event::Shutdown);
        // Wake the accept loop (blocked in `incoming()`) so it observes
        // the stop flag and drops the listener.
        let _ = TcpStream::connect(&self.addr);
    }
}

/// Start a node: bind `addrs[&id]`, dial peers lazily, run the event loop
/// on a dedicated thread.
pub fn spawn_node(
    id: NodeId,
    node: Box<dyn Node>,
    addrs: BTreeMap<NodeId, String>,
) -> Result<NodeHandle> {
    spawn_node_with_nemesis(id, node, addrs, None)
}

/// [`spawn_node`] with an optional [`FaultShim`] filtering every egress
/// frame and skewing the node's observed clock (`repro run --nemesis`).
#[allow(clippy::disallowed_methods)] // wall clock is this runtime's job; see TimerService
pub fn spawn_node_with_nemesis(
    id: NodeId,
    mut node: Box<dyn Node>,
    addrs: BTreeMap<NodeId, String>,
    shim: Option<FaultShim>,
) -> Result<NodeHandle> {
    let my_addr = addrs.get(&id).context("own address missing")?.clone();
    let listener = TcpListener::bind(&my_addr).with_context(|| format!("bind {my_addr}"))?;

    let (ev_tx, ev_rx) = channel::<Event>();
    let (ann_tx, ann_rx) = channel::<(Time, Announce)>();

    // Accept loop. Exits (releasing the listener, so the port can be
    // rebound by a restarted incarnation) when the stop flag is set and
    // `shutdown()` pokes it awake with a dummy connection.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let accept_stop = stop.clone();
    let accept_tx = ev_tx.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            if accept_stop.load(std::sync::atomic::Ordering::SeqCst) {
                break;
            }
            let Ok(mut stream) = stream else { break };
            let tx = accept_tx.clone();
            std::thread::spawn(move || {
                let _ = stream.set_nodelay(true);
                while let Ok(env) = read_frame(&mut stream) {
                    if tx.send(Event::Msg(env)).is_err() {
                        return;
                    }
                }
            });
        }
    });

    let timers = TimerService::new(ev_tx.clone());

    let shutdown_tx = ev_tx.clone();
    let join = std::thread::spawn(move || {
        let start = Instant::now();
        // The nemesis clock-skew fault shifts what this node *observes*
        // (its lease clock), never the transport itself.
        let skew = shim.as_ref().map(FaultShim::skew_handle);
        let now = move || {
            let raw = start.elapsed().as_nanos() as i128;
            let adj = skew
                .as_ref()
                .map_or(0, |s| s.load(std::sync::atomic::Ordering::Relaxed))
                as i128;
            (raw + adj).max(0) as Time
        };
        let mut peers: BTreeMap<NodeId, Sender<Envelope>> = BTreeMap::new();

        let apply = |fx: Effects, peers: &mut BTreeMap<NodeId, Sender<Envelope>>| {
            for a in fx.announces {
                let _ = ann_tx.send((now(), a));
            }
            for (delay, timer) in fx.timers {
                timers.arm(delay, timer);
            }
            for (to, msg) in fx.msgs {
                let env = Envelope { from: id, to, msg };
                if to == id {
                    let _ = ev_tx.send(Event::Msg(env));
                    continue;
                }
                let copies = match &shim {
                    Some(s) => s.outbound(env),
                    None => vec![(env, 0)],
                };
                for (env, delay_us) in copies {
                    let peer = peers.entry(env.to).or_insert_with(|| {
                        spawn_peer_writer(addrs.get(&env.to).cloned().unwrap_or_default())
                    });
                    if delay_us == 0 {
                        let _ = peer.send(env);
                    } else {
                        // Gray-slow / reorder: hold the frame off-thread so
                        // the node loop never blocks on injected latency.
                        let tx = peer.clone();
                        std::thread::spawn(move || {
                            std::thread::sleep(std::time::Duration::from_micros(delay_us));
                            let _ = tx.send(env);
                        });
                    }
                }
            }
        };

        let mut fx = Effects::new();
        node.on_start(now(), &mut fx);
        apply(fx, &mut peers);

        while let Ok(ev) = ev_rx.recv() {
            let mut fx = Effects::new();
            match ev {
                Event::Msg(env) => {
                    if env.to != id {
                        continue;
                    }
                    node.on_msg(now(), env.from, env.msg, &mut fx);
                }
                Event::Timer(t) => node.on_timer(now(), t, &mut fx),
                Event::Shutdown => break,
            }
            apply(fx, &mut peers);
        }
    });

    Ok(NodeHandle { shutdown: shutdown_tx, stop, addr: my_addr, join, announces: ann_rx })
}

/// Allocate `n` consecutive loopback addresses starting at `base_port`.
pub fn local_addrs(n: usize, base_port: u16) -> BTreeMap<NodeId, String> {
    (0..n as NodeId)
        .map(|i| (i, format!("127.0.0.1:{}", base_port + i as u16)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Msg;

    #[test]
    fn frame_roundtrip() {
        let env = Envelope { from: 1, to: 2, msg: Msg::StopA };
        let frame = encode_frame(&env);
        assert_eq!(
            u32::from_be_bytes(frame[..4].try_into().unwrap()) as usize,
            frame.len() - 4
        );
        let back = Envelope::decode(&frame[4..]).unwrap();
        assert_eq!(back, env);
    }

    #[test]
    fn scratch_frame_matches_allocating_frame() {
        // The reused-buffer path is byte-identical to encode_frame for
        // every message variant, including back-to-back reuse.
        let mut scratch = Enc::new();
        for m in crate::codec::sample_messages() {
            let env = Envelope { from: 1, to: 2, msg: m };
            encode_frame_into(&env, &mut scratch);
            assert_eq!(scratch.buf, encode_frame(&env));
        }
    }

    #[test]
    fn oversized_frame_rejected_with_descriptive_error() {
        // A deliberately huge SnapshotResp — the exact message class the
        // chunked-transfer protocol exists to avoid — encodes past
        // MAX_FRAME and must be refused at the framing layer before the
        // body allocation happens.
        let env = Envelope {
            from: 1,
            to: 2,
            msg: Msg::SnapshotResp {
                base: 10,
                state: vec![7u8; MAX_FRAME],
                entries: Vec::new(),
            },
        };
        let frame = encode_frame(&env);
        assert!(frame.len() > MAX_FRAME + 4);
        let err = read_frame(&mut std::io::Cursor::new(frame)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("exceeds MAX_FRAME"), "unhelpful error: {msg}");
        assert!(msg.contains("SnapshotChunk"), "error should point at chunking: {msg}");
    }

    #[test]
    fn corrupt_length_prefix_rejected_without_allocating() {
        // A corrupt prefix claiming ~4 GiB must fail fast on the length
        // check — reading it as an allocation size would abort the
        // process long before read_exact ever ran.
        let frame = u32::MAX.to_be_bytes().to_vec();
        let err = read_frame(&mut std::io::Cursor::new(frame)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds MAX_FRAME"));
    }

    #[test]
    fn frame_at_limit_still_accepted() {
        // The guard is about the prefix, not honest big-but-legal
        // frames: just-under-limit messages round-trip.
        let env = Envelope {
            from: 1,
            to: 2,
            msg: Msg::SnapshotResp {
                base: 10,
                state: vec![7u8; 1 << 20],
                entries: Vec::new(),
            },
        };
        let frame = encode_frame(&env);
        let back = read_frame(&mut std::io::Cursor::new(frame)).unwrap();
        assert_eq!(back, env);
    }

    #[test]
    fn local_addrs_dense() {
        let a = local_addrs(3, 9000);
        assert_eq!(a[&0], "127.0.0.1:9000");
        assert_eq!(a[&2], "127.0.0.1:9002");
    }

    #[test]
    fn fault_shim_filters_egress() {
        let shim = FaultShim::new(1, 7, &crate::nemesis::NemesisPlan::none());
        let env = |to| Envelope { from: 1, to, msg: Msg::StopA };
        // Clean shim: one undelayed copy.
        assert_eq!(shim.outbound(env(2)), vec![(env(2), 0)]);
        {
            let mut s = shim.state.lock().unwrap();
            s.cut.insert((1, 2));
            s.slow_pct.insert(3, 2000);
        }
        // Cut link: dropped. Uncut destination from a gray-slow peer:
        // delivered late.
        assert!(shim.outbound(env(2)).is_empty());
        assert_eq!(shim.outbound(env(3)), vec![(env(3), 20_000)]);
        // Certain duplication: exactly two copies.
        shim.state.lock().unwrap().dup_prob = 1.0;
        assert_eq!(shim.outbound(env(3)).len(), 2);
        // Certain corruption either mutates (still decodable) or drops;
        // across many frames both must be sane (never panics, never
        // yields a frame the codec would reject downstream).
        {
            let mut s = shim.state.lock().unwrap();
            s.dup_prob = 0.0;
            s.slow_pct.clear();
            s.corrupt_prob = 1.0;
        }
        let mut delivered = 0;
        for _ in 0..64 {
            delivered += shim.outbound(env(3)).len();
        }
        assert!(delivered > 0, "single-bit flips should often stay decodable");
    }

    #[test]
    #[allow(clippy::disallowed_methods)] // wall-clock polling is this runtime's job
    fn fault_shim_schedule_thread_applies_events() {
        // A plan firing at 0 ms is applied by the schedule thread almost
        // immediately; poll briefly rather than assuming scheduling.
        let plan = crate::nemesis::NemesisPlan::parse("0:oneway(1>2)").unwrap();
        let shim = FaultShim::new(1, 7, &plan);
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if shim.state.lock().unwrap().cut.contains(&(1, 2)) {
                break;
            }
            assert!(Instant::now() < deadline, "schedule thread never applied the cut");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    // Full TCP cluster round-trips are exercised in tests/net_cluster.rs.
}
