//! The machine-checked protocol invariant catalog.
//!
//! Each [`Invariant`] consumes the simulator's announcement stream
//! ([`crate::sim::Sim::announces`]) — including the model-checker probe
//! variants of [`Announce`] — and reports the first violation it sees.
//! The catalog is evaluated incrementally after *every* explored event
//! ([`InvariantSet::feed`]), so a violating schedule is caught at the
//! exact step that breaks the property, and the set's [`digest`]
//! participates in state fingerprints so two paths with different
//! violation-relevant history never merge in the explorer's dedup table.
//!
//! The catalog (paper references per invariant):
//!
//! | name                  | property                                     |
//! |-----------------------|----------------------------------------------|
//! | `chosen-unique`       | ≤1 value per (group, slot) — §3 Theorem 1    |
//! | `quorum-intersection` | every P1 quorum meets every P2 quorum — §3.2 |
//! | `matchmaker-monotonic`| MatchB rounds non-decreasing, ≥ GC watermark — Alg. 1/4 |
//! | `mm-merge`            | Figure-7 merge of stopped logs is correct — §6 |
//! | `lease-fence`         | old grants expire before a new fence lifts    |
//! | `lease-disjoint-under-skew` | lease-fence with a clock-drift envelope: old grants expire ≥ `max_drift` before the fence lifts |
//! | `watermark-order`     | truncate ≤ executed/durable; snapshots advance |
//! | `client-fifo`         | per-client exactly-once / FIFO execution order |
//! | `recovery-sound`      | WAL replay restores ≥ everything durably acked — DESIGN.md §Durability |
//!
//! [`digest`]: InvariantSet::digest

use crate::config::Configuration;
use crate::msg::{Command, MmLog, Value};
use crate::node::Announce;
use crate::round::Round;
use crate::util::Fnv;
use crate::{GroupId, NodeId, Slot, Time, US};
use std::collections::BTreeMap;
use std::fmt;

/// A violated invariant: which one, where in the run, and why.
#[derive(Clone, Debug)]
pub struct Violation {
    /// [`Invariant::name`] of the violated invariant.
    pub invariant: &'static str,
    /// Virtual time of the violating announcement (0 for end-of-run
    /// checks).
    pub at: Time,
    /// Node that emitted the violating announcement.
    pub node: NodeId,
    /// Human-readable explanation.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invariant {} violated at t={} by node {}: {}",
            self.invariant, self.at, self.node, self.detail
        )
    }
}

/// A machine-checked protocol property over the announcement stream.
///
/// Implementations are incremental state machines: [`observe`] feeds one
/// announcement (with its timestamp and emitting node) and returns the
/// violation message if the property just broke. [`digest`] must hash all
/// state that future verdicts depend on — it feeds the explorer's state
/// fingerprints. [`finish`] runs once at a *terminal* state (quiescent,
/// nothing left to deliver) for properties that are only required
/// eventually (e.g. FIFO contiguity).
///
/// [`observe`]: Invariant::observe
/// [`digest`]: Invariant::digest
/// [`finish`]: Invariant::finish
pub trait Invariant {
    /// Stable kebab-case name (used in traces and `expect` lines).
    fn name(&self) -> &'static str;

    /// Feed one announcement; `Err` describes the violation.
    fn observe(&mut self, at: Time, node: NodeId, a: &Announce) -> Result<(), String>;

    /// FNV-1a digest of all verdict-relevant internal state.
    fn digest(&self) -> u64;

    /// End-of-run check at a terminal (quiescent) state.
    fn finish(&self) -> Result<(), String> {
        Ok(())
    }
}

fn fnv_of(s: &str) -> u64 {
    let mut h = Fnv::new();
    h.write_str(s);
    h.finish()
}

// ---------------------------------------------------------------------
// chosen-unique
// ---------------------------------------------------------------------

/// §3 Theorem 1: at most one value is ever chosen per `(group, slot)`,
/// across all rounds, leaders, and configurations. The generalization of
/// [`crate::sim::Sim::check_chosen_safety`] to incremental evaluation.
#[derive(Default)]
struct ChosenUnique {
    chosen: BTreeMap<(GroupId, Slot), Value>,
}

impl Invariant for ChosenUnique {
    fn name(&self) -> &'static str {
        "chosen-unique"
    }

    fn observe(&mut self, _at: Time, _node: NodeId, a: &Announce) -> Result<(), String> {
        let (group, slot, value) = match a {
            Announce::Chosen { group, slot, value, .. } => (*group, *slot, value),
            _ => return Ok(()),
        };
        match self.chosen.get(&(group, slot)) {
            None => {
                self.chosen.insert((group, slot), value.clone());
                Ok(())
            }
            Some(prev) if prev == value => Ok(()),
            Some(prev) => Err(format!(
                "group {group} slot {slot}: two distinct values chosen: {prev:?} then {value:?}"
            )),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        for ((g, s), v) in &self.chosen {
            h.write_u64(*g as u64);
            h.write_u64(*s);
            h.write_str(&format!("{v:?}"));
        }
        h.finish()
    }
}

// ---------------------------------------------------------------------
// quorum-intersection
// ---------------------------------------------------------------------

/// §3.2 (Theorem 1's precondition): in every configuration a leader
/// activates, every Phase-1 quorum intersects every Phase-2 quorum.
/// Stateless — the property is per-announcement.
struct QuorumIntersection;

impl Invariant for QuorumIntersection {
    fn name(&self) -> &'static str {
        "quorum-intersection"
    }

    fn observe(&mut self, _at: Time, _node: NodeId, a: &Announce) -> Result<(), String> {
        let Announce::QuorumConfig { group, round, config } = a else {
            return Ok(());
        };
        if let Err(e) = config.validate() {
            return Err(format!(
                "group {group} round {round:?}: activated invalid configuration {config:?}: {e}"
            ));
        }
        if !config.quorum.intersects(config.acceptors.len()) {
            return Err(format!(
                "group {group} round {round:?}: some P1 quorum misses some P2 quorum in {config:?}"
            ));
        }
        Ok(())
    }

    fn digest(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------
// matchmaker-monotonic
// ---------------------------------------------------------------------

/// Algorithm 1's refusal discipline and Algorithm 4's GC watermark, per
/// (matchmaker, group): the rounds a matchmaker answers `MatchB` for
/// never decrease, never dip below its GC watermark, and the watermark
/// itself only rises. Resets on [`Announce::NodeRestarted`] (a fresh
/// incarnation legitimately starts over).
#[derive(Default)]
struct MatchmakerMonotonic {
    answered: BTreeMap<(NodeId, GroupId), Round>,
    gc: BTreeMap<(NodeId, GroupId), Round>,
}

impl Invariant for MatchmakerMonotonic {
    fn name(&self) -> &'static str {
        "matchmaker-monotonic"
    }

    fn observe(&mut self, _at: Time, node: NodeId, a: &Announce) -> Result<(), String> {
        match a {
            Announce::MatchAnswered { group, round } => {
                if let Some(prev) = self.answered.get(&(node, *group)) {
                    if round < prev {
                        return Err(format!(
                            "matchmaker {node} group {group}: answered round {round:?} after \
                             {prev:?} (refusal discipline requires non-decreasing rounds)"
                        ));
                    }
                }
                if let Some(w) = self.gc.get(&(node, *group)) {
                    if round < w {
                        return Err(format!(
                            "matchmaker {node} group {group}: answered round {round:?} below \
                             its GC watermark {w:?}"
                        ));
                    }
                }
                self.answered.insert((node, *group), *round);
                Ok(())
            }
            Announce::MmGc { group, round } => {
                if let Some(prev) = self.gc.get(&(node, *group)) {
                    if round < prev {
                        return Err(format!(
                            "matchmaker {node} group {group}: GC watermark regressed \
                             {prev:?} -> {round:?}"
                        ));
                    }
                }
                self.gc.insert((node, *group), *round);
                Ok(())
            }
            Announce::NodeRestarted { node: n } => {
                self.answered.retain(|(id, _), _| id != n);
                self.gc.retain(|(id, _), _| id != n);
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str(&format!("{:?}|{:?}", self.answered, self.gc));
        h.finish()
    }
}

// ---------------------------------------------------------------------
// mm-merge
// ---------------------------------------------------------------------

/// §6 / Figure 7: when a leader merges the logs of `f+1` stopped
/// matchmakers, the merged log must be, per group, the union of the
/// input logs with every entry below the maximum input watermark
/// removed, and the merged watermarks the pointwise maxima. This is an
/// independent re-derivation from the announced *inputs* — it does not
/// call [`crate::roles::matchmaker::merge_stopped`], so a bug there
/// (or an announcement that misreports its inputs) is caught.
struct MmMergeConsistent;

impl Invariant for MmMergeConsistent {
    fn name(&self) -> &'static str {
        "mm-merge"
    }

    fn observe(&mut self, _at: Time, node: NodeId, a: &Announce) -> Result<(), String> {
        let Announce::MmMerged { inputs, merged, watermarks } = a else {
            return Ok(());
        };
        let mut want_wms: BTreeMap<GroupId, Round> = BTreeMap::new();
        for (_, wms) in inputs {
            for (g, w) in wms {
                let e = want_wms.entry(*g).or_insert(*w);
                if w > e {
                    *e = *w;
                }
            }
        }
        let mut want: MmLog = BTreeMap::new();
        for (log, _) in inputs {
            for (g, glog) in log {
                let keep = want.entry(*g).or_default();
                for (r, c) in glog {
                    if want_wms.get(g).is_some_and(|w| r < w) {
                        continue;
                    }
                    keep.insert(*r, c.clone());
                }
            }
        }
        if &want != merged {
            return Err(format!(
                "leader {node}: merged matchmaker log {merged:?} differs from the Figure-7 \
                 merge of its inputs {want:?}"
            ));
        }
        if &want_wms != watermarks {
            return Err(format!(
                "leader {node}: merged watermarks {watermarks:?} differ from pointwise maxima \
                 {want_wms:?}"
            ));
        }
        Ok(())
    }

    fn digest(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------
// lease-fence
// ---------------------------------------------------------------------

/// Lease safety (DESIGN.md §Reads): a new leader's post-election fence
/// for round `r'` may only lift once every read-lease grant issued under
/// a lower round has expired — otherwise an old leaseholder could serve
/// a stale read concurrently with the new configuration choosing writes.
#[derive(Default)]
struct LeaseFence {
    /// Per grant round: the latest `valid_until` ever granted.
    grants: BTreeMap<Round, Time>,
}

impl Invariant for LeaseFence {
    fn name(&self) -> &'static str {
        "lease-fence"
    }

    fn observe(&mut self, at: Time, node: NodeId, a: &Announce) -> Result<(), String> {
        match a {
            Announce::LeaseGranted { round, valid_until } => {
                let e = self.grants.entry(*round).or_insert(0);
                if *valid_until > *e {
                    *e = *valid_until;
                }
                Ok(())
            }
            Announce::FenceLifted { round } => {
                for (r, vu) in &self.grants {
                    if r < round && *vu > at {
                        return Err(format!(
                            "leader {node}: fence for {round:?} lifted at t={at} while a \
                             grant under {r:?} is still valid until t={vu}"
                        ));
                    }
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        for (r, vu) in &self.grants {
            h.write_str(&format!("{r:?}"));
            h.write_u64(*vu);
        }
        h.finish()
    }
}

// ---------------------------------------------------------------------
// lease-disjoint-under-skew
// ---------------------------------------------------------------------

/// Default drift envelope for `lease-disjoint-under-skew` in the
/// standard and strict catalogs: 1µs, matching the floor
/// [`crate::config::LeaseSpec::every`] clamps `drift` to.
pub const DEFAULT_DRIFT_ENVELOPE: Time = US;

/// `lease-fence` hardened by a clock-drift envelope (DESIGN.md
/// §Nemesis): no two leaders hold overlapping lease validity given the
/// maximum modeled drift. Plain `lease-fence` accepts a fence that lifts
/// the very nanosecond the last lower-round grant expires; with real
/// clocks that is only safe if every clock agrees on that nanosecond.
/// This variant requires the margin the protocol actually promises: at
/// `FenceLifted` for round `r'`, every grant issued under `r < r'` must
/// have been expired for at least `max_drift` — so a grant holder whose
/// clock runs `max_drift` behind still cannot consider its lease valid
/// while the new leader starts choosing writes.
///
/// The leader guarantees a `2 × LeaseSpec::drift` gap by construction
/// (grants shave `drift` off their announced validity and the
/// post-election fence waits `duration + drift`), so the catalog is
/// sound whenever `max_drift ≤ 2 × LeaseSpec::drift`. The default
/// envelope is [`DEFAULT_DRIFT_ENVELOPE`]; nemesis runs that inject
/// clock skew widen it to the injected bound via
/// [`InvariantSet::standard_with_drift`].
struct LeaseDisjointUnderSkew {
    max_drift: Time,
    /// Per grant round: the latest `valid_until` ever granted.
    grants: BTreeMap<Round, Time>,
}

impl Invariant for LeaseDisjointUnderSkew {
    fn name(&self) -> &'static str {
        "lease-disjoint-under-skew"
    }

    fn observe(&mut self, at: Time, node: NodeId, a: &Announce) -> Result<(), String> {
        match a {
            Announce::LeaseGranted { round, valid_until } => {
                let e = self.grants.entry(*round).or_insert(0);
                if *valid_until > *e {
                    *e = *valid_until;
                }
                Ok(())
            }
            Announce::FenceLifted { round } => {
                for (r, vu) in &self.grants {
                    if r < round && vu.saturating_add(self.max_drift) > at {
                        return Err(format!(
                            "leader {node}: fence for {round:?} lifted at t={at}, but a \
                             grant under {r:?} valid until t={vu} is inside the drift \
                             envelope ({} ns): a clock running behind could still \
                             consider the old lease valid",
                            self.max_drift
                        ));
                    }
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_u64(self.max_drift);
        for (r, vu) in &self.grants {
            h.write_str(&format!("{r:?}"));
            h.write_u64(*vu);
        }
        h.finish()
    }
}

// ---------------------------------------------------------------------
// watermark-order
// ---------------------------------------------------------------------

/// Snapshot / GC watermark ordering (§5.3, DESIGN.md §Snapshots):
/// * a replica only truncates below what it has executed
///   (`ReplicaTruncated.below ≤ exec`), and its truncation point never
///   regresses;
/// * a leader only compacts below the `f+1`-replica durable watermark
///   (`LogTruncated.below ≤ durable`), monotonically;
/// * successive snapshots of one replica cover strictly more of the log
///   (the role only snapshots when the executed watermark advanced).
///
/// All three reset for a node on [`Announce::NodeRestarted`].
#[derive(Default)]
struct WatermarkOrder {
    snap_upto: BTreeMap<NodeId, Slot>,
    replica_below: BTreeMap<NodeId, Slot>,
    leader_below: BTreeMap<(NodeId, GroupId), Slot>,
}

impl Invariant for WatermarkOrder {
    fn name(&self) -> &'static str {
        "watermark-order"
    }

    fn observe(&mut self, _at: Time, node: NodeId, a: &Announce) -> Result<(), String> {
        match a {
            Announce::SnapshotTaken { replica, upto } => {
                if let Some(prev) = self.snap_upto.get(replica) {
                    if upto <= prev {
                        return Err(format!(
                            "replica {replica}: snapshot at {upto} does not advance past \
                             the previous snapshot at {prev}"
                        ));
                    }
                }
                self.snap_upto.insert(*replica, *upto);
                Ok(())
            }
            Announce::ReplicaTruncated { replica, below, exec } => {
                if below > exec {
                    return Err(format!(
                        "replica {replica}: truncated below {below} but only executed \
                         through {exec} (would discard unexecuted slots)"
                    ));
                }
                if let Some(prev) = self.replica_below.get(replica) {
                    if below < prev {
                        return Err(format!(
                            "replica {replica}: truncation point regressed {prev} -> {below}"
                        ));
                    }
                }
                self.replica_below.insert(*replica, *below);
                Ok(())
            }
            Announce::LogTruncated { group, below, durable } => {
                if below > durable {
                    return Err(format!(
                        "leader {node} group {group}: compacted below {below} but the \
                         durable watermark is {durable} (a chosen value could be lost)"
                    ));
                }
                if let Some(prev) = self.leader_below.get(&(node, *group)) {
                    if below < prev {
                        return Err(format!(
                            "leader {node} group {group}: compaction point regressed \
                             {prev} -> {below}"
                        ));
                    }
                }
                self.leader_below.insert((node, *group), *below);
                Ok(())
            }
            Announce::NodeRestarted { node: n } => {
                self.snap_upto.remove(n);
                self.replica_below.remove(n);
                self.leader_below.retain(|(id, _), _| id != n);
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str(&format!(
            "{:?}|{:?}|{:?}",
            self.snap_upto, self.replica_below, self.leader_below
        ));
        h.finish()
    }
}

// ---------------------------------------------------------------------
// client-fifo
// ---------------------------------------------------------------------

/// Per-client exactly-once / FIFO over the chosen log (§2's client
/// interface contract, enforced by [`crate::roles::sequencer`] and the
/// replica dedup table).
///
/// Two modes:
///
/// * **Lenient** (harness runs, crashy/lossy instances): only payload
///   consistency — one `(group, client, seq)` never appears with two
///   different payloads. Duplicate choices of the same command across
///   slots are legal under leader failover (replicas dedup at
///   execution).
/// * **Strict** (crash-free checker instances): additionally, no
///   `(client, seq)` is chosen in two different slots, first occurrences
///   appear in seq order along the slot order, and at a terminal state
///   each client's chosen seqs are contiguous (nothing admitted was
///   lost).
struct ClientFifo {
    strict: bool,
    /// (group, client, seq) → payload digest (both modes).
    payloads: BTreeMap<(GroupId, NodeId, u64), u64>,
    /// (group, client, seq) → slot of first choice (strict).
    placed: BTreeMap<(GroupId, NodeId, u64), Slot>,
    /// group → slot → commands, for the strict end-of-run FIFO scan.
    slots: BTreeMap<GroupId, BTreeMap<Slot, Vec<Command>>>,
}

impl ClientFifo {
    fn new(strict: bool) -> ClientFifo {
        ClientFifo {
            strict,
            payloads: BTreeMap::new(),
            placed: BTreeMap::new(),
            slots: BTreeMap::new(),
        }
    }

    fn commands(value: &Value) -> &[Command] {
        match value {
            Value::Cmd(c) => std::slice::from_ref(c),
            Value::Batch(cs) => cs,
            Value::Noop | Value::Reconfig(_) => &[],
        }
    }
}

impl Invariant for ClientFifo {
    fn name(&self) -> &'static str {
        "client-fifo"
    }

    fn observe(&mut self, _at: Time, _node: NodeId, a: &Announce) -> Result<(), String> {
        let Announce::Chosen { group, slot, value, .. } = a else {
            return Ok(());
        };
        for cmd in Self::commands(value) {
            let key = (*group, cmd.client, cmd.seq);
            let digest = {
                let mut h = Fnv::new();
                h.write(&cmd.payload);
                h.finish()
            };
            match self.payloads.get(&key) {
                None => {
                    self.payloads.insert(key, digest);
                }
                Some(prev) if *prev == digest => {}
                Some(_) => {
                    return Err(format!(
                        "group {group} client {} seq {}: chosen twice with different \
                         payloads",
                        cmd.client, cmd.seq
                    ));
                }
            }
            if self.strict {
                match self.placed.get(&key) {
                    None => {
                        self.placed.insert(key, *slot);
                    }
                    Some(prev) if prev == slot => {}
                    Some(prev) => {
                        return Err(format!(
                            "group {group} client {} seq {}: chosen in two slots \
                             ({prev} and {slot}) in a crash-free run",
                            cmd.client, cmd.seq
                        ));
                    }
                }
            }
        }
        if self.strict {
            self.slots
                .entry(*group)
                .or_default()
                .insert(*slot, Self::commands(value).to_vec());
        }
        Ok(())
    }

    fn finish(&self) -> Result<(), String> {
        if !self.strict {
            return Ok(());
        }
        for (group, slots) in &self.slots {
            // Walk the chosen log in slot order; per client the seqs must
            // read 1, 2, 3, ... — monotone (FIFO) and contiguous
            // (exactly-once admission lost nothing).
            let mut last: BTreeMap<NodeId, u64> = BTreeMap::new();
            for cmds in slots.values() {
                for cmd in cmds {
                    let prev = last.entry(cmd.client).or_insert(cmd.seq.saturating_sub(1));
                    if cmd.seq != *prev + 1 {
                        return Err(format!(
                            "group {group} client {}: seq {} follows {} in slot order \
                             (FIFO/contiguity broken)",
                            cmd.client, cmd.seq, prev
                        ));
                    }
                    *prev = cmd.seq;
                }
            }
        }
        Ok(())
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        for ((g, c, q), p) in &self.payloads {
            h.write_u64(*g as u64);
            h.write_u64(*c as u64);
            h.write_u64(*q);
            h.write_u64(*p);
        }
        for ((g, c, q), s) in &self.placed {
            h.write_u64(*g as u64);
            h.write_u64(*c as u64);
            h.write_u64(*q);
            h.write_u64(*s);
        }
        h.finish()
    }
}

// ---------------------------------------------------------------------
// recovery-sound
// ---------------------------------------------------------------------

/// Durability soundness (DESIGN.md §Durability): an acceptor that
/// crashes and replays its WAL must come back knowing *at least*
/// everything it durably acknowledged before the crash. The storage
/// layer fsyncs before every ack precisely so that P1∩P2 intersection
/// arguments survive `kill -9`; this invariant checks the contract from
/// the outside.
///
/// A *durable shadow* accumulates per acceptor from the probe
/// announcements ([`Announce::DurablePromise`], [`Announce::DurableVote`],
/// [`Announce::AcceptorWatermark`]); at [`Announce::AcceptorRecovered`]
/// the restored state is compared against it:
///
/// * the restored promise may not be below the highest durably-acked
///   promise (an "un-promise" would let an old leader slip a quorum);
/// * the restored chosen-prefix watermark may not regress;
/// * every durably-acked vote at or above the restored watermark must be
///   restored with an equal-or-higher vote round (votes *below* the
///   watermark are legally compacted — they are durable on `f+1`
///   replicas).
///
/// Unlike the per-node monotonicity checks, the shadow deliberately
/// survives [`Announce::NodeRestarted`] — outliving the crash is the
/// property.
#[derive(Default)]
struct RecoverySound {
    /// Highest durably-acked promise per acceptor.
    promised: BTreeMap<NodeId, Round>,
    /// Durably-acked votes per acceptor: slot → highest vote round.
    votes: BTreeMap<NodeId, BTreeMap<Slot, Round>>,
    /// Durably-acked chosen-prefix watermark per acceptor.
    watermark: BTreeMap<NodeId, Slot>,
}

impl Invariant for RecoverySound {
    fn name(&self) -> &'static str {
        "recovery-sound"
    }

    fn observe(&mut self, _at: Time, _node: NodeId, a: &Announce) -> Result<(), String> {
        match a {
            Announce::DurablePromise { node, round } => {
                let e = self.promised.entry(*node).or_insert(*round);
                if *round > *e {
                    *e = *round;
                }
                Ok(())
            }
            Announce::DurableVote { node, slot, vr } => {
                let e = self.votes.entry(*node).or_default().entry(*slot).or_insert(*vr);
                if *vr > *e {
                    *e = *vr;
                }
                Ok(())
            }
            Announce::AcceptorWatermark { node, upto } => {
                let w = self.watermark.entry(*node).or_insert(0);
                if *upto > *w {
                    *w = *upto;
                }
                // Compacted votes are off the durability hook.
                if let Some(vs) = self.votes.get_mut(node) {
                    vs.retain(|s, _| s >= upto);
                }
                Ok(())
            }
            Announce::AcceptorRecovered { node, round, watermark, votes } => {
                if let Some(want) = self.promised.get(node) {
                    if (*round).map_or(true, |r| r < *want) {
                        return Err(format!(
                            "acceptor {node}: recovered promise {round:?} below the \
                             durably-acked {want:?} (un-promise: an old leader could \
                             slip a quorum past the crash)"
                        ));
                    }
                }
                let want_wm = self.watermark.get(node).copied().unwrap_or(0);
                if *watermark < want_wm {
                    return Err(format!(
                        "acceptor {node}: recovered chosen-prefix watermark {watermark} \
                         below the durably-acked {want_wm}"
                    ));
                }
                if let Some(want_votes) = self.votes.get(node) {
                    for (slot, vr) in want_votes {
                        if slot < watermark {
                            continue; // legally compacted by the recovery itself
                        }
                        let got = votes.iter().find(|(s, _)| s == slot).map(|(_, r)| *r);
                        if got.map_or(true, |g| g < *vr) {
                            return Err(format!(
                                "acceptor {node}: durably-acked vote at slot {slot} in \
                                 {vr:?} recovered as {got:?} (a promised quorum could \
                                 miss it)"
                            ));
                        }
                    }
                }
                // The restored state is the new durable baseline.
                if let Some(r) = round {
                    let e = self.promised.entry(*node).or_insert(*r);
                    if *r > *e {
                        *e = *r;
                    }
                }
                let w = self.watermark.entry(*node).or_insert(0);
                if *watermark > *w {
                    *w = *watermark;
                }
                if let Some(vs) = self.votes.get_mut(node) {
                    vs.retain(|s, _| s >= watermark);
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str(&format!("{:?}|{:?}|{:?}", self.promised, self.votes, self.watermark));
        h.finish()
    }
}

// ---------------------------------------------------------------------
// The set
// ---------------------------------------------------------------------

/// The full invariant catalog plus an incremental cursor over an
/// announcement stream. [`feed`] consumes only announcements it has not
/// seen yet, so the explorer can call it after every fired event without
/// re-scanning history.
///
/// [`feed`]: InvariantSet::feed
pub struct InvariantSet {
    invs: Vec<Box<dyn Invariant>>,
    cursor: usize,
}

impl InvariantSet {
    /// The standard catalog (lenient client-FIFO): safe for any run,
    /// including crashy and lossy ones. This is what the harness asserts
    /// after every experiment.
    pub fn standard() -> InvariantSet {
        Self::with_fifo(false, DEFAULT_DRIFT_ENVELOPE)
    }

    /// The strict catalog: adds exactly-once slot placement and
    /// end-of-run FIFO contiguity. Sound only for crash-free runs where
    /// every admitted command is eventually chosen (the explorer's
    /// loss-free instances).
    pub fn strict() -> InvariantSet {
        Self::with_fifo(true, DEFAULT_DRIFT_ENVELOPE)
    }

    /// The standard catalog with the `lease-disjoint-under-skew` drift
    /// envelope widened to `max_drift` — for nemesis runs that inject
    /// clock skew up to that bound. Sound (no false positives) whenever
    /// `max_drift ≤ 2 × LeaseSpec::drift` of the run's lease config.
    pub fn standard_with_drift(max_drift: Time) -> InvariantSet {
        Self::with_fifo(false, max_drift)
    }

    fn with_fifo(strict: bool, max_drift: Time) -> InvariantSet {
        InvariantSet {
            invs: vec![
                Box::new(ChosenUnique::default()),
                Box::new(QuorumIntersection),
                Box::new(MatchmakerMonotonic::default()),
                Box::new(MmMergeConsistent),
                Box::new(LeaseFence::default()),
                Box::new(LeaseDisjointUnderSkew { max_drift, grants: BTreeMap::new() }),
                Box::new(WatermarkOrder::default()),
                Box::new(ClientFifo::new(strict)),
                Box::new(RecoverySound::default()),
            ],
            cursor: 0,
        }
    }

    /// Remove one invariant by name (checker instances that *demonstrate*
    /// a violation disable the guard invariant that would fire first —
    /// e.g. `badquorum` drops `quorum-intersection` so the explorer gets
    /// to find the downstream chosen-safety violation itself).
    pub fn without(mut self, name: &str) -> InvariantSet {
        self.invs.retain(|i| i.name() != name);
        self
    }

    /// Names of the invariants in the catalog, in evaluation order.
    pub fn names(&self) -> Vec<&'static str> {
        self.invs.iter().map(|i| i.name()).collect()
    }

    /// Feed the not-yet-seen suffix of `events` to every invariant.
    pub fn feed(&mut self, events: &[(Time, NodeId, Announce)]) -> Result<(), Violation> {
        while self.cursor < events.len() {
            let (at, node, a) = &events[self.cursor];
            self.cursor += 1;
            for inv in &mut self.invs {
                if let Err(detail) = inv.observe(*at, *node, a) {
                    return Err(Violation {
                        invariant: inv.name(),
                        at: *at,
                        node: *node,
                        detail,
                    });
                }
            }
        }
        Ok(())
    }

    /// End-of-run checks; call only at terminal (quiescent) states.
    pub fn finish(&self) -> Result<(), Violation> {
        for inv in &self.invs {
            if let Err(detail) = inv.finish() {
                return Err(Violation { invariant: inv.name(), at: 0, node: 0, detail });
            }
        }
        Ok(())
    }

    /// One-shot evaluation of a complete announcement stream with the
    /// standard catalog (no end-of-run checks — the stream may come from
    /// a run that stopped mid-flight).
    pub fn check_all(events: &[(Time, NodeId, Announce)]) -> Result<(), Violation> {
        let mut set = InvariantSet::standard();
        set.feed(events)
    }

    /// Combined digest of every invariant's state, for state
    /// fingerprinting.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        for inv in &self.invs {
            h.write_str(inv.name());
            h.write_u64(inv.digest());
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Command;
    use crate::quorum::QuorumSpec;

    fn r(epoch: u64) -> Round {
        Round { epoch, proposer: 0, seq: 0 }
    }

    fn cmd(client: NodeId, seq: u64, payload: &[u8]) -> Value {
        Value::Cmd(Command { client, seq, payload: payload.to_vec() })
    }

    fn chosen(group: GroupId, slot: Slot, v: Value) -> (Time, NodeId, Announce) {
        (1, 6, Announce::Chosen { group, slot, round: r(1), value: v })
    }

    #[test]
    fn clean_stream_passes() {
        let events = vec![
            chosen(0, 0, cmd(90, 1, b"a")),
            chosen(0, 1, cmd(90, 2, b"b")),
            chosen(1, 0, cmd(91, 1, b"c")), // same slot index, other group: fine
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
        let mut s = InvariantSet::strict();
        s.feed(&events).unwrap();
        s.finish().unwrap();
    }

    #[test]
    fn chosen_unique_fires() {
        let events = vec![chosen(0, 0, cmd(90, 1, b"a")), chosen(0, 0, cmd(91, 1, b"b"))];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "chosen-unique");
    }

    #[test]
    fn quorum_intersection_fires() {
        let bad = Configuration {
            id: 9,
            acceptors: vec![0, 1, 2],
            quorum: QuorumSpec::Explicit {
                p1: vec![[0, 1].into_iter().collect()],
                p2: vec![[2].into_iter().collect()],
            },
        };
        let events = vec![(
            1,
            6,
            Announce::QuorumConfig { group: 0, round: r(1), config: bad },
        )];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "quorum-intersection");
    }

    #[test]
    fn matchmaker_monotonic_fires_on_regression() {
        let events = vec![
            (1, 3, Announce::MatchAnswered { group: 0, round: r(5) }),
            (2, 3, Announce::MatchAnswered { group: 0, round: r(3) }),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "matchmaker-monotonic");
    }

    #[test]
    fn matchmaker_monotonic_fires_below_watermark() {
        let events = vec![
            (1, 3, Announce::MmGc { group: 0, round: r(5) }),
            (2, 3, Announce::MatchAnswered { group: 0, round: r(4) }),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "matchmaker-monotonic");
    }

    #[test]
    fn matchmaker_monotonic_resets_on_restart() {
        let events = vec![
            (1, 3, Announce::MatchAnswered { group: 0, round: r(5) }),
            (2, 3, Announce::NodeRestarted { node: 3 }),
            (3, 3, Announce::MatchAnswered { group: 0, round: r(1) }),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn mm_merge_fires_on_wrong_merge() {
        let cfg = Configuration::majority(1, vec![0, 1, 2]);
        let mut log: MmLog = BTreeMap::new();
        log.entry(0).or_default().insert(r(1), cfg.clone());
        // Announced merge drops the entry without any watermark excuse.
        let events = vec![(
            1,
            6,
            Announce::MmMerged {
                inputs: vec![(log, BTreeMap::new())],
                merged: BTreeMap::new(),
                watermarks: BTreeMap::new(),
            },
        )];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "mm-merge");
    }

    #[test]
    fn mm_merge_accepts_figure7() {
        let cfg = |id| Configuration::majority(id, vec![0, 1, 2]);
        let mut log_a: MmLog = BTreeMap::new();
        log_a.entry(0).or_default().insert(r(1), cfg(1));
        log_a.entry(0).or_default().insert(r(2), cfg(2));
        let mut log_b: MmLog = BTreeMap::new();
        log_b.entry(0).or_default().insert(r(3), cfg(3));
        let wms_b: BTreeMap<GroupId, Round> = [(0, r(2))].into_iter().collect();
        // Expected: union minus rounds below watermark r(2).
        let mut merged: MmLog = BTreeMap::new();
        merged.entry(0).or_default().insert(r(2), cfg(2));
        merged.entry(0).or_default().insert(r(3), cfg(3));
        let events = vec![(
            1,
            6,
            Announce::MmMerged {
                inputs: vec![(log_a, BTreeMap::new()), (log_b, wms_b.clone())],
                merged,
                watermarks: wms_b,
            },
        )];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn lease_fence_fires_on_live_old_grant() {
        let events = vec![
            (10, 6, Announce::LeaseGranted { round: r(1), valid_until: 100 }),
            (50, 7, Announce::FenceLifted { round: r(2) }),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "lease-fence");
    }

    #[test]
    fn lease_fence_accepts_expired_grants() {
        // Expired well past the default drift envelope (1µs), so neither
        // lease-fence nor lease-disjoint-under-skew fires.
        let events = vec![
            (10, 6, Announce::LeaseGranted { round: r(1), valid_until: 100 }),
            (100 + 2 * US, 7, Announce::FenceLifted { round: r(2) }),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn lease_disjoint_fires_inside_drift_envelope() {
        // The old grant *is* expired (lease-fence passes), but only by
        // 400ns — inside the 1µs envelope a clock running behind could
        // still consider it valid.
        let events = vec![
            (10, 6, Announce::LeaseGranted { round: r(1), valid_until: 100 }),
            (500, 7, Announce::FenceLifted { round: r(2) }),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "lease-disjoint-under-skew");
    }

    #[test]
    fn lease_disjoint_ignores_same_and_newer_rounds() {
        // Grants under the fenced round itself (or newer) are the new
        // leader's own; only *lower*-round grants must be margined out.
        let events = vec![
            (10, 6, Announce::LeaseGranted { round: r(2), valid_until: 10 * US }),
            (20, 6, Announce::FenceLifted { round: r(2) }),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn lease_disjoint_envelope_widens_with_injected_skew() {
        // Expired by 1.5µs: clean under the default 1µs envelope, a
        // violation once the catalog models 2µs of injected skew.
        let vu = 10 * US;
        let at = vu + US + US / 2;
        let events = vec![
            (10, 6, Announce::LeaseGranted { round: r(1), valid_until: vu }),
            (at, 7, Announce::FenceLifted { round: r(2) }),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
        let mut skewed = InvariantSet::standard_with_drift(2 * US);
        let v = skewed.feed(&events).unwrap_err();
        assert_eq!(v.invariant, "lease-disjoint-under-skew");
    }

    #[test]
    fn watermark_order_fires_on_overtruncation() {
        let events =
            vec![(1, 8, Announce::ReplicaTruncated { replica: 8, below: 10, exec: 5 })];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "watermark-order");
    }

    #[test]
    fn watermark_order_fires_on_leader_compaction_past_durable() {
        let events = vec![(1, 6, Announce::LogTruncated { group: 0, below: 9, durable: 4 })];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "watermark-order");
    }

    #[test]
    fn watermark_order_fires_on_stalled_snapshot() {
        let events = vec![
            (1, 8, Announce::SnapshotTaken { replica: 8, upto: 5 }),
            (2, 8, Announce::SnapshotTaken { replica: 8, upto: 5 }),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "watermark-order");
    }

    #[test]
    fn watermark_order_resets_on_restart() {
        let events = vec![
            (1, 8, Announce::SnapshotTaken { replica: 8, upto: 5 }),
            (2, 8, Announce::NodeRestarted { node: 8 }),
            (3, 8, Announce::SnapshotTaken { replica: 8, upto: 2 }),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn client_fifo_payload_consistency_fires_in_lenient_mode() {
        let events = vec![chosen(0, 0, cmd(90, 1, b"a")), chosen(0, 1, cmd(90, 1, b"b"))];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "client-fifo");
    }

    #[test]
    fn client_fifo_duplicate_slot_fires_only_in_strict_mode() {
        let events = vec![chosen(0, 0, cmd(90, 1, b"a")), chosen(0, 1, cmd(90, 1, b"a"))];
        // Lenient: duplicate choice with identical payload is legal
        // (leader failover re-proposal).
        assert!(InvariantSet::check_all(&events).is_ok());
        let mut s = InvariantSet::strict();
        let v = s.feed(&events).unwrap_err();
        assert_eq!(v.invariant, "client-fifo");
    }

    #[test]
    fn client_fifo_contiguity_fires_at_finish() {
        // seq 1 then seq 3: nothing wrong mid-run, broken at quiescence.
        let events = vec![chosen(0, 0, cmd(90, 1, b"a")), chosen(0, 1, cmd(90, 3, b"c"))];
        let mut s = InvariantSet::strict();
        s.feed(&events).unwrap();
        let v = s.finish().unwrap_err();
        assert_eq!(v.invariant, "client-fifo");
    }

    #[test]
    fn client_fifo_order_fires_at_finish() {
        // Chosen out of order across slots in a crash-free run.
        let events = vec![chosen(0, 0, cmd(90, 2, b"b")), chosen(0, 1, cmd(90, 1, b"a"))];
        let mut s = InvariantSet::strict();
        s.feed(&events).unwrap();
        assert!(s.finish().is_err());
    }

    #[test]
    fn batches_unwrap_in_order() {
        let batch = Value::Batch(vec![
            Command { client: 90, seq: 1, payload: vec![1] },
            Command { client: 90, seq: 2, payload: vec![2] },
        ]);
        let events = vec![chosen(0, 0, batch)];
        let mut s = InvariantSet::strict();
        s.feed(&events).unwrap();
        s.finish().unwrap();
    }

    #[test]
    fn without_removes_named_invariant() {
        let s = InvariantSet::standard().without("quorum-intersection");
        assert!(!s.names().contains(&"quorum-intersection"));
        assert_eq!(s.names().len(), 8);
    }

    #[test]
    fn recovery_sound_accepts_faithful_replay() {
        let events = vec![
            (1, 2, Announce::DurablePromise { node: 2, round: r(3) }),
            (2, 2, Announce::DurableVote { node: 2, slot: 0, vr: r(3) }),
            (3, 2, Announce::NodeRestarted { node: 2 }),
            (
                4,
                2,
                Announce::AcceptorRecovered {
                    node: 2,
                    round: Some(r(3)),
                    watermark: 0,
                    votes: vec![(0, r(3))],
                },
            ),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn recovery_sound_fires_on_unpromise() {
        let events = vec![
            (1, 2, Announce::DurablePromise { node: 2, round: r(5) }),
            (2, 2, Announce::NodeRestarted { node: 2 }),
            (
                3,
                2,
                Announce::AcceptorRecovered {
                    node: 2,
                    round: Some(r(3)),
                    watermark: 0,
                    votes: vec![],
                },
            ),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "recovery-sound");
        assert!(v.detail.contains("un-promise"), "{}", v.detail);
    }

    #[test]
    fn recovery_sound_fires_on_lost_vote() {
        let events = vec![
            (1, 2, Announce::DurableVote { node: 2, slot: 7, vr: r(2) }),
            (
                2,
                2,
                Announce::AcceptorRecovered {
                    node: 2,
                    round: None,
                    watermark: 0,
                    votes: vec![],
                },
            ),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "recovery-sound");
        assert!(v.detail.contains("slot 7"), "{}", v.detail);
    }

    #[test]
    fn recovery_sound_accepts_votes_compacted_below_watermark() {
        // The vote at slot 3 is below both the durably-acked and the
        // recovered watermark: compaction legally forgot it.
        let events = vec![
            (1, 2, Announce::DurableVote { node: 2, slot: 3, vr: r(2) }),
            (2, 2, Announce::AcceptorWatermark { node: 2, upto: 5 }),
            (
                3,
                2,
                Announce::AcceptorRecovered {
                    node: 2,
                    round: None,
                    watermark: 5,
                    votes: vec![],
                },
            ),
        ];
        assert!(InvariantSet::check_all(&events).is_ok());
    }

    #[test]
    fn recovery_sound_fires_on_watermark_regression() {
        let events = vec![
            (1, 2, Announce::AcceptorWatermark { node: 2, upto: 9 }),
            (
                2,
                2,
                Announce::AcceptorRecovered {
                    node: 2,
                    round: None,
                    watermark: 4,
                    votes: vec![],
                },
            ),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "recovery-sound");
    }

    #[test]
    fn recovery_sound_fires_on_stale_vote_round() {
        // The slot survives recovery but with a *lower* vote round than
        // was durably acked — a promised quorum could miss the real vote.
        let events = vec![
            (1, 2, Announce::DurableVote { node: 2, slot: 0, vr: r(4) }),
            (
                2,
                2,
                Announce::AcceptorRecovered {
                    node: 2,
                    round: None,
                    watermark: 0,
                    votes: vec![(0, r(2))],
                },
            ),
        ];
        let v = InvariantSet::check_all(&events).unwrap_err();
        assert_eq!(v.invariant, "recovery-sound");
    }

    #[test]
    fn digest_tracks_observed_history() {
        let mut a = InvariantSet::standard();
        let mut b = InvariantSet::standard();
        assert_eq!(a.digest(), b.digest());
        a.feed(&[chosen(0, 0, cmd(90, 1, b"a"))]).unwrap();
        assert_ne!(a.digest(), b.digest());
        b.feed(&[chosen(0, 0, cmd(90, 1, b"a"))]).unwrap();
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn feed_is_incremental() {
        let mut s = InvariantSet::standard();
        let mut events = vec![chosen(0, 0, cmd(90, 1, b"a"))];
        s.feed(&events).unwrap();
        // A second feed with the same prefix must not re-observe it
        // (re-observation would false-positive strict dup detection and
        // corrupt digests).
        events.push(chosen(0, 1, cmd(90, 2, b"b")));
        s.feed(&events).unwrap();
        assert!(s.feed(&events).is_ok());
    }
}
