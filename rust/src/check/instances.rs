//! The catalog of checked instances: small, fully deterministic
//! deployments sized for exhaustive exploration (ISSUE: f = 1, two
//! proposers, a handful of slots, one reconfiguration).
//!
//! Every instance shares the same cluster shape — acceptors `{0,1,2}`
//! plus spare `10`, matchmakers `{3,4,5}`, proposers `{6,7}` (proposer 6
//! self-elects at start), replicas `{8,9}` — and drives traffic from
//! *sink* clients (ids ≥ [`SINK_CLIENTS`]) that are never added as
//! nodes: their requests are injected directly and replies to them are
//! auto-fired and discarded, which keeps client-side bookkeeping out of
//! the explored state space.
//!
//! * [`base`] — happy-path: four commands from three clients racing one
//!   acceptor reconfiguration `{0,1,2} → {1,2,10}`. Checked against the
//!   strict invariant catalog; expected clean.
//! * [`lossy`] — the same deployment, but the explorer may also drop one
//!   message per schedule. Checked against the standard (lenient)
//!   catalog — commands may be lost, but safety must hold. Expected
//!   clean.
//! * [`partitioned`] — the same deployment, but the explorer may sever
//!   and restore the leader's links to two acceptors as first-class
//!   schedule actions (the nemesis `partition` event class in
//!   miniature). Liveness is forfeit while a link is down, so the
//!   lenient catalog applies; safety must hold through every cut/heal
//!   interleaving. Expected clean.
//! * [`badquorum`] — a deliberately broken configuration whose P1 and
//!   P2 quorums do not intersect (`P1 = {{0,1}}`, `P2 = {{2}}`). The
//!   explorer must find the classic two-leader chosen-value divergence;
//!   this is the checker-check that proves the explorer can actually
//!   catch protocol bugs (and the source of the checked-in regression
//!   trace).

use super::explorer::Instance;
use super::invariants::InvariantSet;
use crate::config::{Configuration, OptFlags};
use crate::msg::{Command, Msg};
use crate::quorum::QuorumSpec;
use crate::roles::{Acceptor, Leader, Matchmaker, Replica};
use crate::sim::{NetworkModel, PendingEvent, PendingKind, Sim};
use crate::statemachine::Noop;
use crate::{NodeId, MS};

/// Node ids at or above this are workload sinks: never added to the
/// simulator, requests injected on their behalf, replies auto-fired.
pub const SINK_CLIENTS: NodeId = 90;

/// A deterministic network: fixed one-way delay, no jitter, no drops
/// (the explorer injects drops itself, as first-class schedule actions).
fn det_net() -> NetworkModel {
    NetworkModel { jitter: 0, drop_prob: 0.0, ..NetworkModel::default() }
}

/// Auto-fire rule shared by all instances: replies addressed to sink
/// clients carry no protocol state, so they are executed immediately
/// (into the void — the sink is not a node) instead of multiplying the
/// explored interleavings.
fn auto_sink(ev: &PendingEvent) -> bool {
    matches!(ev.kind, PendingKind::Deliver { to, .. } if to >= SINK_CLIENTS)
}

/// Timer rule shared by all instances: no timer fires. The checked
/// instances have no drops the protocol must recover from (the lossy
/// instance checks safety, not liveness, under its one drop), so
/// retry/heartbeat/lease machinery would only blow up the state space —
/// and excluding timers is exactly the "timing quotient" documented in
/// DESIGN.md §Model checking.
fn no_timers(_: &crate::node::Timer) -> bool {
    false
}

/// Build the shared cluster shape and run it to a steady state: proposer
/// 6 elected, no client traffic yet. `leader_replicas` is the replica
/// set the leaders broadcast Chosen to — `badquorum` passes `[]` so the
/// new leader cannot learn the chosen prefix from a replica (the point
/// of that instance is what the *quorums* fail to tell it); the Chosen
/// announce itself comes from the leader, so invariants see every
/// decision either way.
fn core(opts: OptFlags, initial: Configuration, seed: u64, leader_replicas: Vec<NodeId>) -> Sim {
    let mut sim = Sim::new(seed, det_net());
    for a in [0u32, 1, 2, 10] {
        sim.add_node(a, Box::new(Acceptor::new(a)));
    }
    for m in [3u32, 4, 5] {
        sim.add_node(m, Box::new(Matchmaker::new(m)));
    }
    for r in [8u32, 9] {
        let mut rep = Replica::new(r, Box::new(Noop));
        rep.peers = vec![8, 9];
        rep.proposers = vec![6, 7];
        sim.add_node(r, Box::new(rep));
    }
    for p in [6u32, 7] {
        let leader = Leader::new(
            p,
            1,
            initial.clone(),
            vec![3, 4, 5],
            leader_replicas.clone(),
            vec![6, 7],
            opts,
            seed,
        );
        sim.add_node(p, Box::new(leader));
    }
    sim
}

fn request(client: NodeId, seq: u64, payload: u8) -> Msg {
    Msg::ClientRequest {
        group: 0,
        cmd: Command { client, seq, payload: vec![payload] },
        lowest: 1,
    }
}

/// Build the `base`/`lossy` start state: steady cluster, four in-flight
/// commands from three sink clients, and one scheduled acceptor
/// reconfiguration `{0,1,2} → {1,2,10}` racing them.
fn base_build() -> Sim {
    let mut sim =
        core(OptFlags::none(), Configuration::majority(0, vec![0, 1, 2]), 1, vec![8, 9]);
    sim.run_until(50 * MS);
    for (client, seq, payload) in [(90, 1, 1u8), (90, 2, 2), (91, 1, 3), (92, 1, 4)] {
        sim.inject(client, 6, request(client, seq, payload));
    }
    let at = sim.now();
    sim.schedule(at, |s| {
        s.with_node::<Leader, _>(6, |l, now, fx| {
            l.reconfigure(Configuration::majority(1, vec![1, 2, 10]), now, fx);
        });
    });
    sim
}

/// The happy-path instance: every interleaving of four commands against
/// one reconfiguration must satisfy the *strict* catalog (exactly-once,
/// FIFO-contiguous client ordering included).
pub fn base() -> Instance {
    Instance {
        name: "base",
        about: "4 commands from 3 clients racing one acceptor reconfiguration {0,1,2}->{1,2,10}; \
                strict invariants, no drops",
        build: base_build,
        invariants: InvariantSet::strict,
        expect_violation: None,
        depth: 48,
        smoke_depth: 9,
        timers: no_timers,
        auto: auto_sink,
        max_drops: 0,
        partition_links: &[],
        max_partition_ops: 0,
    }
}

/// The lossy instance: same deployment, but each schedule may also drop
/// one in-flight message. Liveness is forfeit (no retry timers fire), so
/// the lenient catalog applies: safety invariants only, client FIFO
/// checked for payload consistency but not completion.
pub fn lossy() -> Instance {
    Instance {
        name: "lossy",
        about: "base deployment, but schedules may drop one message; standard (safety-only) \
                invariants",
        build: base_build,
        invariants: InvariantSet::standard,
        expect_violation: None,
        depth: 32,
        smoke_depth: 7,
        timers: no_timers,
        auto: auto_sink,
        max_drops: 1,
        partition_links: &[],
        max_partition_ops: 0,
    }
}

/// The partitioned instance: base deployment, but schedules may sever
/// and restore the leader's one-way links to acceptors 1 and 2 (two
/// partition operations per schedule — enough for one cut/heal cycle or
/// an asymmetric double cut). Messages sent on a severed link are lost,
/// so liveness is forfeit and the lenient catalog applies: commands may
/// stall, but no cut/heal interleaving may break safety.
pub fn partitioned() -> Instance {
    Instance {
        name: "partitioned",
        about: "base deployment; schedules may cut/heal the one-way links 6->1 and 6->2 \
                within a 2-op budget; standard (safety-only) invariants",
        build: base_build,
        invariants: InvariantSet::standard,
        expect_violation: None,
        depth: 22,
        smoke_depth: 6,
        timers: no_timers,
        auto: auto_sink,
        max_drops: 0,
        partition_links: &[(6, 1), (6, 2)],
        max_partition_ops: 2,
    }
}

/// Build the `badquorum` start state: a configuration whose P1 quorum
/// `{0,1}` and P2 quorum `{2}` do not intersect (violating the paper's
/// §3.2 quorum requirement), thriftiness on so Phase 2 really does touch
/// only acceptor 2. During warmup, proposer 6 chooses client 90's
/// command in slot 0 via the P2 quorum `{2}`. The scheduled control then
/// makes proposer 7 grab leadership; its Phase 1 quorum `{0,1}` never
/// intersects the vote, so schedules exist where it proposes client 91's
/// command in the same slot — the divergence the checker must find.
fn badquorum_build() -> Sim {
    let bad = Configuration {
        id: 0,
        acceptors: vec![0, 1, 2],
        quorum: QuorumSpec::Explicit {
            p1: vec![[0usize, 1].into_iter().collect()],
            p2: vec![[2usize].into_iter().collect()],
        },
    };
    let opts = OptFlags { thrifty: true, ..OptFlags::none() };
    let mut sim = core(opts, bad, 1, Vec::new());
    sim.run_until(20 * MS);
    sim.inject(90, 6, request(90, 1, 1));
    // Let the first command be chosen (via P2 = {2}) inside the warmup:
    // the explored schedules start from "slot 0 already decided".
    sim.run_until(40 * MS);
    let at = sim.now();
    sim.schedule(at, |s| {
        s.with_node::<Leader, _>(7, |l, now, fx| l.become_leader(now, fx));
    });
    sim.inject(91, 7, request(91, 1, 2));
    sim
}

/// The deliberately broken instance: non-intersecting quorums. The
/// quorum-intersection guard invariant is excluded — it would flag the
/// configuration the moment it is announced, which is the *lint* view of
/// this bug; this instance instead proves the explorer catches the
/// *semantic* consequence (two values chosen in one slot).
pub fn badquorum() -> Instance {
    Instance {
        name: "badquorum",
        about: "non-intersecting P1/P2 quorums (P1={{0,1}}, P2={{2}}): the explorer must find \
                two values chosen in slot 0 after a leader change",
        build: badquorum_build,
        invariants: || InvariantSet::strict().without("quorum-intersection"),
        expect_violation: Some("chosen-unique"),
        depth: 28,
        smoke_depth: 28,
        timers: no_timers,
        auto: auto_sink,
        max_drops: 0,
        partition_links: &[],
        max_partition_ops: 0,
    }
}

/// Every checked instance, in documentation order.
pub fn all() -> Vec<Instance> {
    vec![base(), lossy(), partitioned(), badquorum()]
}

/// Look up an instance by name.
pub fn find(name: &str) -> Option<Instance> {
    all().into_iter().find(|i| i.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::explorer::{enabled_actions, explore, replay, Action, Replayed};

    #[test]
    fn registry_finds_every_instance() {
        for inst in all() {
            assert!(find(inst.name).is_some(), "{} not findable", inst.name);
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn builds_are_deterministic() {
        for inst in all() {
            let a = (inst.build)();
            let b = (inst.build)();
            assert_eq!(a.pending(), b.pending(), "{}: pending events differ", inst.name);
            let da = (inst.invariants)().digest();
            let db = (inst.invariants)().digest();
            assert_eq!(da, db, "{}: invariant digests differ", inst.name);
            assert_eq!(
                a.fingerprint(da),
                b.fingerprint(db),
                "{}: fingerprints differ",
                inst.name
            );
        }
    }

    #[test]
    fn warmup_is_clean() {
        // The announces produced while building each instance must
        // already satisfy its own invariant catalog (violations are
        // supposed to come from explored schedules, not the warmup).
        for inst in all() {
            let sim = (inst.build)();
            let mut invs = (inst.invariants)();
            if let Err(v) = invs.feed(&sim.announces) {
                panic!("{} warmup violates {v}", inst.name);
            }
        }
    }

    #[test]
    fn base_has_pending_work() {
        let inst = base();
        let sim = (inst.build)();
        let actions = enabled_actions(&inst, &sim, &[]);
        assert!(!actions.is_empty());
        // The scheduled reconfiguration is an enabled control action.
        assert!(
            actions.iter().any(|a| a.sig().starts_with('c')),
            "no control action in {actions:?}"
        );
        // Per-channel FIFO: client 90 has two requests in flight on the
        // same channel, so exactly one 90->6 deliver is enabled.
        let from_90 =
            actions.iter().filter(|a| a.sig().starts_with("d90->6:")).count();
        assert_eq!(from_90, 1, "channel head reduction broken: {actions:?}");
    }

    #[test]
    fn lossy_offers_drops_within_budget() {
        let inst = lossy();
        let sim = (inst.build)();
        let actions = enabled_actions(&inst, &sim, &[]);
        assert!(actions.iter().any(|a| matches!(a, Action::Drop(..))));
        // After one drop is in the prefix, the budget is exhausted.
        let first_drop =
            actions.iter().find(|a| matches!(a, Action::Drop(..))).unwrap().clone();
        match replay(&inst, std::slice::from_ref(&first_drop)) {
            Replayed::State(sim2, _) => {
                let next = enabled_actions(&inst, &sim2, std::slice::from_ref(&first_drop));
                assert!(
                    next.iter().all(|a| matches!(a, Action::Fire(..))),
                    "drop budget not enforced: {next:?}"
                );
            }
            Replayed::Violation(v, _) => panic!("unexpected violation: {v}"),
            Replayed::Invalid(e) => panic!("invalid replay: {e}"),
        }
    }

    #[test]
    fn partitioned_offers_cuts_within_budget() {
        let inst = partitioned();
        let sim = (inst.build)();
        let actions = enabled_actions(&inst, &sim, &[]);
        // Both candidate links are open, so both cuts are offered (and
        // no heals yet).
        assert!(actions.contains(&Action::Cut(6, 1)), "{actions:?}");
        assert!(actions.contains(&Action::Cut(6, 2)), "{actions:?}");
        assert!(!actions.iter().any(|a| matches!(a, Action::Heal(..))));
        // After a cut, that link offers a heal instead; after the budget
        // is spent, no partition actions remain.
        let prefix = vec![Action::Cut(6, 1)];
        match replay(&inst, &prefix) {
            Replayed::State(sim2, _) => {
                let next = enabled_actions(&inst, &sim2, &prefix);
                assert!(next.contains(&Action::Heal(6, 1)), "{next:?}");
                assert!(next.contains(&Action::Cut(6, 2)), "{next:?}");
                assert!(!next.contains(&Action::Cut(6, 1)), "{next:?}");
            }
            other => panic!("cut prefix did not replay to a state: {:?}", other_kind(&other)),
        }
        let spent = vec![Action::Cut(6, 1), Action::Heal(6, 1)];
        match replay(&inst, &spent) {
            Replayed::State(sim2, _) => {
                let next = enabled_actions(&inst, &sim2, &spent);
                assert!(
                    !next.iter().any(|a| matches!(a, Action::Cut(..) | Action::Heal(..))),
                    "partition budget not enforced: {next:?}"
                );
            }
            other => panic!("spent prefix did not replay to a state: {:?}", other_kind(&other)),
        }
        // A heal of an open link is an invalid (hand-edited) trace.
        assert!(matches!(
            replay(&inst, &[Action::Heal(6, 1)]),
            Replayed::Invalid(_)
        ));
    }

    fn other_kind(r: &Replayed) -> &'static str {
        match r {
            Replayed::State(..) => "state",
            Replayed::Violation(..) => "violation",
            Replayed::Invalid(_) => "invalid",
        }
    }

    #[test]
    fn shallow_partitioned_exploration_is_clean() {
        let report = explore(&partitioned(), 4, 20_000);
        assert!(report.violation.is_none(), "{:?}", report.violation);
        assert!(report.unique_states > 1);
    }

    #[test]
    fn shallow_exploration_of_base_is_clean_and_dedups() {
        let report = explore(&base(), 5, 20_000);
        assert!(report.violation.is_none(), "{:?}", report.violation);
        assert!(report.unique_states > 1);
        assert!(
            report.raw_states > report.unique_states as f64,
            "no merging at all: raw {} unique {}",
            report.raw_states,
            report.unique_states
        );
    }

    #[test]
    fn exploration_is_deterministic() {
        let a = explore(&base(), 4, 5_000);
        let b = explore(&base(), 4, 5_000);
        assert_eq!(a.replays, b.replays);
        assert_eq!(a.raw_states.to_bits(), b.raw_states.to_bits());
        assert_eq!(a.unique_states, b.unique_states);
        assert_eq!(a.trace, b.trace);
    }
}
