//! `repro check` — exhaustive model checking of the protocol on small
//! instances (DESIGN.md §Model checking).
//!
//! Three layers:
//!
//! * [`invariants`] — a machine-checked catalog of the paper's safety
//!   properties ([`Invariant`]), evaluated incrementally over the
//!   simulator's announce stream: per-(group, slot) chosen-value
//!   uniqueness, Phase-1/Phase-2 quorum intersection, matchmaker-log
//!   monotonicity and Figure-7 merge consistency, lease/fence safety,
//!   snapshot/GC watermark ordering, and per-client exactly-once/FIFO
//!   delivery.
//! * [`explorer`] — bounded explicit-state exploration: the simulator's
//!   pending event queue is the frontier, enabled actions are enumerated
//!   under per-channel-FIFO reduction, and schedules are replayed
//!   depth-first with fingerprint dedup ([`explore`]).
//! * [`trace`] — minimized violating schedules serialized as replayable
//!   text files (`repro check replay <file>`), for regression-testing
//!   found bugs.
//!
//! The checked instances live in [`instances`]; the randomized property
//! suites in `rust/tests/` assert the same catalog via
//! [`InvariantSet::check_all`].

pub mod explorer;
pub mod instances;
pub mod invariants;
pub mod trace;

pub use explorer::{
    enabled_actions, explore, replay, shrink, Action, Instance, Replayed, Report, WILDCARD_SEQ,
};
pub use invariants::{Invariant, InvariantSet, Violation, DEFAULT_DRIFT_ENVELOPE};

/// Run one instance end to end at the given bounds and print a report.
/// Returns `Ok` if the outcome matches the instance's expectation
/// (clean, or the seeded violation was found); the `Err` is a one-line
/// explanation for the CLI to print before exiting nonzero.
pub fn run_instance(
    inst: &Instance,
    depth: usize,
    max_replays: u64,
    emit_trace: Option<&std::path::Path>,
) -> Result<Report, String> {
    let report = explore(inst, depth, max_replays);
    println!(
        "check {}: depth {} | {} replays -> {:.3e} raw states, {} unique ({:.1}x dedup), \
         {} terminal, {} depth-cut{}",
        inst.name,
        report.depth,
        report.replays,
        report.raw_states,
        report.unique_states,
        report.dedup_ratio(),
        report.terminal_states,
        report.depth_truncated,
        if report.hit_state_cap { " [replay cap hit]" } else { "" },
    );
    match (&report.violation, inst.expect_violation) {
        (None, None) => Ok(report),
        (Some(v), Some(want)) if v.invariant == want => {
            println!("  found expected violation: {v}");
            println!("  minimized schedule ({} actions):", report.trace.len());
            for line in trace::serialize(inst.name, Some(want), &report.trace).lines() {
                println!("    {line}");
            }
            if let Some(path) = emit_trace {
                let text = trace::serialize(inst.name, Some(want), &report.trace);
                std::fs::write(path, text).map_err(|e| format!("writing {path:?}: {e}"))?;
                println!("  trace written to {}", path.display());
            }
            Ok(report)
        }
        (Some(v), Some(want)) => Err(format!(
            "{}: expected a {want} violation, found {v}",
            inst.name
        )),
        (Some(v), None) => {
            println!("  VIOLATION: {v}");
            println!("  minimized schedule ({} actions):", report.trace.len());
            for line in trace::serialize(inst.name, Some(v.invariant), &report.trace).lines() {
                println!("    {line}");
            }
            if let Some(path) = emit_trace {
                let text = trace::serialize(inst.name, Some(v.invariant), &report.trace);
                std::fs::write(path, text).map_err(|e| format!("writing {path:?}: {e}"))?;
                println!("  trace written to {}", path.display());
            }
            Err(format!("{}: invariant {} violated", inst.name, v.invariant))
        }
        (None, Some(want)) => Err(format!(
            "{}: expected exploration to find a {want} violation (checker-check failed — \
             the instance seeds a bug the catalog must catch)",
            inst.name
        )),
    }
}
