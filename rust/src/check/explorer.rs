//! Bounded explicit-state exploration of small protocol instances.
//!
//! Stateright-style, but native to this repo's sans-io [`crate::sim`]:
//! the simulator is treated as a transition system whose frontier is the
//! pending event queue. An enabled action either *fires* one pending
//! event out of timestamp order ([`crate::sim::Sim::fire`]) or *drops*
//! one in-flight message ([`crate::sim::Sim::drop_event`], budgeted per
//! instance). The explorer runs depth-bounded DFS over action sequences,
//! deduplicating states by fingerprint
//! ([`crate::sim::Sim::fingerprint`] folded with the invariant catalog's
//! digest), and evaluates the [`InvariantSet`] incrementally after every
//! action.
//!
//! **Replay-based:** simulator states are not cloneable (nodes are
//! `Box<dyn Node>`, controls are `FnOnce`), so instead of snapshotting,
//! the explorer rebuilds the instance and re-applies the action prefix
//! for every state it expands. Event seqs are assigned deterministically
//! (creation order), so a prefix names the same schedule on every
//! rebuild — the same property that makes trace files replayable.
//!
//! Reduction choices (documented in DESIGN.md §Model checking):
//!
//! * **Per-channel FIFO:** only the *head* message of each `(src, dst)`
//!   channel is enabled. Real TCP links don't reorder, and the protocol
//!   makes no ordering assumptions beyond that; this is the classic
//!   reduction that keeps the branching factor at (#non-empty channels),
//!   not (#in-flight messages).
//! * **Timers are filtered**, not branched, by an instance predicate —
//!   the loss-free instances need no timeout paths, and every timer left
//!   in the queue still participates in fingerprints.
//! * **Auto events** (per-instance predicate, e.g. deliveries to the
//!   workload sink) fire immediately after every action and are excluded
//!   from frontiers and traces.
//! * **Partitions are first-class actions** ([`Action::Cut`] /
//!   [`Action::Heal`]): an instance may declare candidate one-way links
//!   ([`Instance::partition_links`]) the explorer severs and restores as
//!   schedule steps, within a per-schedule budget
//!   ([`Instance::max_partition_ops`]). Cuts apply to *future* sends
//!   (in-flight messages still deliver, as on a real network), and the
//!   cut-link state participates in state fingerprints.
//!
//! On a violation the offending action sequence is shrunk to a local
//! minimum ([`shrink`]) before being reported: every action whose
//! removal still reproduces the same invariant's violation is removed,
//! to a fixpoint.

use super::invariants::{InvariantSet, Violation};
use crate::node::Timer;
use crate::sim::{PendingEvent, PendingKind, Sim};
use crate::NodeId;
use std::collections::BTreeSet;

/// A small, fully described protocol instance the explorer can rebuild
/// from scratch deterministically (the checker's unit of configuration).
pub struct Instance {
    /// Stable name (`repro check <name>`, trace files).
    pub name: &'static str,
    /// One-line description for `repro check list`.
    pub about: &'static str,
    /// Build the instance: construct nodes, run the deterministic warmup
    /// (leader election, steady state), inject the workload, schedule
    /// controls. Must be deterministic — every call yields the same sim
    /// with the same event seqs.
    pub build: fn() -> Sim,
    /// The invariant catalog this instance is checked against.
    pub invariants: fn() -> InvariantSet,
    /// `Some(name)`: this instance exists to *demonstrate* that the named
    /// invariant catches a seeded bug; exploration must find a violation
    /// of exactly that invariant. `None`: exploration must be clean.
    pub expect_violation: Option<&'static str>,
    /// Depth bound (actions per schedule) for `--mode full`.
    pub depth: usize,
    /// Depth bound for the CI fast-loop `--mode smoke`.
    pub smoke_depth: usize,
    /// Which pending timers are explorable (fired as branches). Timers
    /// failing the predicate stay queued forever — loss-free instances
    /// never need timeout paths.
    pub timers: fn(&Timer) -> bool,
    /// Events fired automatically (not branched, not recorded): responses
    /// draining to the workload sink.
    pub auto: fn(&PendingEvent) -> bool,
    /// Total network drops the explorer may inject per schedule.
    pub max_drops: usize,
    /// Directed links the explorer may sever and restore as first-class
    /// schedule actions (the nemesis `partition` event class). Empty:
    /// no partition branching.
    pub partition_links: &'static [(NodeId, NodeId)],
    /// Total partition operations (cuts plus heals) the explorer may
    /// take per schedule.
    pub max_partition_ops: usize,
}

/// Seq sentinel meaning "the lowest-seq pending event whose signature
/// matches" — written as `*` in trace files. Lets regression traces be
/// authored (and read) in terms of protocol messages instead of raw
/// scheduler ids; resolution is deterministic because pending events are
/// enumerated in seq order. The explorer itself always emits concrete
/// seqs.
pub const WILDCARD_SEQ: u64 = u64::MAX;

/// One step of a schedule. The `String` is the event signature
/// ([`PendingEvent::sig`]): replays validate it so a stale trace fails
/// loudly instead of silently exploring a different schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Action {
    /// Deliver/execute the pending event with this seq ([`WILDCARD_SEQ`]:
    /// lowest seq matching the signature).
    Fire(u64, String),
    /// Drop the pending message with this seq (same wildcard rule).
    Drop(u64, String),
    /// Sever the one-way link `from -> to`. Future sends on the link are
    /// silently discarded; already-pending deliveries still arrive.
    Cut(NodeId, NodeId),
    /// Restore the one-way link `from -> to` severed by a prior `Cut`.
    Heal(NodeId, NodeId),
}

impl Action {
    pub fn seq(&self) -> u64 {
        match self {
            Action::Fire(s, _) | Action::Drop(s, _) => *s,
            Action::Cut(..) | Action::Heal(..) => WILDCARD_SEQ,
        }
    }

    pub fn sig(&self) -> &str {
        match self {
            Action::Fire(_, sig) | Action::Drop(_, sig) => sig,
            Action::Cut(..) | Action::Heal(..) => "",
        }
    }
}

/// Outcome of re-applying an action prefix to a freshly built instance.
pub enum Replayed {
    /// Clean: the resulting state and the caught-up invariant set.
    State(Sim, InvariantSet),
    /// An invariant fired after applying `usize` actions of the prefix.
    Violation(Violation, usize),
    /// The prefix does not apply (hand-edited or stale trace).
    Invalid(String),
}

/// Fire every pending event matching the instance's `auto` predicate, in
/// seq order, until none remain (one auto event may schedule another).
fn drain_autos(inst: &Instance, sim: &mut Sim) {
    loop {
        let next = sim.pending().into_iter().find(|e| (inst.auto)(e));
        match next {
            Some(e) => {
                sim.fire(e.seq);
            }
            None => break,
        }
    }
}

/// Rebuild `inst` and re-apply `actions`, feeding the invariant catalog
/// after the warmup and after every action.
pub fn replay(inst: &Instance, actions: &[Action]) -> Replayed {
    let mut sim = (inst.build)();
    let mut invs = (inst.invariants)();
    drain_autos(inst, &mut sim);
    if let Err(v) = invs.feed(&sim.announces) {
        return Replayed::Violation(v, 0);
    }
    for (i, act) in actions.iter().enumerate() {
        match act {
            Action::Cut(a, b) => {
                if !sim.link_open(*a, *b) {
                    return Replayed::Invalid(format!(
                        "action {i}: cut {a}->{b}, but the link is already severed"
                    ));
                }
                sim.set_link_oneway(*a, *b, false);
                // No deliveries happen on a cut, but feed anyway so the
                // per-action bookkeeping stays uniform.
                drain_autos(inst, &mut sim);
                if let Err(v) = invs.feed(&sim.announces) {
                    return Replayed::Violation(v, i + 1);
                }
                continue;
            }
            Action::Heal(a, b) => {
                if sim.link_open(*a, *b) {
                    return Replayed::Invalid(format!(
                        "action {i}: heal {a}->{b}, but the link is not severed"
                    ));
                }
                sim.set_link_oneway(*a, *b, true);
                drain_autos(inst, &mut sim);
                if let Err(v) = invs.feed(&sim.announces) {
                    return Replayed::Violation(v, i + 1);
                }
                continue;
            }
            Action::Fire(..) | Action::Drop(..) => {}
        }
        let seq = if act.seq() == WILDCARD_SEQ {
            match sim.pending().into_iter().find(|e| e.sig == act.sig()) {
                Some(e) => e.seq,
                None => {
                    return Replayed::Invalid(format!(
                        "action {i}: no pending event matches signature {}",
                        act.sig()
                    ));
                }
            }
        } else {
            act.seq()
        };
        let got = match act {
            Action::Fire(..) => sim.fire(seq),
            Action::Drop(..) => sim.drop_event(seq),
            Action::Cut(..) | Action::Heal(..) => unreachable!("handled above"),
        };
        match got {
            Some(sig) if sig == act.sig() => {}
            Some(sig) => {
                return Replayed::Invalid(format!(
                    "action {i}: trace says {} for seq {}, queue had {sig}",
                    act.sig(),
                    act.seq()
                ));
            }
            None => {
                return Replayed::Invalid(format!(
                    "action {i}: no pending event with seq {} ({})",
                    act.seq(),
                    act.sig()
                ));
            }
        }
        drain_autos(inst, &mut sim);
        if let Err(v) = invs.feed(&sim.announces) {
            return Replayed::Violation(v, i + 1);
        }
    }
    Replayed::State(sim, invs)
}

/// Enumerate the actions enabled in `sim` under the instance's reduction
/// rules: the head of every non-empty `(src, dst)` channel (fire, plus
/// drop while budget remains), the lowest-id pending control, any
/// pending timer passing the instance filter, and — while the partition
/// budget lasts — a cut (or, if already severed, a heal) of each
/// candidate link.
pub fn enabled_actions(inst: &Instance, sim: &Sim, prefix: &[Action]) -> Vec<Action> {
    let drops_used = prefix.iter().filter(|a| matches!(a, Action::Drop(..))).count();
    let part_ops_used = prefix
        .iter()
        .filter(|a| matches!(a, Action::Cut(..) | Action::Heal(..)))
        .count();
    let mut heads: BTreeSet<(NodeId, NodeId)> = BTreeSet::new();
    let mut control_seen = false;
    let mut acts = Vec::new();
    for ev in sim.pending() {
        match ev.kind {
            PendingKind::Deliver { from, to } => {
                if heads.insert((from, to)) {
                    if drops_used < inst.max_drops {
                        acts.push(Action::Drop(ev.seq, ev.sig.clone()));
                    }
                    acts.push(Action::Fire(ev.seq, ev.sig));
                }
            }
            PendingKind::Timer { timer, .. } => {
                if (inst.timers)(&timer) {
                    acts.push(Action::Fire(ev.seq, ev.sig));
                }
            }
            PendingKind::Control => {
                // Controls fire in id order (they model an experiment
                // script, which is sequential).
                if !control_seen {
                    control_seen = true;
                    acts.push(Action::Fire(ev.seq, ev.sig));
                }
            }
        }
    }
    if part_ops_used < inst.max_partition_ops {
        for &(from, to) in inst.partition_links {
            if sim.link_open(from, to) {
                acts.push(Action::Cut(from, to));
            } else {
                acts.push(Action::Heal(from, to));
            }
        }
    }
    acts
}

/// Does `actions` reproduce a violation of invariant `name` on a fresh
/// rebuild? (Feed violations count anywhere; end-of-run violations count
/// only at terminal states, where `finish` is meaningful.)
fn reproduces(inst: &Instance, actions: &[Action], name: &str) -> bool {
    match replay(inst, actions) {
        Replayed::Violation(v, _) => v.invariant == name,
        Replayed::State(sim, invs) => {
            enabled_actions(inst, &sim, actions).is_empty()
                && invs.finish().err().is_some_and(|v| v.invariant == name)
        }
        Replayed::Invalid(_) => false,
    }
}

/// Greedy ddmin-style minimization: repeatedly delete any single action
/// whose removal preserves the violation, to a fixpoint. Quadratic in
/// trace length per pass, which is fine at checker scale — traces are
/// tens of actions.
pub fn shrink(inst: &Instance, actions: &[Action], v: &Violation) -> Vec<Action> {
    let mut cur = actions.to_vec();
    loop {
        let mut improved = false;
        let mut i = 0;
        while i < cur.len() {
            let mut cand = cur.clone();
            cand.remove(i);
            if reproduces(inst, &cand, v.invariant) {
                cur = cand;
                improved = true;
            } else {
                i += 1;
            }
        }
        if !improved {
            return cur;
        }
    }
}

/// What an exploration found.
#[derive(Debug)]
pub struct Report {
    pub instance: &'static str,
    /// Depth bound the run used.
    pub depth: usize,
    /// Prefixes actually rebuilt and replayed — the work the run did.
    pub replays: u64,
    /// States a dedup-free depth-bounded DFS would have expanded: the
    /// exact size of the unfolded schedule tree, computed by memoized
    /// subtree counting (no naive run happens). `f64` because diamonds
    /// compound multiplicatively — at full depth this overflows `u64`.
    pub raw_states: f64,
    /// Distinct state fingerprints.
    pub unique_states: u64,
    /// Distinct states with no enabled actions (full schedules).
    pub terminal_states: u64,
    /// Distinct states cut by the depth bound.
    pub depth_truncated: u64,
    /// The replay cap stopped the run early.
    pub hit_state_cap: bool,
    /// First violation found, if any.
    pub violation: Option<Violation>,
    /// Minimized violating schedule (empty when `violation` is `None`).
    pub trace: Vec<Action>,
}

impl Report {
    /// raw/unique — how much of the schedule tree fingerprint dedup
    /// collapsed.
    pub fn dedup_ratio(&self) -> f64 {
        crate::metrics::dedup_ratio(self.raw_states, self.unique_states)
    }
}

struct Search<'a> {
    inst: &'a Instance,
    depth: usize,
    max_replays: u64,
    /// `(fingerprint, remaining depth) → naive subtree size`. Keying on
    /// remaining depth (not just the fingerprint) keeps the search
    /// complete when the same state is reached at different depths — a
    /// shallower revisit still explores the deeper frontier.
    memo: std::collections::BTreeMap<(u64, usize), f64>,
    seen: BTreeSet<u64>,
    report: Report,
    done: bool,
}

impl Search<'_> {
    /// Expand the state reached by `prefix`; returns the size of the
    /// schedule tree a dedup-free DFS would build below it (inclusive).
    fn dfs(&mut self, prefix: &mut Vec<Action>) -> f64 {
        if self.done {
            return 0.0;
        }
        if self.report.replays >= self.max_replays {
            self.report.hit_state_cap = true;
            self.done = true;
            return 0.0;
        }
        self.report.replays += 1;
        match replay(self.inst, prefix) {
            Replayed::Violation(v, consumed) => {
                self.report.trace = shrink(self.inst, &prefix[..consumed], &v);
                self.report.violation = Some(v);
                self.done = true;
                1.0
            }
            Replayed::Invalid(e) => {
                // Replays of explorer-enumerated actions are deterministic;
                // a mismatch means the instance's `build` is not.
                panic!("instance {} is nondeterministic: {e}", self.inst.name);
            }
            Replayed::State(sim, invs) => {
                let fp = sim.fingerprint(invs.digest());
                let remaining = self.depth - prefix.len();
                if let Some(&n) = self.memo.get(&(fp, remaining)) {
                    return n;
                }
                let fresh = self.seen.insert(fp);
                let acts = enabled_actions(self.inst, &sim, prefix);
                let n = if acts.is_empty() {
                    if fresh {
                        self.report.terminal_states += 1;
                        // End-of-run invariants are meaningful only at
                        // quiescent states (nothing further will happen).
                        if let Err(v) = invs.finish() {
                            self.report.trace = shrink(self.inst, prefix, &v);
                            self.report.violation = Some(v);
                            self.done = true;
                        }
                    }
                    1.0
                } else if remaining == 0 {
                    if fresh {
                        self.report.depth_truncated += 1;
                    }
                    1.0
                } else {
                    let mut total = 1.0;
                    for act in acts {
                        prefix.push(act);
                        total += self.dfs(prefix);
                        prefix.pop();
                        if self.done {
                            break;
                        }
                    }
                    total
                };
                if !self.done {
                    self.memo.insert((fp, remaining), n);
                }
                n
            }
        }
    }
}

/// Depth-bounded DFS from the instance's initial (post-warmup) state.
/// Stops at the first violation (after shrinking it) or when the
/// frontier is exhausted / `max_replays` prefix replays are spent.
pub fn explore(inst: &Instance, depth: usize, max_replays: u64) -> Report {
    let mut search = Search {
        inst,
        depth,
        max_replays,
        memo: Default::default(),
        seen: Default::default(),
        report: Report {
            instance: inst.name,
            depth,
            replays: 0,
            raw_states: 0.0,
            unique_states: 0,
            terminal_states: 0,
            depth_truncated: 0,
            hit_state_cap: false,
            violation: None,
            trace: Vec::new(),
        },
        done: false,
    };
    let mut prefix = Vec::new();
    search.report.raw_states = search.dfs(&mut prefix);
    search.report.unique_states = search.seen.len() as u64;
    search.report
}
