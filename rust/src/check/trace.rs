//! Minimized-schedule trace files: serialize, parse, replay.
//!
//! Format v1 — line-oriented text, one action per line, so traces diff
//! cleanly and can be checked in as regression tests:
//!
//! ```text
//! # repro-check trace v1
//! instance badquorum
//! expect violation chosen-unique
//! fire 41 c0
//! fire 44 d90->7:Client
//! fire 47 d7->2:Phase2A
//! ...
//! ```
//!
//! * `instance <name>` — which [`Instance`] to rebuild.
//! * `expect ok` / `expect violation <invariant>` — the outcome the
//!   replay must reproduce (a regression trace that stops violating is a
//!   *failure*: the bug it pinned is hidden, or the schedule went stale).
//! * `cut <from> <to>` / `heal <from> <to>` — partition actions: sever
//!   or restore the one-way link `from -> to` (only meaningful for
//!   instances that declare the link in `partition_links`).
//! * `fire <seq> <sig>` / `drop <seq> <sig>` — the schedule. Seqs are
//!   the simulator's deterministic event ids; the signature is
//!   re-validated on replay so a stale trace fails loudly instead of
//!   silently exploring a different schedule. A seq of `*` means "the
//!   lowest-seq pending event with this signature" — deterministic, and
//!   lets regression traces be authored in terms of protocol messages
//!   rather than raw scheduler ids.
//! * `#`-lines and blank lines are comments.

use super::explorer::{enabled_actions, replay, Action, Instance, Replayed, WILDCARD_SEQ};
use std::fmt::Write;

/// A parsed trace file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    pub instance: String,
    /// `None` = `expect ok`; `Some(inv)` = `expect violation <inv>`.
    pub expect: Option<String>,
    pub actions: Vec<Action>,
}

/// Render a trace file (format v1).
pub fn serialize(instance: &str, expect: Option<&str>, actions: &[Action]) -> String {
    let mut out = String::from("# repro-check trace v1\n");
    let _ = writeln!(out, "instance {instance}");
    match expect {
        Some(inv) => {
            let _ = writeln!(out, "expect violation {inv}");
        }
        None => out.push_str("expect ok\n"),
    }
    for a in actions {
        let (verb, seq, sig) = match a {
            Action::Fire(seq, sig) => ("fire", *seq, sig),
            Action::Drop(seq, sig) => ("drop", *seq, sig),
            Action::Cut(from, to) => {
                let _ = writeln!(out, "cut {from} {to}");
                continue;
            }
            Action::Heal(from, to) => {
                let _ = writeln!(out, "heal {from} {to}");
                continue;
            }
        };
        if seq == WILDCARD_SEQ {
            let _ = writeln!(out, "{verb} * {sig}");
        } else {
            let _ = writeln!(out, "{verb} {seq} {sig}");
        }
    }
    out
}

/// Parse a trace file (format v1).
pub fn parse(text: &str) -> Result<Trace, String> {
    let mut instance: Option<String> = None;
    let mut expect: Option<Option<String>> = None;
    let mut actions = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(3, ' ');
        let verb = parts.next().unwrap_or("");
        match verb {
            "instance" => {
                let name = parts.next().ok_or(format!("line {}: instance needs a name", ln + 1))?;
                instance = Some(name.to_string());
            }
            "expect" => match parts.next() {
                Some("ok") => expect = Some(None),
                Some("violation") => {
                    let inv = parts
                        .next()
                        .ok_or(format!("line {}: expect violation needs an invariant", ln + 1))?;
                    expect = Some(Some(inv.trim().to_string()));
                }
                other => {
                    return Err(format!(
                        "line {}: expect must be `ok` or `violation <inv>`, got {other:?}",
                        ln + 1
                    ));
                }
            },
            "cut" | "heal" => {
                let from = parts
                    .next()
                    .ok_or(format!("line {}: {verb} needs a source node", ln + 1))?
                    .parse()
                    .map_err(|_| format!("line {}: {verb} needs numeric node ids", ln + 1))?;
                let to = parts
                    .next()
                    .ok_or(format!("line {}: {verb} needs a destination node", ln + 1))?
                    .trim()
                    .parse()
                    .map_err(|_| format!("line {}: {verb} needs numeric node ids", ln + 1))?;
                actions.push(if verb == "cut" {
                    Action::Cut(from, to)
                } else {
                    Action::Heal(from, to)
                });
            }
            "fire" | "drop" => {
                let seq: u64 = match parts.next() {
                    Some("*") => WILDCARD_SEQ,
                    Some(s) => s
                        .parse()
                        .map_err(|_| format!("line {}: {verb} needs a numeric seq or `*`", ln + 1))?,
                    None => return Err(format!("line {}: {verb} needs a seq", ln + 1)),
                };
                let sig = parts
                    .next()
                    .ok_or(format!("line {}: {verb} needs an event signature", ln + 1))?
                    .to_string();
                actions.push(if verb == "fire" {
                    Action::Fire(seq, sig)
                } else {
                    Action::Drop(seq, sig)
                });
            }
            other => return Err(format!("line {}: unknown directive {other:?}", ln + 1)),
        }
    }
    Ok(Trace {
        instance: instance.ok_or("trace has no `instance` line")?,
        expect: expect.ok_or("trace has no `expect` line")?,
        actions,
    })
}

/// Replay a trace against its instance and check the recorded
/// expectation. `Ok` carries a one-line summary; `Err` explains the
/// mismatch (which is a test failure for checked-in regression traces).
pub fn run(inst: &Instance, trace: &Trace) -> Result<String, String> {
    if inst.name != trace.instance {
        return Err(format!(
            "trace is for instance {:?}, replaying against {:?}",
            trace.instance, inst.name
        ));
    }
    let outcome = match replay(inst, &trace.actions) {
        Replayed::Violation(v, consumed) => {
            if consumed < trace.actions.len() {
                return Err(format!(
                    "violation fired after {consumed} of {} actions — trace has dead tail \
                     (re-minimize): {v}",
                    trace.actions.len()
                ));
            }
            Some(v)
        }
        Replayed::State(sim, invs) => {
            // End-of-run checks apply only if the trace ends quiescent.
            if enabled_actions(inst, &sim, &trace.actions).is_empty() {
                invs.finish().err()
            } else {
                None
            }
        }
        Replayed::Invalid(e) => return Err(format!("trace does not replay: {e}")),
    };
    match (&trace.expect, outcome) {
        (None, None) => Ok(format!(
            "replayed {} actions on {}: clean, as expected",
            trace.actions.len(),
            inst.name
        )),
        (Some(want), Some(v)) if want == v.invariant => Ok(format!(
            "replayed {} actions on {}: reproduced {v}",
            trace.actions.len(),
            inst.name
        )),
        (Some(want), Some(v)) => {
            Err(format!("expected a {want} violation, got {v}"))
        }
        (Some(want), None) => Err(format!(
            "expected a {want} violation, replay was clean — regression trace went stale"
        )),
        (None, Some(v)) => Err(format!("expected a clean replay, got {v}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let actions = vec![
            Action::Fire(41, "c0".into()),
            Action::Fire(44, "d90->7:Client".into()),
            Action::Drop(47, "d7->2:Phase2A".into()),
            Action::Fire(50, "t6:Phase2Watchdog".into()),
        ];
        let text = serialize("badquorum", Some("chosen-unique"), &actions);
        let t = parse(&text).unwrap();
        assert_eq!(t.instance, "badquorum");
        assert_eq!(t.expect.as_deref(), Some("chosen-unique"));
        assert_eq!(t.actions, actions);
    }

    #[test]
    fn roundtrip_expect_ok() {
        let text = serialize("base", None, &[]);
        let t = parse(&text).unwrap();
        assert_eq!(t.expect, None);
        assert!(t.actions.is_empty());
    }

    #[test]
    fn sig_with_spaces_survives() {
        // Timer debug reprs contain spaces; the sig is the line's tail.
        let actions = vec![Action::Fire(9, "t6:Phase2Retry { slot: 0, generation: 1 }".into())];
        let text = serialize("base", None, &actions);
        assert_eq!(parse(&text).unwrap().actions, actions);
    }

    #[test]
    fn wildcard_seq_roundtrips() {
        let actions = vec![
            Action::Fire(WILDCARD_SEQ, "c0".into()),
            Action::Drop(WILDCARD_SEQ, "d7->2:Phase2A".into()),
        ];
        let text = serialize("badquorum", Some("chosen-unique"), &actions);
        assert!(text.contains("fire * c0"));
        assert!(text.contains("drop * d7->2:Phase2A"));
        assert_eq!(parse(&text).unwrap().actions, actions);
    }

    #[test]
    fn partition_verbs_roundtrip() {
        let actions = vec![
            Action::Cut(6, 2),
            Action::Fire(WILDCARD_SEQ, "d90->6:Client".into()),
            Action::Heal(6, 2),
        ];
        let text = serialize("partitioned", None, &actions);
        assert!(text.contains("cut 6 2"));
        assert!(text.contains("heal 6 2"));
        assert_eq!(parse(&text).unwrap().actions, actions);
        assert!(parse("instance x\nexpect ok\ncut 6\n").is_err());
        assert!(parse("instance x\nexpect ok\nheal a b\n").is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("instance x\nexpect ok\nfire nope sig").is_err());
        assert!(parse("instance x\nexpect maybe\n").is_err());
        assert!(parse("instance x\nexpect ok\nlaunch 3 x").is_err());
        assert!(parse("expect ok\n").is_err(), "missing instance line");
        assert!(parse("instance x\n").is_err(), "missing expect line");
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let t = parse("# hello\n\ninstance base\n# mid\nexpect ok\n\n").unwrap();
        assert_eq!(t.instance, "base");
    }
}
