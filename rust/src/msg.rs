//! Wire messages for the whole protocol family.
//!
//! One enum carries every message: Matchmaker Paxos / MultiPaxos
//! (MatchA/B, Phase1A/B, Phase2A/B), garbage collection (GarbageA/B, §5),
//! matchmaker reconfiguration (StopA/B, Bootstrap, and the meta-Paxos that
//! chooses the new matchmaker set, §6), the client path, replica
//! acknowledgements (GC Scenario 3), heartbeats for leader election, and
//! nacks. The TCP transport frames [`Envelope`]s with the in-tree binary
//! codec ([`crate::codec`]); the simulator passes them by value.

use crate::config::Configuration;
use crate::round::Round;
use crate::{GroupId, NodeId, Slot, Time};
use std::collections::BTreeMap;

/// A shared matchmaker's full configuration log: per consensus group, the
/// configurations indexed by round (§6: one matchmaker set serves many
/// groups; entries are keyed by `(group, round)`). Carried whole by the
/// matchmaker-reconfiguration messages ([`Msg::StopB`], [`Msg::Bootstrap`]).
pub type MmLog = BTreeMap<GroupId, BTreeMap<Round, Configuration>>;

/// A client command: identified by `(client, seq)` so replicas can
/// deduplicate retries, carrying an opaque payload interpreted by the
/// replicas' state machine.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Command {
    pub client: NodeId,
    pub seq: u64,
    pub payload: Vec<u8>,
}

/// Identifies a command for deduplication: `(client, seq)`.
pub type CommandId = (NodeId, u64);

impl Command {
    pub fn id(&self) -> CommandId {
        (self.client, self.seq)
    }
}

/// A value voted on in a log slot: a client command, a batch of client
/// commands decided together (Phase 2 batching — one quorum round trip
/// chooses up to `OptFlags::batch_size` commands), or a no-op used to
/// fill holes during leader recovery (§4.1), or a reconfiguration marker
/// (used by the Horizontal MultiPaxos baseline, §7.2).
///
/// Proposers and acceptors treat batches opaquely (they are just values);
/// replicas unpack them and execute the commands in order, replying to
/// each client individually.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Value {
    Cmd(Command),
    /// Two or more commands sharing one slot. Invariant (leader-enforced):
    /// batches are never empty; single commands use `Cmd`.
    Batch(Vec<Command>),
    Noop,
    /// Horizontal MultiPaxos only: "configuration `config` takes effect at
    /// slot `chosen_slot + α`".
    Reconfig(Configuration),
}

/// One acceptor's vote state for a slot, reported in Phase1B.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct SlotVote {
    pub slot: Slot,
    /// Round of the vote (`vr`).
    pub vr: Round,
    /// Voted value (`vv`).
    pub vv: Value,
}

/// All protocol messages.
#[derive(Clone, PartialEq, Debug)]
pub enum Msg {
    // ---- Matchmaking phase (§3.2, Algorithm 1; §5, Algorithm 4) ----
    /// Proposer → matchmaker: "group `group` is starting round `round`
    /// with configuration `config`". Matchmakers are shared across
    /// consensus groups (§6), so every matchmaking message names its
    /// group; single-group deployments use group 0.
    MatchA { group: GroupId, round: Round, config: Configuration },
    /// Matchmaker → proposer: the group's prior configurations (`H_i`)
    /// plus the group's GC watermark (§5: rounds `< gc_watermark` are
    /// retired).
    MatchB {
        group: GroupId,
        round: Round,
        gc_watermark: Option<Round>,
        prior: BTreeMap<Round, Configuration>,
    },
    /// Matchmaker → proposer: the MatchA was refused (the group's log
    /// holds a configuration for a round ≥ `round`, or `round` is below
    /// the group's GC watermark). Carries the blocking round so the
    /// proposer can jump past it.
    MatchNack { group: GroupId, round: Round, blocking: Round },

    // ---- Phase 1 (classic Paxos over possibly-many configurations) ----
    /// One Phase1A covers every slot ≥ `from_slot` (MultiPaxos bulk
    /// Phase 1, §4.1).
    Phase1A { round: Round, from_slot: Slot },
    /// Acceptor → proposer: per-slot votes for slots ≥ the request's
    /// `from_slot`, plus the acceptor's chosen-prefix watermark (GC
    /// Scenario 3: slots < `chosen_watermark` are known chosen and stored
    /// on f+1 replicas — the recovering proposer fetches them from
    /// replicas instead of re-running Paxos).
    Phase1B {
        round: Round,
        votes: Vec<SlotVote>,
        chosen_watermark: Slot,
    },

    // ---- Phase 2 ----
    Phase2A { round: Round, slot: Slot, value: Value },
    Phase2B { round: Round, slot: Slot },
    /// Acceptor → proposer: message ignored because the acceptor has seen
    /// `higher`. Prompts the proposer to abandon the round / re-elect.
    Nack { round: Round, higher: Round },

    // ---- Chosen-value dissemination ----
    /// Leader → replicas: `value` is chosen in `slot`.
    Chosen { slot: Slot, value: Value },
    /// Replica → leader: "my contiguous executed/stored prefix reaches
    /// `upto` (exclusive)". Drives GC Scenario 3 (§5.3).
    ReplicaAck { upto: Slot },
    /// Leader → acceptors (a P2 quorum of the active config): the prefix
    /// `< upto` is stored on f+1 replicas (Scenario 3 precondition).
    PrefixPersisted { round: Round, upto: Slot },
    /// Acceptor → leader: acknowledges recording the persisted prefix.
    PrefixAck { round: Round, upto: Slot },
    /// New leader → replica: request the chosen prefix starting at `from`.
    ReadPrefix { from: Slot },
    /// Replica → new leader: chosen prefix entries.
    PrefixResp { entries: Vec<(Slot, Value)>, upto: Slot },

    // ---- Garbage collection (§5, Algorithm 4) ----
    /// Leader → matchmakers: retire the group's configurations below
    /// `round`. GC is per group: a quiet group's entries never pin — and
    /// are never collateral damage of — a busy group's GC.
    GarbageA { group: GroupId, round: Round },
    GarbageB { group: GroupId, round: Round },

    // ---- State retention: snapshot transfer & log truncation ----
    /// Leader → lagging replica: "slots below `below` are truncated from
    /// my log (durable on f+1 replicas); fetch a snapshot from `peer`".
    /// Sent when a replica acks a prefix the leader can no longer re-send
    /// entry by entry.
    CatchUp { below: Slot, peer: NodeId },
    /// Replica → peer replica: request a snapshot covering my missing
    /// prefix (my contiguous executed prefix reaches only `from`).
    SnapshotRequest { from: Slot },
    /// Peer replica → requester: serialized replica state (state machine
    /// + client dedup table) covering all slots `< base`, plus the
    /// retained tail of chosen entries at slots `>= base`.
    SnapshotResp { base: Slot, state: Vec<u8>, entries: Vec<(Slot, Value)> },
    /// Peer replica → requester: one chunk of a chunked snapshot
    /// transfer (the GB-scale replacement for one-shot [`Msg::SnapshotResp`];
    /// see DESIGN.md §Durability). The serialized replica state covering
    /// slots `< base` is split into `total` chunks of bounded size and
    /// streamed in order; `seq` is this chunk's 0-based index. The
    /// receiver assembles chunks keyed by `(sender, base)`, so a sender
    /// restart (which re-snapshots at a new `base`) implicitly restarts
    /// the transfer, and a receiver restart resumes with
    /// [`Msg::SnapshotResume`]. After the final chunk the receiver
    /// installs the snapshot and fetches the retained tail of chosen
    /// entries with an ordinary [`Msg::SnapshotRequest`]`{ from: base }`.
    SnapshotChunk { base: Slot, seq: u32, total: u32, bytes: Vec<u8> },
    /// Requester → peer replica: resume cursor for an in-flight chunked
    /// transfer — "re-send snapshot `base` starting from chunk `next`".
    /// Sent after a receiver restart (the assembly buffer was lost up to
    /// the durable cursor) or when the stream stalls mid-transfer. A
    /// sender that no longer holds snapshot `base` answers with a fresh
    /// transfer at its current base.
    SnapshotResume { base: Slot, next: u32 },

    // ---- Client path ----
    /// Client → leader. `group` names the consensus group the command is
    /// routed to (the shard router hashes the key; single-group clients
    /// send 0). `lowest` is the client's oldest in-flight seq *in that
    /// group's lane*: every seq below it has been acknowledged back to
    /// the client. The leader's per-client sequencer uses it to admit
    /// pipelined requests in FIFO order across network reordering and
    /// leader changes (seqs `< lowest` are settled; seqs `≥ lowest` are
    /// admitted in contiguous order). Sharded clients keep an
    /// independent, contiguous seq stream per group, so per-group FIFO
    /// admission is preserved shard-locally.
    ClientRequest { group: GroupId, cmd: Command, lowest: u64 },
    /// Replica → client: result of executing the command. Tagged with the
    /// replica's group so a shard router can route the reply to the
    /// right per-group lane (seq spaces are per-lane).
    ClientReply { group: GroupId, seq: u64, result: Vec<u8> },
    /// Any node → client/other: "I am not this group's leader; try
    /// `hint`".
    NotLeader { group: GroupId, hint: Option<NodeId> },
    /// Leader → client: admission control pushback (DESIGN.md
    /// §Overload). The leader's proposal inbox is over its configured
    /// bound (`admission = inbox:N,...`), so the request identified by
    /// `seq` was *dropped without side effects* — it never touched the
    /// per-client FIFO sequencer, so the client may retry it after
    /// `retry_after_us` µs (or shed it) without risking reordering or
    /// duplicate execution. Critically, a Busy is NOT an ack: the client
    /// must keep `seq` in its outstanding window so its advertised
    /// `lowest` never advances past a shed command.
    Busy { group: GroupId, seq: u64, retry_after_us: u64 },

    // ---- Linearizable reads off the Phase-2 hot path ----
    /// Client → replica: a linearizable read-only query. Reads never
    /// enter the chosen log: the replica resolves a *read index* (the
    /// leader's contiguous chosen watermark as of a point after this
    /// message arrived), waits until its applied prefix covers it, and
    /// answers from local state via [`crate::statemachine::StateMachine::query`].
    /// `seq` lives in a per-client read-only sequence space, disjoint
    /// from the write stream (reads must not perturb the leader-side
    /// FIFO sequencer).
    Read { group: GroupId, seq: u64, payload: Vec<u8> },
    /// Replica → client: result of a read-only query.
    ReadReply { group: GroupId, seq: u64, result: Vec<u8> },
    /// Replica → leader: "what is your chosen watermark?" — the
    /// ReadIndex fallback when the replica holds no active lease.
    /// `id` is a replica-local token matching the response to the
    /// batch of reads that were pending when the request was sent.
    ReadIndexReq { id: u64 },
    /// Leader → replica: the chosen watermark. Sent immediately under
    /// an active leader lease, else only after a quorum-confirmed lease
    /// renewal (so a deposed leader can never answer with a stale
    /// watermark).
    ReadIndexResp { id: u64, upto: Slot },
    /// Replica → client: this replica cannot serve reads right now
    /// (no lease and no known leader to ReadIndex); try another replica.
    NotLeaseholder { group: GroupId, hint: Option<NodeId> },

    // ---- Read leases (epoch/round-fenced; see DESIGN.md §Reads) ----
    /// Leader → acceptors of the active configuration: extend my lease
    /// for `round`. An acceptor acks only while it has promised no
    /// higher round, so any newer round's Phase 1 (which intersects
    /// every P2 quorum of this configuration) cuts the renewal off.
    LeaseRenew { round: Round, seq: u64 },
    /// Acceptor → leader: renewal ack (promised round still ≤ `round`).
    LeaseRenewAck { round: Round, seq: u64 },
    /// Leader → replicas: the lease, re-broadcast on every renewal and
    /// (throttled) on chosen-watermark advances. `upto` is the leader's
    /// contiguous chosen watermark when the grant was sent; `granted_at`
    /// orders grants against read arrivals at the replica; `valid_until`
    /// is the quorum-confirmed validity horizon, already discounted by
    /// the configured clock-drift bound.
    LeaseGrant { round: Round, upto: Slot, granted_at: Time, valid_until: Time },

    // ---- Matchmaker reconfiguration (§6) ----
    /// Reconfigurer → old matchmakers: stop processing and dump state.
    StopA,
    /// Old matchmaker → reconfigurer: final multi-group log + per-group
    /// GC watermarks (groups absent from the map have no watermark).
    StopB {
        log: MmLog,
        gc_watermarks: BTreeMap<GroupId, Round>,
    },
    /// Reconfigurer → new matchmakers: initial state (merged multi-group
    /// logs) plus the new set's generation number (see the meta-Paxos
    /// note below).
    Bootstrap {
        log: MmLog,
        gc_watermarks: BTreeMap<GroupId, Round>,
        generation: u64,
    },
    BootstrapAck,
    /// Reconfigurer → new matchmakers (start serving) and → its follower
    /// proposers (adopt the set, so a proposer elected mid-migration
    /// does not keep matchmaking at the stopped old set). `generation`
    /// is the chosen set's §6 generation: matchmakers activate only
    /// their own generation, proposers adopt only strictly newer
    /// generations — both reject stale re-deliveries from an earlier
    /// migration.
    MatchmakersActivated { generation: u64, matchmakers: Vec<NodeId> },

    // ---- Meta-Paxos choosing the new matchmaker set (§6): the old
    // matchmakers double as Paxos acceptors for the single value M_new.
    // Each matchmaker *generation* g runs its own single-decree instance
    // choosing generation g+1; `generation` tags the instance so votes
    // from earlier generations can never leak into later ones. ----
    MetaPhase1A { round: Round, generation: u64 },
    MetaPhase1B {
        round: Round,
        vr: Option<Round>,
        vv: Option<Vec<NodeId>>,
    },
    MetaPhase2A { round: Round, generation: u64, matchmakers: Vec<NodeId> },
    MetaPhase2B { round: Round },

    // ---- Failure detection / leader election ----
    Heartbeat { epoch: u64 },
    HeartbeatReply { epoch: u64 },

    // ---- Fast Paxos (§7): clients send directly to acceptors ----
    /// Client/proposer → acceptor: fast-round proposal (counts as a
    /// Phase2A in the fast round with value chosen by the sender).
    FastPropose { round: Round, value: Value },
    /// Acceptor → coordinator: fast-round vote, reporting what it voted.
    FastPhase2B { round: Round, value: Value },
}

/// A routed message: `from → to`.
#[derive(Clone, PartialEq, Debug)]
pub struct Envelope {
    pub from: NodeId,
    pub to: NodeId,
    pub msg: Msg,
}

impl Msg {
    /// Coarse message-kind label, used by the simulator's per-kind delay
    /// injection (the §8.2 ablation delays Phase1B and MatchB by 250 ms)
    /// and by metrics.
    pub fn kind(&self) -> MsgKind {
        match self {
            Msg::MatchA { .. } => MsgKind::MatchA,
            Msg::MatchB { .. } | Msg::MatchNack { .. } => MsgKind::MatchB,
            Msg::Phase1A { .. } => MsgKind::Phase1A,
            Msg::Phase1B { .. } => MsgKind::Phase1B,
            Msg::Phase2A { .. } | Msg::FastPropose { .. } => MsgKind::Phase2A,
            Msg::Phase2B { .. } | Msg::FastPhase2B { .. } => MsgKind::Phase2B,
            Msg::Nack { .. } => MsgKind::Other,
            Msg::Chosen { .. } => MsgKind::Chosen,
            Msg::ClientRequest { .. } => MsgKind::Client,
            Msg::ClientReply { .. } | Msg::NotLeader { .. } => MsgKind::Client,
            Msg::Busy { .. } => MsgKind::Busy,
            Msg::Read { .. }
            | Msg::ReadReply { .. }
            | Msg::ReadIndexReq { .. }
            | Msg::ReadIndexResp { .. }
            | Msg::NotLeaseholder { .. } => MsgKind::Read,
            Msg::LeaseRenew { .. } | Msg::LeaseRenewAck { .. } | Msg::LeaseGrant { .. } => {
                MsgKind::Lease
            }
            Msg::GarbageA { .. } | Msg::GarbageB { .. } => MsgKind::Gc,
            Msg::CatchUp { .. }
            | Msg::SnapshotRequest { .. }
            | Msg::SnapshotResp { .. }
            | Msg::SnapshotChunk { .. }
            | Msg::SnapshotResume { .. } => MsgKind::Snapshot,
            Msg::StopA
            | Msg::StopB { .. }
            | Msg::Bootstrap { .. }
            | Msg::BootstrapAck
            | Msg::MatchmakersActivated { .. }
            | Msg::MetaPhase1A { .. }
            | Msg::MetaPhase1B { .. }
            | Msg::MetaPhase2A { .. }
            | Msg::MetaPhase2B { .. } => MsgKind::MmReconfig,
            Msg::Heartbeat { .. } | Msg::HeartbeatReply { .. } => MsgKind::Heartbeat,
            Msg::ReplicaAck { .. }
            | Msg::PrefixPersisted { .. }
            | Msg::PrefixAck { .. }
            | Msg::ReadPrefix { .. }
            | Msg::PrefixResp { .. } => MsgKind::Other,
        }
    }

    /// The variant's source-level name. Total by construction (the match
    /// below has no wildcard arm, so adding a variant without extending
    /// it is a compile error) — which is what lets the codec's
    /// [`crate::codec::MSG_TAG_TABLE`] exhaustiveness lint pair every
    /// variant with exactly one wire tag.
    pub fn variant_name(&self) -> &'static str {
        match self {
            Msg::MatchA { .. } => "MatchA",
            Msg::MatchB { .. } => "MatchB",
            Msg::MatchNack { .. } => "MatchNack",
            Msg::Phase1A { .. } => "Phase1A",
            Msg::Phase1B { .. } => "Phase1B",
            Msg::Phase2A { .. } => "Phase2A",
            Msg::Phase2B { .. } => "Phase2B",
            Msg::Nack { .. } => "Nack",
            Msg::Chosen { .. } => "Chosen",
            Msg::ReplicaAck { .. } => "ReplicaAck",
            Msg::PrefixPersisted { .. } => "PrefixPersisted",
            Msg::PrefixAck { .. } => "PrefixAck",
            Msg::ReadPrefix { .. } => "ReadPrefix",
            Msg::PrefixResp { .. } => "PrefixResp",
            Msg::GarbageA { .. } => "GarbageA",
            Msg::GarbageB { .. } => "GarbageB",
            Msg::ClientRequest { .. } => "ClientRequest",
            Msg::ClientReply { .. } => "ClientReply",
            Msg::NotLeader { .. } => "NotLeader",
            Msg::Busy { .. } => "Busy",
            Msg::StopA => "StopA",
            Msg::StopB { .. } => "StopB",
            Msg::Bootstrap { .. } => "Bootstrap",
            Msg::BootstrapAck => "BootstrapAck",
            Msg::MatchmakersActivated { .. } => "MatchmakersActivated",
            Msg::MetaPhase1A { .. } => "MetaPhase1A",
            Msg::MetaPhase1B { .. } => "MetaPhase1B",
            Msg::MetaPhase2A { .. } => "MetaPhase2A",
            Msg::MetaPhase2B { .. } => "MetaPhase2B",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::HeartbeatReply { .. } => "HeartbeatReply",
            Msg::FastPropose { .. } => "FastPropose",
            Msg::FastPhase2B { .. } => "FastPhase2B",
            Msg::CatchUp { .. } => "CatchUp",
            Msg::SnapshotRequest { .. } => "SnapshotRequest",
            Msg::SnapshotResp { .. } => "SnapshotResp",
            Msg::SnapshotChunk { .. } => "SnapshotChunk",
            Msg::SnapshotResume { .. } => "SnapshotResume",
            Msg::Read { .. } => "Read",
            Msg::ReadReply { .. } => "ReadReply",
            Msg::ReadIndexReq { .. } => "ReadIndexReq",
            Msg::ReadIndexResp { .. } => "ReadIndexResp",
            Msg::NotLeaseholder { .. } => "NotLeaseholder",
            Msg::LeaseRenew { .. } => "LeaseRenew",
            Msg::LeaseRenewAck { .. } => "LeaseRenewAck",
            Msg::LeaseGrant { .. } => "LeaseGrant",
        }
    }
}

/// Coarse message classification (see [`Msg::kind`]).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum MsgKind {
    MatchA,
    MatchB,
    Phase1A,
    Phase1B,
    Phase2A,
    Phase2B,
    Chosen,
    Client,
    /// Admission-control pushback (`Busy`): the leader shed a request
    /// at its bounded inbox. Tracked as its own kind so per-group
    /// busy-rate metrics can count pushback without string matching.
    Busy,
    /// Linearizable-read traffic (`Read`/`ReadReply`/`ReadIndexReq`/
    /// `ReadIndexResp`/`NotLeaseholder`).
    Read,
    /// Lease renewal and grant traffic (`LeaseRenew`/`LeaseRenewAck`/
    /// `LeaseGrant`).
    Lease,
    Gc,
    /// Snapshot catch-up traffic (`CatchUp`/`SnapshotRequest`/
    /// `SnapshotResp`/`SnapshotChunk`/`SnapshotResume`).
    Snapshot,
    MmReconfig,
    Heartbeat,
    Other,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Configuration;

    #[test]
    fn command_id() {
        let c = Command { client: 3, seq: 9, payload: vec![1] };
        assert_eq!(c.id(), (3, 9));
    }

    #[test]
    fn wire_roundtrip() {
        use crate::codec::Wire;
        let msgs = vec![
            Msg::MatchA {
                group: 3,
                round: Round::first(0, 1),
                config: Configuration::majority(0, vec![2, 3, 4]),
            },
            Msg::Phase1B {
                round: Round::first(1, 0),
                votes: vec![SlotVote {
                    slot: 7,
                    vr: Round::first(0, 1),
                    vv: Value::Noop,
                }],
                chosen_watermark: 3,
            },
            Msg::ClientRequest {
                group: 2,
                cmd: Command { client: 9, seq: 2, payload: vec![0xab] },
                lowest: 1,
            },
            Msg::StopB { log: BTreeMap::new(), gc_watermarks: BTreeMap::new() },
            Msg::Read { group: 1, seq: 4, payload: vec![b'g', 1, b'k'] },
            Msg::ReadReply { group: 1, seq: 4, result: vec![7, 7] },
            Msg::ReadIndexReq { id: 9 },
            Msg::ReadIndexResp { id: 9, upto: 123 },
            Msg::NotLeaseholder { group: 2, hint: Some(14) },
            Msg::Busy { group: 1, seq: 42, retry_after_us: 5_000 },
            Msg::LeaseRenew { round: Round::first(0, 1), seq: 3 },
            Msg::LeaseRenewAck { round: Round::first(0, 1), seq: 3 },
            Msg::LeaseGrant {
                round: Round::first(0, 1),
                upto: 50,
                granted_at: 1_000_000,
                valid_until: 51_000_000,
            },
        ];
        for m in msgs {
            let back = Msg::decode(&m.encode()).unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn kind_classification() {
        assert_eq!(
            Msg::MatchNack {
                group: 0,
                round: Round::first(0, 0),
                blocking: Round::first(1, 0)
            }
            .kind(),
            MsgKind::MatchB
        );
        assert_eq!(
            Msg::Phase1B { round: Round::first(0, 0), votes: vec![], chosen_watermark: 0 }.kind(),
            MsgKind::Phase1B
        );
        assert_eq!(Msg::StopA.kind(), MsgKind::MmReconfig);
        assert_eq!(
            Msg::Busy { group: 0, seq: 1, retry_after_us: 1000 }.kind(),
            MsgKind::Busy
        );
        assert_eq!(Msg::Heartbeat { epoch: 0 }.kind(), MsgKind::Heartbeat);
        assert_eq!(
            Msg::Read { group: 0, seq: 1, payload: vec![] }.kind(),
            MsgKind::Read
        );
        assert_eq!(Msg::ReadIndexReq { id: 0 }.kind(), MsgKind::Read);
        assert_eq!(Msg::LeaseRenew { round: Round::first(0, 0), seq: 1 }.kind(), MsgKind::Lease);
        assert_eq!(
            Msg::LeaseGrant { round: Round::first(0, 0), upto: 0, granted_at: 0, valid_until: 1 }
                .kind(),
            MsgKind::Lease
        );
        assert_eq!(Msg::SnapshotRequest { from: 3 }.kind(), MsgKind::Snapshot);
        assert_eq!(Msg::CatchUp { below: 9, peer: 1 }.kind(), MsgKind::Snapshot);
        assert_eq!(
            Msg::SnapshotChunk { base: 9, seq: 0, total: 2, bytes: vec![1] }.kind(),
            MsgKind::Snapshot
        );
        assert_eq!(Msg::SnapshotResume { base: 9, next: 1 }.kind(), MsgKind::Snapshot);
    }

    #[test]
    fn envelope_wire() {
        use crate::codec::Wire;
        let e = Envelope { from: 1, to: 2, msg: Msg::BootstrapAck };
        let back = Envelope::decode(&e.encode()).unwrap();
        assert_eq!(back, e);
    }
}
