//! One driver per paper table/figure (§8). See DESIGN.md's
//! per-experiment index. All drivers run on the deterministic simulator;
//! absolute numbers differ from the paper's EC2 testbed but the shapes —
//! who wins, where the stalls are, what recovers when — are the point.

use super::report::{
    BenchJson, BenchRow, CurveReport, FigureReport, NemesisReport, NemesisRow, OpenLoopReport,
    OverloadReport, OverloadRow, ReadReport, RetentionReport, ShardReport, TableReport,
    ViolinReport,
};
use super::{msec, secs, Cluster, HorizontalCluster, ShardedCluster};
use crate::config::{AdmissionSpec, Configuration, LeaseSpec, OptFlags, SnapshotSpec};
use crate::nemesis::{Fault, NemesisEvent, NemesisPlan};
use crate::metrics::{
    check_counter_reads, group_summary, interval_summary, open_loop_summary, rate_in_window,
    read_mix_summary, timeline, GroupSummary, OpenLoopSummary, ReadMixSummary, ReadSample,
    RetentionSummary, Sample, Timeline,
};
use crate::roles::{HorizontalLeader, Leader, Replica};
use crate::round::Round;
use crate::sim::NetworkModel;
use crate::statemachine::{Counter, TensorStateMachine};
use crate::util::stats;
use crate::workload::WorkloadSpec;
use crate::{NodeId, Time, MS, SEC, US};

/// Output of one reconfiguration-timeline run (the Figure 9 family).
pub struct ReconfigRun {
    pub samples: Vec<Sample>,
    pub timeline: Timeline,
    /// (reconfig→active ms, reconfig→retired ms) per reconfiguration.
    pub reconfig_latencies: Vec<(f64, Option<f64>)>,
    /// Max |H_i| the leader ever saw.
    pub max_prior_configs: usize,
}

/// The §8.1 schedule: 35 s; no reconfigs in [0,10) s; one acceptor
/// reconfiguration per second in [10,20) s (random 2f+1 of the
/// 2·(2f+1)-acceptor pool); an acceptor failure at 25 s; a reconfiguration
/// replacing it at 30 s.
pub fn run_reconfig_schedule(
    f: usize,
    n_clients: usize,
    thrifty: bool,
    seed: u64,
    duration: Time,
) -> ReconfigRun {
    let mut opts = OptFlags::default();
    opts.thrifty = thrifty;
    let mut cluster = Cluster::builder().f(f).clients(n_clients).opts(opts).seed(seed).build();
    let leader = cluster.initial_leader();

    // Pre-draw the ten reconfiguration targets (ids 1..=10).
    let cfgs: Vec<Configuration> = (1..=10).map(|i| cluster.random_config(i)).collect();
    let mut issue_times: Vec<(Time, Round)> = Vec::new();
    for (i, cfg) in cfgs.iter().cloned().enumerate() {
        let at = secs(10) + i as Time * SEC;
        // Round of the (i+1)'th reconfiguration: epoch 1, seq i+1 (seq 0 is
        // the startup installation).
        issue_times.push((at, Round { epoch: 1, proposer: leader, seq: i as u64 + 1 }));
        cluster.sim.schedule(at, move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
    }

    // At 25 s fail one acceptor of the then-active configuration; at 30 s
    // reconfigure to a set that excludes it.
    let last_cfg = cfgs.last().unwrap().clone();
    let victim = last_cfg.acceptors[0];
    cluster.sim.schedule(secs(25), move |s| s.crash(victim));
    let mut replacement = cluster.random_config(11);
    while replacement.acceptors.contains(&victim) {
        replacement = cluster.random_config(11);
    }
    issue_times.push((secs(30), Round { epoch: 1, proposer: leader, seq: 11 }));
    cluster.sim.schedule(secs(30), move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(replacement.clone(), now, fx));
    });

    cluster.sim.run_until(duration);
    cluster.assert_safe();

    let samples = cluster.samples();
    let tl = timeline(&samples, duration, SEC, 250 * MS);
    let reconfig_latencies = cluster.reconfig_latencies(&issue_times);
    let max_prior = cluster
        .sim
        .node_mut::<Leader>(leader)
        .map(|l| l.max_prior_configs)
        .unwrap_or(0);
    ReconfigRun {
        samples,
        timeline: tl,
        reconfig_latencies,
        max_prior_configs: max_prior,
    }
}

/// Figure 9 + Table 1: Matchmaker MultiPaxos latency/throughput under the
/// reconfiguration schedule, f = 1, clients ∈ {1, 4, 8}, thrifty.
pub fn figure9(seed: u64) -> (FigureReport, TableReport) {
    reconfig_figure("F9", "Matchmaker MultiPaxos reconfiguration (f=1, thrifty)", 1, true, seed)
}

/// Figure 11: the f = 2 variant of Figure 9.
pub fn figure11(seed: u64) -> (FigureReport, TableReport) {
    reconfig_figure("F11", "Matchmaker MultiPaxos reconfiguration (f=2, thrifty)", 2, true, seed)
}

/// Figure 15: Figure 9 without thriftiness.
pub fn figure15(seed: u64) -> (FigureReport, TableReport) {
    reconfig_figure("F15", "Matchmaker MultiPaxos reconfiguration (f=1, non-thrifty)", 1, false, seed)
}

fn reconfig_figure(
    id: &str,
    title: &str,
    f: usize,
    thrifty: bool,
    seed: u64,
) -> (FigureReport, TableReport) {
    let mut fig = FigureReport { id: id.into(), title: title.into(), ..Default::default() };
    let mut tab = TableReport {
        id: format!("T-{id}"),
        title: format!("{title}: [0,10)s vs [10,20)s"),
        ..Default::default()
    };
    for &clients in &[1usize, 4, 8] {
        let run = run_reconfig_schedule(f, clients, thrifty, seed + clients as u64, secs(35));
        if let (Some(a), Some(b)) = (
            interval_summary(&run.samples, 0, secs(10)),
            interval_summary(&run.samples, secs(10), secs(20)),
        ) {
            tab.rows.push((clients, a, b));
        }
        if clients == 8 {
            let act: Vec<f64> = run.reconfig_latencies.iter().map(|(a, _)| *a).collect();
            let ret: Vec<f64> =
                run.reconfig_latencies.iter().filter_map(|(_, r)| *r).collect();
            if let (Some(sa), Some(sr)) = (stats(&act), stats(&ret)) {
                fig.notes.push(format!(
                    "reconfig→active median {:.2} ms, reconfig→retired median {:.2} ms \
                     (paper: ~1 ms active, ~5 ms retired)",
                    sa.median, sr.median
                ));
            }
            fig.notes.push(format!(
                "max |H_i| seen by the leader: {} (paper: matchmakers usually return one config)",
                run.max_prior_configs
            ));
        }
        fig.series.push((format!("{clients} client(s)"), run.timeline));
    }
    (fig, tab)
}

/// Figure 16: Figure 9 with 100 clients (more natural variance; same
/// trends).
pub fn figure16(seed: u64) -> FigureReport {
    let run = run_reconfig_schedule(1, 100, true, seed, secs(35));
    FigureReport {
        id: "F16".into(),
        title: "Figure 9 with 100 clients".into(),
        series: vec![("100 clients".into(), run.timeline)],
        notes: vec![format!(
            "reconfig→active median {:.2} ms over {} reconfigs",
            stats(&run.reconfig_latencies.iter().map(|(a, _)| *a).collect::<Vec<_>>())
                .map(|s| s.median)
                .unwrap_or(f64::NAN),
            run.reconfig_latencies.len()
        )],
    }
}

/// Figures 12/13: violin-plot data (distribution quartiles) for the
/// Figure 9 and Figure 10 runs.
pub fn figure12_13(seed: u64) -> ViolinReport {
    let mut rep = ViolinReport {
        id: "F12/F13".into(),
        title: "latency distribution quartiles, [0,10)s vs [10,20)s (ms)".into(),
        groups: vec![],
    };
    for &clients in &[1usize, 4, 8] {
        let run = run_reconfig_schedule(1, clients, true, seed + clients as u64, secs(21));
        for (label, from, to) in
            [("0-10s", 0, secs(10)), ("10-20s", secs(10), secs(20))]
        {
            if let Some(s) = interval_summary(&run.samples, from, to) {
                rep.groups.push((
                    format!("mm/{clients}c/{label}"),
                    s.latency.p25,
                    s.latency.median,
                    s.latency.p75,
                    s.latency.p95,
                ));
            }
        }
    }
    rep
}

/// Horizontal MultiPaxos under the same §8.1 schedule (Figure 10), α = 8.
pub fn run_horizontal_schedule(
    f: usize,
    n_clients: usize,
    with_reconfigs: bool,
    seed: u64,
    duration: Time,
) -> (Vec<Sample>, Timeline) {
    let mut cluster = HorizontalCluster::builder().f(f).clients(n_clients).alpha(8).seed(seed).build();
    let leader = cluster.leader;
    if with_reconfigs {
        let cfgs: Vec<Configuration> = (1..=10).map(|i| cluster.random_config(i)).collect();
        let last = cfgs.last().unwrap().clone();
        for (i, cfg) in cfgs.into_iter().enumerate() {
            let at = secs(10) + i as Time * SEC;
            cluster.sim.schedule(at, move |s| {
                s.with_node::<HorizontalLeader, _>(leader, |l, now, fx| {
                    l.reconfigure(cfg.clone(), now, fx)
                });
            });
        }
        let victim = last.acceptors[0];
        cluster.sim.schedule(secs(25), move |s| s.crash(victim));
        let mut replacement = cluster.random_config(11);
        while replacement.acceptors.contains(&victim) {
            replacement = cluster.random_config(11);
        }
        cluster.sim.schedule(secs(30), move |s| {
            s.with_node::<HorizontalLeader, _>(leader, |l, now, fx| {
                l.reconfigure(replacement.clone(), now, fx)
            });
        });
    }
    cluster.sim.run_until(duration);
    cluster.sim.check_chosen_safety().expect("horizontal safety");
    let samples = cluster.samples();
    let tl = timeline(&samples, duration, SEC, 250 * MS);
    (samples, tl)
}

/// Figure 10: Horizontal MultiPaxos with reconfigurations (f=1, α=8).
pub fn figure10(seed: u64) -> (FigureReport, TableReport) {
    let mut fig = FigureReport {
        id: "F10".into(),
        title: "Horizontal MultiPaxos reconfiguration (f=1, α=8)".into(),
        ..Default::default()
    };
    let mut tab = TableReport {
        id: "T-F10".into(),
        title: "Horizontal MultiPaxos: [0,10)s vs [10,20)s".into(),
        ..Default::default()
    };
    for &clients in &[1usize, 4, 8] {
        let (samples, tl) = run_horizontal_schedule(1, clients, true, seed + clients as u64, secs(35));
        if let (Some(a), Some(b)) = (
            interval_summary(&samples, 0, secs(10)),
            interval_summary(&samples, secs(10), secs(20)),
        ) {
            tab.rows.push((clients, a, b));
        }
        fig.series.push((format!("{clients} client(s)"), tl));
    }
    (fig, tab)
}

/// Figure 19: plain Horizontal MultiPaxos (no failures, no reconfigs).
pub fn figure19(seed: u64) -> FigureReport {
    let mut fig = FigureReport {
        id: "F19".into(),
        title: "Horizontal MultiPaxos steady state (f=1)".into(),
        ..Default::default()
    };
    for &clients in &[1usize, 4, 8] {
        let (_, tl) = run_horizontal_schedule(1, clients, false, seed + clients as u64, secs(20));
        fig.series.push((format!("{clients} client(s)"), tl));
    }
    fig
}

/// Figure 14: latency-throughput curves with and without thriftiness
/// (no reconfigurations, no failures).
pub fn figure14(seed: u64) -> CurveReport {
    let mut rep = CurveReport {
        id: "F14".into(),
        title: "latency-throughput curves, thrifty vs non-thrifty".into(),
        ..Default::default()
    };
    for &thrifty in &[true, false] {
        let mut rows = Vec::new();
        for &clients in &[1usize, 2, 4, 8, 16, 32, 64, 100] {
            let mut opts = OptFlags::default();
            opts.thrifty = thrifty;
            let mut cluster = Cluster::builder()
                .clients(clients)
                .opts(opts)
                .seed(seed + clients as u64)
                .build();
            cluster.sim.run_until(secs(10));
            cluster.assert_safe();
            let samples = cluster.samples();
            if let Some(s) = interval_summary(&samples, secs(1), secs(10)) {
                let tput = samples
                    .iter()
                    .filter(|(t, _)| *t >= secs(1) && *t < secs(10))
                    .count() as f64
                    / 9.0;
                rows.push((clients, tput, s.latency.median));
            }
        }
        rep.series.push((
            if thrifty { "thrifty" } else { "non-thrifty" }.to_string(),
            rows,
        ));
    }
    rep.notes.push(
        "expected shape: thrifty peak throughput > non-thrifty (fewer Phase2 messages)".into(),
    );
    rep
}

/// Figure 17: the optimization ablation on an emulated WAN — Phase1B and
/// MatchB delayed by 250 ms; 8 clients; 20 s; 5 reconfigurations; max
/// latency over 500 ms windows, throughput over 250 ms windows.
pub fn figure17(seed: u64) -> FigureReport {
    let mut fig = FigureReport {
        id: "F17".into(),
        title: "ablation: optimizations under 250 ms WAN Phase1/Matchmaking delays".into(),
        ..Default::default()
    };
    let variants: [(&str, OptFlags); 4] = [
        ("no optimizations (stop-the-world)", OptFlags {
            proactive_matchmaking: false,
            phase1_bypass: false,
            garbage_collection: false,
            round_pruning: false,
            thrifty: true,
            ..OptFlags::default()
        }),
        ("+ garbage collection", OptFlags {
            proactive_matchmaking: false,
            phase1_bypass: false,
            garbage_collection: true,
            round_pruning: false,
            thrifty: true,
            ..OptFlags::default()
        }),
        ("+ GC + Phase 1 bypassing", OptFlags {
            proactive_matchmaking: false,
            phase1_bypass: true,
            garbage_collection: true,
            round_pruning: false,
            thrifty: true,
            ..OptFlags::default()
        }),
        ("all optimizations", OptFlags::default()),
    ];
    for (label, opts) in variants {
        let net = NetworkModel::default().with_wan_phase1(250 * MS);
        let mut cluster = Cluster::builder().clients(8).opts(opts).seed(seed).net(net).build();
        let leader = cluster.initial_leader();
        // Five reconfigurations at 4, 6, 8, 10, 12 s.
        for i in 0..5u64 {
            let cfg = cluster.random_config(i + 1);
            let at = secs(4) + i * 2 * SEC;
            cluster.sim.schedule(at, move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
            });
        }
        cluster.sim.run_until(secs(20));
        cluster.assert_safe();
        let samples = cluster.samples();
        // Paper: max latency over 500 ms windows; throughput over 250 ms.
        let mut tl = timeline(&samples, secs(20), 500 * MS, 250 * MS);
        let tp = timeline(&samples, secs(20), 250 * MS, 250 * MS);
        tl.throughput = tp.throughput.clone();
        fig.series.push((label.to_string(), tl));
    }
    fig.notes.push(
        "expected shape: ∅ → 500 ms latency spikes & 500 ms zero-throughput gaps per reconfig; \
         +GC similar; +bypass → 250 ms spikes; all → flat (paper Fig. 17)"
            .into(),
    );
    fig
}

/// Figure 18: leader failure. 20 s; the leader fails at 7 s; the next
/// proposer's election timeout is 5 s, so a new leader takes over at ~12 s.
pub fn figure18(seed: u64) -> FigureReport {
    let mut fig = FigureReport {
        id: "F18".into(),
        title: "leader failure at 7 s; new leader at ~12 s".into(),
        ..Default::default()
    };
    for &clients in &[1usize, 4, 8] {
        let mut cluster = Cluster::builder().clients(clients).seed(seed + clients as u64).build();
        let p0 = cluster.layout.proposers[0];
        let p1 = cluster.layout.proposers[1];
        // Paper: "5 seconds later, a new leader is elected. The 5 second
        // delay is arbitrary."
        if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
            l.timing.election_timeout = secs(5);
        }
        cluster.sim.schedule(secs(7), move |s| s.crash(p0));
        cluster.sim.run_until(secs(20));
        cluster.assert_safe();
        let samples = cluster.samples();
        fig.series.push((
            format!("{clients} client(s)"),
            timeline(&samples, secs(20), SEC, 250 * MS),
        ));
    }
    fig.notes
        .push("expected shape: throughput → 0 at 7 s, recovery within ~2 s of election".into());
    fig
}

/// Figure 20: leader + acceptor + matchmaker fail simultaneously at 7 s;
/// new leader at ~11 s; acceptor reconfiguration at 17 s; matchmaker
/// reconfiguration at 22 s.
pub fn figure20(seed: u64) -> FigureReport {
    let mut cluster = Cluster::builder().clients(8).seed(seed).build();
    let p0 = cluster.layout.proposers[0];
    let p1 = cluster.layout.proposers[1];
    let dead_acc = cluster.layout.acceptor_pool[0];
    let dead_mm = cluster.layout.matchmaker_pool[0];
    if let Some(l) = cluster.sim.node_mut::<Leader>(p1) {
        l.timing.election_timeout = secs(4);
    }
    cluster.sim.schedule(secs(7), move |s| {
        s.crash(p0);
        s.crash(dead_acc);
        s.crash(dead_mm);
    });
    // Reconfigure away from the failed acceptor (new leader p1, 17 s).
    let healthy_acc: Vec<NodeId> = cluster
        .layout
        .acceptor_pool
        .iter()
        .copied()
        .filter(|&a| a != dead_acc)
        .take(3)
        .collect();
    let cfg = Configuration::majority(50, healthy_acc);
    cluster.sim.schedule(secs(17), move |s| {
        s.with_node::<Leader, _>(p1, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });
    // Reconfigure away from the failed matchmaker (22 s).
    let healthy_mm: Vec<NodeId> = cluster
        .layout
        .matchmaker_pool
        .iter()
        .copied()
        .filter(|&m| m != dead_mm)
        .take(3)
        .collect();
    cluster.sim.schedule(secs(22), move |s| {
        s.with_node::<Leader, _>(p1, |l, now, fx| {
            l.reconfigure_matchmakers(healthy_mm.clone(), now, fx)
        });
    });
    cluster.sim.run_until(secs(25));
    cluster.assert_safe();
    let samples = cluster.samples();
    FigureReport {
        id: "F20".into(),
        title: "simultaneous leader+acceptor+matchmaker failure".into(),
        series: vec![("8 clients".into(), timeline(&samples, secs(25), SEC, 250 * MS))],
        notes: vec![
            "expected shape: tput → 0 at 7 s; reduced after election (failed acceptor + thrifty); \
             normal after acceptor reconfig at 17 s; unchanged by mm reconfig at 22 s"
                .into(),
        ],
    }
}

/// Figure 21 + Table 2: matchmaker reconfiguration. 40 s; one matchmaker
/// reconfiguration per second in [10,20) s; matchmaker failure at 25 s;
/// replacement at 30 s; acceptor reconfiguration at 35 s.
pub fn figure21(seed: u64) -> (FigureReport, TableReport) {
    let mut fig = FigureReport {
        id: "F21".into(),
        title: "matchmaker reconfiguration (f=1)".into(),
        ..Default::default()
    };
    let mut tab = TableReport {
        id: "T2".into(),
        title: "matchmaker reconfiguration: [0,10)s vs [10,20)s".into(),
        ..Default::default()
    };
    for &clients in &[1usize, 4, 8] {
        let mut cluster = Cluster::builder().clients(clients).seed(seed + clients as u64).build();
        let leader = cluster.initial_leader();
        // Ten random matchmaker sets, one per second in [10,20).
        let mut last_set = cluster.layout.initial_matchmakers();
        for i in 0..10u64 {
            let set = cluster.random_matchmakers();
            last_set = set.clone();
            cluster.sim.schedule(secs(10) + i * SEC, move |s| {
                s.with_node::<Leader, _>(leader, |l, now, fx| {
                    l.reconfigure_matchmakers(set.clone(), now, fx)
                });
            });
        }
        // Fail one active matchmaker at 25 s, replace the set at 30 s.
        let victim = last_set[0];
        cluster.sim.schedule(secs(25), move |s| s.crash(victim));
        let mut replacement = cluster.random_matchmakers();
        while replacement.contains(&victim) {
            replacement = cluster.random_matchmakers();
        }
        cluster.sim.schedule(secs(30), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| {
                l.reconfigure_matchmakers(replacement.clone(), now, fx)
            });
        });
        // Acceptor reconfiguration at 35 s (shows mm reconfig doesn't
        // impair later acceptor reconfigs).
        let cfg = cluster.random_config(99);
        cluster.sim.schedule(secs(35), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        cluster.sim.run_until(secs(40));
        cluster.assert_safe();
        let samples = cluster.samples();
        if let (Some(a), Some(b)) = (
            interval_summary(&samples, 0, secs(10)),
            interval_summary(&samples, secs(10), secs(20)),
        ) {
            tab.rows.push((clients, a, b));
        }
        let mm_reconfigs = cluster
            .sim
            .announces
            .iter()
            .filter(|(_, _, a)| matches!(a, crate::node::Announce::MatchmakersReconfigured { .. }))
            .count();
        if clients == 8 {
            fig.notes.push(format!(
                "matchmaker reconfigurations completed: {mm_reconfigs} (10 scheduled + replacement)"
            ));
        }
        fig.series.push((
            format!("{clients} client(s)"),
            timeline(&samples, secs(40), SEC, 250 * MS),
        ));
    }
    (fig, tab)
}

/// Output of one batching-throughput run (the X3 experiment).
pub struct BatchingRun {
    pub batch_size: usize,
    /// Commands per simulated second after warm-up.
    pub throughput: f64,
    /// Median latency after warm-up, ms.
    pub median_ms: f64,
    /// Total commands completed.
    pub commands: usize,
}

/// Per-client 16-lane tensor command, keyed off the client's node id so
/// every client streams a distinct (deterministic) payload (used via
/// [`crate::workload::PayloadSpec::PerClient`]).
pub fn tensor_lane_payload(id: NodeId) -> Vec<u8> {
    let cmd: Vec<f32> = (0..16)
        .map(|j| ((id as usize * 16 + j) % 13) as f32 / 4.0 - 1.5)
        .collect();
    TensorStateMachine::encode(&cmd)
}

/// X3: Phase 2 batching on the tensor state machine path — the shape of
/// the paper's Figure 8 runs (throughput vs per-slot amortization), on a
/// network model with a finite per-message egress cost (`tx_overhead`),
/// which is the resource batching trades against. A mid-stream acceptor
/// reconfiguration checks that batches keep flowing through matchmaking
/// (Optimization 1) without loss.
///
/// Replicas execute every chosen batch through
/// [`TensorStateMachine::apply_batch`]-backed `apply_many` (batch sizes
/// 1/8/32, padded), so one quorum round trip chooses and one tensor
/// invocation executes up to 32 commands.
pub fn run_batching_throughput(
    seed: u64,
    batch_size: usize,
    n_clients: usize,
    duration: Time,
) -> BatchingRun {
    let opts = OptFlags::default().with_batching(batch_size, 500 * US);
    let mut net = NetworkModel::default();
    net.tx_overhead = 20 * US;
    let mut cluster = Cluster::builder()
        .clients(n_clients)
        .workload(WorkloadSpec::closed_loop().payload_with(tensor_lane_payload))
        .opts(opts)
        .seed(seed)
        .net(net)
        .build();

    // Tensor state machines on the replicas (16 f32 lanes per command).
    for &r in &cluster.layout.replicas.clone() {
        let sm = TensorStateMachine::load().expect("tensor state machine");
        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
            rep.sm = Box::new(sm);
        }
    }

    // Reconfigure the acceptors mid-stream: batching must be correct
    // across the configuration change.
    let leader = cluster.initial_leader();
    let cfg = cluster.random_config(1);
    cluster.sim.schedule(duration / 2, move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });

    cluster.sim.run_until(duration);
    cluster.assert_safe();

    let samples = cluster.samples();
    let warm = duration / 5;
    let n = samples.iter().filter(|(t, _)| *t >= warm).count();
    let throughput = n as f64 / ((duration - warm) as f64 / 1e9);
    let median_ms = interval_summary(&samples, warm, duration)
        .map(|s| s.latency.median)
        .unwrap_or(f64::NAN);
    BatchingRun { batch_size, throughput, median_ms, commands: samples.len() }
}

/// X3 report: batch sizes 1/8/32 with 32 closed-loop clients.
pub fn batching_figure(seed: u64) -> CurveReport {
    let mut rep = CurveReport {
        id: "X3".into(),
        title: "Phase 2 batching on the tensor SM path (first column = batch_size, \
                32 clients, 20 µs/msg egress)"
            .into(),
        ..Default::default()
    };
    let mut rows = Vec::new();
    for &bs in &[1usize, 8, 32] {
        let run = run_batching_throughput(seed, bs, 32, secs(5));
        rows.push((run.batch_size, run.throughput, run.median_ms));
    }
    if let (Some(b1), Some(b32)) =
        (rows.iter().find(|r| r.0 == 1), rows.iter().find(|r| r.0 == 32))
    {
        rep.notes.push(format!(
            "batch_size 32 vs 1: {:.1}x simulated throughput (acceptance target: >= 2x)",
            b32.1 / b1.1
        ));
    }
    rep.series.push(("tensor path".into(), rows));
    rep
}

/// One open-loop run: `n_clients` clients each offering
/// `rate_per_client` commands/s (fixed-rate, or deterministic-Poisson
/// with `poisson`) with up to `max_in_flight` requests pipelined, over
/// `duration`, with an acceptor reconfiguration at `duration / 2` —
/// reconfiguration under sustained offered load is the regime related
/// reconfiguration work (logless reconfig, "dirty logs") measures.
/// Returns the offered/completed/tail summary; asserts safety.
pub fn run_offered_load(
    n_clients: usize,
    rate_per_client: f64,
    max_in_flight: usize,
    poisson: bool,
    seed: u64,
    duration: Time,
) -> OpenLoopSummary {
    let base = if poisson {
        WorkloadSpec::open_loop_poisson(rate_per_client)
    } else {
        WorkloadSpec::open_loop(rate_per_client)
    };
    let mut cluster = Cluster::builder()
        .clients(n_clients)
        .workload(base.max_in_flight(max_in_flight))
        .seed(seed)
        .net(NetworkModel::lan())
        .build();
    let leader = cluster.initial_leader();
    let cfg = cluster.random_config(1);
    cluster.sim.schedule(duration / 2, move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });
    cluster.sim.run_until(duration);
    cluster.assert_safe();
    let samples = cluster.samples();
    let (offered, _, _) = cluster.workload_totals();
    open_loop_summary(&samples, offered, duration).expect("open-loop run produced no samples")
}

/// Closed-loop comparator at the same client count: completed commands/s
/// with a `window`-deep pipeline (`window = 1` is the paper's §8.1
/// client), same LAN, same mid-run reconfiguration.
pub fn run_closed_loop_rate(n_clients: usize, window: usize, seed: u64, duration: Time) -> f64 {
    let mut cluster = Cluster::builder()
        .clients(n_clients)
        .workload(WorkloadSpec::pipelined(window))
        .seed(seed)
        .net(NetworkModel::lan())
        .build();
    let leader = cluster.initial_leader();
    let cfg = cluster.random_config(1);
    cluster.sim.schedule(duration / 2, move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });
    cluster.sim.run_until(duration);
    cluster.assert_safe();
    cluster.samples().len() as f64 / (duration as f64 / 1e9)
}

/// X4: throughput/tail-latency vs offered load across a mid-run
/// reconfiguration, with and without client-side pipelining. A closed
/// loop can only measure `n_clients / latency`; the open-loop sweep
/// shows where the same deployment actually saturates, and that the
/// in-flight window — not the arrival process — is what moves the knee.
pub fn open_loop_figure(seed: u64) -> OpenLoopReport {
    let clients = 4;
    let duration = secs(4);
    let rates = [500.0, 1000.0, 2000.0, 4000.0, 6000.0];
    let mut rep = OpenLoopReport {
        id: "X4".into(),
        title: format!(
            "open-loop offered-load sweep ({clients} clients, rates per client, \
             acceptor reconfiguration at 2 s)"
        ),
        ..Default::default()
    };
    for (label, window, poisson) in [
        ("no pipelining (in-flight 1)", 1usize, false),
        ("pipelined (in-flight 16)", 16, false),
        ("pipelined, Poisson arrivals (in-flight 16)", 16, true),
    ] {
        let rows: Vec<OpenLoopSummary> = rates
            .iter()
            .map(|&r| run_offered_load(clients, r, window, poisson, seed, duration))
            .collect();
        rep.series.push((label.to_string(), rows));
    }
    let closed = run_closed_loop_rate(clients, 1, seed, duration);
    let piped = rep.series[1]
        .1
        .last()
        .map(|s| s.completed_per_sec)
        .unwrap_or(f64::NAN);
    rep.notes.push(format!(
        "closed-loop baseline ({clients} clients, window 1): {closed:.0} cmds/s; \
         pipelined open loop at the top offered rate: {piped:.0} cmds/s \
         ({:.1}x; acceptance target >= 2x)",
        piped / closed
    ));
    rep.notes.push(
        "expected shape: the window-1 series saturates near the closed-loop rate \
         (delivery ratio < 1, queueing p99 explodes past the knee); the pipelined \
         series tracks the offered rate with a flat p99 across the reconfiguration"
            .into(),
    );
    rep
}

/// Output of one X5 state-retention run.
pub struct RetentionRun {
    /// Commands completed per simulated second over the whole run.
    pub completed_per_sec: f64,
    /// Per-replica retention counters at the end of the run.
    pub retention: Vec<RetentionSummary>,
    /// Rounds the leader installed (startup + the storm).
    pub reconfigs_completed: u64,
    /// The replica that was crashed and replaced mid-run.
    pub rejoined: NodeId,
}

/// X5: the state-retention run — sustained open-loop load on the tensor
/// state machine across a reconfiguration storm, with one replica
/// crashed mid-storm and replaced by a fresh machine. With `snapshots`
/// the replicas snapshot every 50 ms and truncate to a 1024-entry tail
/// (and the leader truncates + propagates the durable watermark to the
/// acceptors); without, the seed behavior: every log grows with the run.
/// `duration` must be ≥ 4 s (the storm is scheduled inside [1 s, 3.5 s]).
pub fn run_retention(seed: u64, snapshots: bool, duration: Time) -> RetentionRun {
    let mut opts = OptFlags::default();
    if snapshots {
        opts.snapshot = SnapshotSpec::every(50 * MS, 1024);
    }
    // Stop arrivals before the horizon so in-flight tails drain and every
    // replica converges by the end of the run.
    let stop = duration.saturating_sub(700 * MS);
    let mut cluster = Cluster::builder()
        .clients(4)
        .workload(
            WorkloadSpec::open_loop(500.0)
                .max_in_flight(16)
                .payload_with(tensor_lane_payload)
                .stop_at(stop),
        )
        .opts(opts)
        .seed(seed)
        .build();
    for &r in &cluster.layout.replicas.clone() {
        let sm = TensorStateMachine::load().expect("tensor state machine");
        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
            rep.sm = Box::new(sm);
        }
    }
    let leader = cluster.initial_leader();
    // Reconfiguration storm: four acceptor reconfigurations while load
    // and snapshotting run.
    for i in 0..4u64 {
        let cfg = cluster.random_config(i + 1);
        let at = secs(1) + i * 800 * MS;
        cluster.sim.schedule(at, move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
    }
    // Crash one replica mid-storm; a fresh machine takes its id 600 ms
    // later and must converge — via snapshot transfer when snapshots are
    // on (the prefix it needs is truncated everywhere), via leader
    // re-sends when they are off.
    let victim = cluster.layout.replicas[2];
    let peers = cluster.layout.replicas.clone();
    let snap_spec = opts.snapshot;
    cluster.sim.schedule(secs(1) + 400 * MS, move |s| s.crash(victim));
    cluster.sim.schedule(secs(2), move |s| {
        let sm = TensorStateMachine::load().expect("tensor state machine");
        let mut rep = Replica::new(victim, Box::new(sm));
        rep.snapshot = snap_spec;
        rep.peers = peers;
        s.replace_node(victim, Box::new(rep));
    });
    cluster.sim.run_until(duration);
    cluster.assert_safe();
    let samples = cluster.samples();
    let completed_per_sec = samples.len() as f64 / (duration as f64 / 1e9);
    let reconfigs_completed = cluster
        .sim
        .node_mut::<Leader>(leader)
        .map(|l| l.reconfigs_completed)
        .unwrap_or(0);
    RetentionRun {
        completed_per_sec,
        retention: cluster.retention_stats(),
        reconfigs_completed,
        rejoined: victim,
    }
}

/// X5 report: the snapshot-enabled and snapshot-disabled runs side by
/// side, with the bounded-memory / throughput-parity / rejoin notes.
pub fn retention_figure(seed: u64) -> RetentionReport {
    let duration = secs(5);
    let on = run_retention(seed, true, duration);
    let off = run_retention(seed, false, duration);
    let mut rep = RetentionReport {
        id: "X5".into(),
        title: "state retention: snapshots + log truncation under a reconfiguration storm \
                (4 open-loop clients x 500/s, tensor SM, crash at 1.4 s, rejoin at 2 s)"
            .into(),
        ..Default::default()
    };
    let max_on = on.retention.iter().map(|r| r.max_log_len).max().unwrap_or(0);
    let final_off = off.retention.iter().map(|r| r.log_len).max().unwrap_or(0);
    let installed: u64 = on.retention.iter().map(|r| r.snapshots_installed).sum();
    rep.notes.push(format!(
        "max replica log length: {} with snapshots (tail 1024) vs {} final without — \
         bounded instead of growing with the run",
        max_on, final_off
    ));
    let baseline_pct = if off.completed_per_sec > 0.0 {
        100.0 * on.completed_per_sec / off.completed_per_sec
    } else {
        0.0
    };
    rep.notes.push(format!(
        "throughput: {:.0} cmds/s with snapshots vs {:.0} without ({:.1}% of baseline; \
         acceptance target >= 90%)",
        on.completed_per_sec, off.completed_per_sec, baseline_pct
    ));
    rep.notes.push(format!(
        "reconfigurations completed: {} (startup + 4-storm); rejoined replica installed \
         {} peer snapshot(s) and converged",
        on.reconfigs_completed, installed
    ));
    rep.series.push(("snapshots on (50 ms, tail 1024)".into(), on.retention));
    rep.series.push(("snapshots off (seed behavior)".into(), off.retention));
    rep
}

/// Output of one X6 sharded scale-out run.
pub struct ShardRun {
    /// Number of consensus groups.
    pub shards: usize,
    /// Total offered arrivals over the run.
    pub offered: u64,
    /// Total offered rate (arrivals/sec) over the run.
    pub offered_per_sec: f64,
    /// Aggregate chosen-commands/sec over the measurement window.
    pub aggregate_per_sec: f64,
    /// Per-group chosen-command summaries over the measurement window.
    pub groups: Vec<GroupSummary>,
    /// For every non-reconfiguring group: windowed throughput during the
    /// group-0 reconfiguration storm divided by its pre-storm
    /// steady-state rate. The minimum across groups — 1.0 when there is
    /// only one group (vacuous). The X6 acceptance gate wants ≥ 0.9.
    pub min_unperturbed_ratio: f64,
    /// Largest total matchmaker-log length (entries across all groups)
    /// on any active matchmaker at the end of the run — must stay ~one
    /// live entry per group, not grow with the storm.
    pub max_mm_log: usize,
    /// Reconfigurations group 0's leader completed (startup + storm).
    pub group0_reconfigs: u64,
}

/// One X6 run: `shards` groups behind one shared matchmaker set, a fixed
/// *total* offered load (so adding groups divides the per-leader load),
/// and a reconfiguration storm on group 0 in the middle of the run.
///
/// The network charges `tx_overhead` per message on the sender's NIC —
/// the same egress model as the X3 batching experiment — which caps a
/// single leader's Phase2A/Chosen fan-out at a few thousand commands/sec.
/// One group saturates at that ceiling; N groups have N leaders (and N
/// acceptor/replica sets), so the same offered load spreads and
/// aggregate throughput scales until the clients' arrival rate is met.
pub fn run_sharded_scaleout(seed: u64, shards: usize, duration: Time) -> ShardRun {
    assert!(duration >= secs(3), "the storm schedule needs >= 3 s");
    let n_clients = 8;
    let per_client_rate = 2000.0; // total 16k/s offered
    let mut net = NetworkModel::default();
    net.tx_overhead = 40 * US;
    // In-flight 8 per client (64 total): enough to keep a saturated
    // leader's egress pipe full (throughput = 1 / per-command egress
    // cost), small enough that queueing latency stays under the Phase 2
    // watchdog's retry threshold — this measures scale-out, not retry
    // amplification under deliberate overload.
    let mut cluster = ShardedCluster::builder()
        .shards(shards)
        .clients(n_clients)
        .workload(WorkloadSpec::open_loop(per_client_rate).max_in_flight(8))
        .net(net)
        .seed(seed)
        .build();

    // Reconfiguration storm on group 0: five acceptor reconfigurations,
    // 150 ms apart, starting at 40% of the run. Other groups see only
    // the shared matchmakers' (off-critical-path) log traffic.
    let storm_from = duration * 2 / 5;
    let storm_until = storm_from + 5 * 150 * MS;
    let leader0 = cluster.group_leader(0);
    for i in 0..5u64 {
        let cfg = cluster.random_config(0, i + 1);
        cluster.sim.schedule(storm_from + i * 150 * MS, move |s| {
            s.with_node::<Leader, _>(leader0, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
    }
    cluster.sim.run_until(duration);
    cluster.assert_safe();

    // Measurement window: skip the startup ramp.
    let warm = duration / 5;
    let mut groups = Vec::new();
    let mut aggregate = 0.0;
    let mut min_unpert = 1.0f64;
    for g in 0..shards {
        let times = cluster.group_chosen_times(g as u32);
        let s = group_summary(g as u32, &times, warm, duration);
        aggregate += s.chosen_per_sec;
        if g != 0 {
            let steady = rate_in_window(&times, warm, storm_from);
            let during = rate_in_window(&times, storm_from, storm_until);
            if steady > 0.0 {
                min_unpert = min_unpert.min(during / steady);
            } else {
                min_unpert = 0.0;
            }
        }
        groups.push(s);
    }
    let (offered, _, _) = cluster.workload_totals();
    let max_mm_log = cluster
        .matchmaker_log_lens()
        .into_iter()
        .map(|(_, len)| len)
        .max()
        .unwrap_or(0);
    let group0_reconfigs = cluster
        .sim
        .node_mut::<Leader>(leader0)
        .map(|l| l.reconfigs_completed)
        .unwrap_or(0);
    ShardRun {
        shards,
        offered,
        offered_per_sec: offered as f64 / (duration as f64 / 1e9),
        aggregate_per_sec: aggregate,
        groups,
        min_unperturbed_ratio: min_unpert,
        max_mm_log,
        group0_reconfigs,
    }
}

/// X6 report: 1/2/4 groups at the same total offered load.
pub fn sharding_figure(seed: u64) -> ShardReport {
    let duration = secs(3);
    let mut rep = ShardReport {
        id: "X6".into(),
        title: "sharded scale-out: N groups, one shared matchmaker set \
                (8 open-loop clients x 2000/s total 16k/s, 40 µs/msg egress, \
                5-reconfig storm on group 0 mid-run)"
            .into(),
        ..Default::default()
    };
    let mut single = None;
    for &shards in &[1usize, 2, 4] {
        let run = run_sharded_scaleout(seed, shards, duration);
        rep.rows.push((
            shards,
            run.offered_per_sec,
            run.aggregate_per_sec,
            run.min_unperturbed_ratio,
            run.max_mm_log,
        ));
        rep.groups.push((format!("{shards} group(s)"), run.groups.clone()));
        if shards == 1 {
            single = Some(run.aggregate_per_sec);
        } else if let Some(s1) = single {
            rep.notes.push(format!(
                "{} groups: {:.1}x the single-group rate ({:.0} vs {:.0} cmds/s)",
                shards,
                run.aggregate_per_sec / s1,
                run.aggregate_per_sec,
                s1
            ));
        }
    }
    rep.notes.push(
        "acceptance: 4-group aggregate >= 2.5x single-group; non-reconfiguring groups \
         within 10% of steady state during group 0's storm; shared matchmaker log \
         bounded (~1 live entry per group after GC)"
            .into(),
    );
    rep
}

/// Which read path an X7 run exercises.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReadVariant {
    /// Reads ride the log through the leader like writes — the
    /// all-through-Phase-2 baseline.
    Baseline,
    /// Reads go to replicas but leases are off: every pending-read
    /// batch costs a quorum-confirmed ReadIndex at the leader (the
    /// lease-expiry fallback path, exercised standalone).
    ReadIndexOnly,
    /// Leased: replicas resolve reads from continuously pushed grants,
    /// no per-read leader traffic.
    Leased,
}

/// Output of one X7 read-scaling run.
pub struct ReadScalingRun {
    /// Read/write-mix throughput + latency summary.
    pub summary: ReadMixSummary,
    /// Every completed read `(issued, completed, result)` — checker input.
    pub reads: Vec<ReadSample>,
    /// Completion times of acknowledged writes.
    pub write_completions: Vec<Time>,
    /// Issue times of all writes ever sent.
    pub write_issues: Vec<Time>,
    /// Per-replica `(id, reads_leased, reads_indexed)`.
    pub read_path: Vec<(NodeId, u64, u64)>,
    /// Rounds the initial leader installed (startup + storm).
    pub reconfigs_completed: u64,
}

impl ReadScalingRun {
    /// Assert that every completed read was linearizable w.r.t. the
    /// global write history (counter semantics: +1 writes, total reads).
    pub fn check_linearizable(&self) -> Result<(), String> {
        check_counter_reads(&self.reads, &self.write_completions, &self.write_issues)
    }
}

/// One X7 run: 8 open-loop clients offering 16k ops/s total at a 90/10
/// read/write mix against a Counter state machine (+1 writes, total
/// reads — every read is checkable against the global write history),
/// under the X6 egress model (40 µs/msg on the sender's NIC, which caps
/// one leader's Phase-2 fan-out at a few thousand ops/s), with a
/// 5-reconfiguration storm mid-run. The baseline routes all 16k ops/s
/// through the leader's Phase 2; the leased variant moves the 90% read
/// share onto the replicas, off the leader's NIC entirely.
pub fn run_read_scaling(seed: u64, variant: ReadVariant, duration: Time) -> ReadScalingRun {
    assert!(duration >= secs(3), "the storm schedule needs >= 3 s");
    let mut opts = OptFlags::default();
    if variant == ReadVariant::Leased {
        opts.leases = LeaseSpec::every(50 * MS, 2 * MS, 100 * US);
    }
    let mut net = NetworkModel::default();
    net.tx_overhead = 40 * US;
    let n_clients = 8;
    let per_client_rate = 2000.0; // 16k/s offered total
    // Stop arrivals before the horizon so in-flight tails drain.
    let stop = duration.saturating_sub(500 * MS);
    let workload = WorkloadSpec::open_loop(per_client_rate)
        .max_in_flight(32)
        .read_fraction(0.9)
        .payload(1i64.to_le_bytes().to_vec())
        .read_payload(Vec::new())
        .stop_at(stop);
    let mut cluster = Cluster::builder()
        .clients(n_clients)
        .workload(workload)
        .opts(opts)
        .route_reads(variant != ReadVariant::Baseline)
        .seed(seed)
        .net(net)
        .build();
    for &r in &cluster.layout.replicas.clone() {
        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
            rep.sm = Box::new(Counter::new());
        }
    }
    // 5-reconfiguration storm starting at 40% of the run: leases must
    // stay correct (or lapse into the fallback) across every change.
    let leader = cluster.initial_leader();
    let storm_from = duration * 2 / 5;
    for i in 0..5u64 {
        let cfg = cluster.random_config(i + 1);
        cluster.sim.schedule(storm_from + i * 150 * MS, move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
    }
    cluster.sim.run_until(duration);
    cluster.assert_safe();
    let samples = cluster.samples();
    let (offered, _, _) = cluster.workload_totals();
    let reads_completed = cluster.reads_completed();
    let summary = read_mix_summary(&samples, offered, reads_completed, duration)
        .expect("read-scaling run produced no samples");
    let reads = cluster.read_records();
    let (write_completions, write_issues) = cluster.write_records();
    let read_path = cluster.read_path_stats();
    let reconfigs_completed = cluster
        .sim
        .node_mut::<Leader>(leader)
        .map(|l| l.reconfigs_completed)
        .unwrap_or(0);
    ReadScalingRun {
        summary,
        reads,
        write_completions,
        write_issues,
        read_path,
        reconfigs_completed,
    }
}

/// X7 report: the three read-path variants side by side at equal
/// offered load, each checked for read linearizability.
pub fn read_scaling_figure(seed: u64) -> ReadReport {
    let duration = secs(3);
    let mut rep = ReadReport {
        id: "X7".into(),
        title: "leased linearizable reads: 90/10 mix, 8 open-loop clients x 2000/s, \
                Counter SM, 40 µs/msg egress, 5-reconfig storm mid-run"
            .into(),
        ..Default::default()
    };
    let variants = [
        ("all_through_phase2", ReadVariant::Baseline),
        ("read_index_no_lease", ReadVariant::ReadIndexOnly),
        ("leases_on", ReadVariant::Leased),
    ];
    let mut baseline = f64::NAN;
    let mut leased = f64::NAN;
    for (label, variant) in variants {
        let run = run_read_scaling(seed, variant, duration);
        match run.check_linearizable() {
            Ok(()) => rep.notes.push(format!(
                "{label}: {} reads, zero stale across {} reconfigurations",
                run.summary.reads,
                run.reconfigs_completed.saturating_sub(1)
            )),
            Err(e) => rep.notes.push(format!("{label}: LINEARIZABILITY VIOLATION: {e}")),
        }
        if variant == ReadVariant::Baseline {
            baseline = run.summary.completed_per_sec;
        }
        if variant == ReadVariant::Leased {
            leased = run.summary.completed_per_sec;
        }
        if variant != ReadVariant::Baseline {
            rep.replicas.push((label.to_string(), run.read_path.clone()));
        }
        rep.rows.push((label.to_string(), run.summary));
    }
    rep.notes.push(format!(
        "leases vs all-through-Phase-2 at equal offered load: {:.1}x \
         ({:.0} vs {:.0} ops/s; acceptance target >= 2x)",
        leased / baseline,
        leased,
        baseline
    ));
    rep
}

/// Which overload-control policy an X9 run exercises.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdmissionPolicy {
    /// Admission off: the leader accepts everything; excess queueing
    /// accumulates leader-side and shows up as latency — the pre-X9
    /// behavior.
    Off,
    /// Bounded inbox, `Busy` pushback; clients honor the leader's
    /// `retry_after_us` hint with exponentially backed-off delayed
    /// retries, and excess load sheds client-side at the queue cap.
    Retry,
    /// Bounded inbox, `Busy` pushback; clients shed the pushed-back
    /// command immediately (counted `abandoned`) and move on.
    Shed,
}

impl AdmissionPolicy {
    fn label(self) -> &'static str {
        match self {
            AdmissionPolicy::Off => "admission_off",
            AdmissionPolicy::Retry => "admission_retry",
            AdmissionPolicy::Shed => "admission_shed",
        }
    }
}

/// X9 deployment constants: 8 open-loop clients against the X6 egress
/// model (40 µs/msg on the sender's NIC), adaptive batching between
/// (1, cfg) on size and (cfg/16, cfg) on delay, a 16-slot inbox bound
/// (a few slots is the normal in-transit depth, so queue growth past
/// ~5x that means the leader has fallen behind), and a 20 ms p99 SLO
/// target for the controller and the retry hint.
const X9_CLIENTS: usize = 8;
const X9_INBOX: usize = 16;
const X9_TARGET_P99_US: u64 = 20_000;

/// One X9 run: `rate_per_client` × 8 clients offered against a single
/// group whose leader runs latency-targeted adaptive batching and (per
/// `policy`) a bounded admission inbox, with one acceptor
/// reconfiguration mid-run (overload control must survive matchmaking).
/// Arrivals stop 500 ms before the horizon so in-flight tails drain.
pub fn run_overload(
    seed: u64,
    rate_per_client: f64,
    policy: AdmissionPolicy,
    duration: Time,
) -> OverloadRow {
    let mut opts = OptFlags::default().with_batching(8, MS);
    match policy {
        AdmissionPolicy::Off => {}
        AdmissionPolicy::Retry => {
            opts.admission = AdmissionSpec::slo(X9_INBOX, X9_TARGET_P99_US, false)
        }
        AdmissionPolicy::Shed => {
            opts.admission = AdmissionSpec::slo(X9_INBOX, X9_TARGET_P99_US, true)
        }
    }
    let mut net = NetworkModel::default();
    net.tx_overhead = 40 * US;
    let stop = duration.saturating_sub(500 * MS);
    // Deep per-client windows (64) so the offered excess actually
    // reaches the pipeline instead of being absorbed by tiny client
    // windows; the 128-entry arrival queue bounds client-side memory.
    let workload = WorkloadSpec::open_loop(rate_per_client)
        .max_in_flight(64)
        .queue_cap(128)
        .stop_at(stop);
    let mut cluster = Cluster::builder()
        .clients(X9_CLIENTS)
        .workload(workload)
        .opts(opts)
        .net(net)
        .seed(seed)
        .build();
    let leader = cluster.initial_leader();
    let cfg = cluster.random_config(1);
    cluster.sim.schedule(duration / 2, move |s| {
        s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
    });
    cluster.sim.run_until(duration);
    cluster.assert_safe();
    let samples = cluster.samples();
    let (offered, _, abandoned) = cluster.workload_totals();
    let summary =
        open_loop_summary(&samples, offered, duration).expect("overload run produced no samples");
    let load = cluster.group_load();
    let (eff_batch, eff_delay) = cluster
        .sim
        .node_mut::<Leader>(leader)
        .map(|l| l.effective_batch())
        .unwrap_or((0, 0));
    OverloadRow {
        offered_per_sec: summary.offered_per_sec,
        goodput: summary.completed_per_sec,
        p50_ms: summary.latency.median,
        p99_ms: summary.latency.p99,
        abandoned,
        busy_rejections: load.busy_rejections,
        busy_rate: load.busy_rate,
        inbox_depth: load.inbox_depth,
        eff_batch,
        eff_delay_us: eff_delay / US,
        ctl_p99_ms: load.windowed_p99 as f64 / 1e6,
    }
}

/// X9 report: offered load swept from well below to well past the
/// leader's egress ceiling, for each admission policy. The acceptance
/// shape (gated in `safety_properties`): with admission on, goodput at
/// the top offered rate stays within 10% of the sweep's peak and p99
/// stays bounded; with admission off the inbox grows with the backlog.
pub fn overload_figure(seed: u64) -> OverloadReport {
    let duration = secs(3);
    let mut rep = OverloadReport {
        id: "X9".into(),
        title: "leader overload control: adaptive batching + Busy admission \
                (8 open-loop clients, 40 µs/msg egress, inbox 16, 20 ms SLO, \
                1 reconfig mid-run)"
            .into(),
        ..Default::default()
    };
    let rates = [250.0, 500.0, 1000.0, 2000.0, 4000.0];
    for policy in [AdmissionPolicy::Off, AdmissionPolicy::Retry, AdmissionPolicy::Shed] {
        let rows: Vec<OverloadRow> =
            rates.iter().map(|&r| run_overload(seed, r, policy, duration)).collect();
        let peak = rows.iter().map(|r| r.goodput).fold(0.0f64, f64::max);
        let top = rows.last().expect("non-empty sweep");
        rep.notes.push(format!(
            "{}: peak goodput {:.0}/s, at top offered rate {:.0}/s goodput {:.0}/s \
             ({:.0}% of peak), p99 {:.1} ms, final inbox {}",
            policy.label(),
            peak,
            top.offered_per_sec,
            top.goodput,
            100.0 * top.goodput / peak.max(1.0),
            top.p99_ms,
            top.inbox_depth
        ));
        rep.series.push((policy.label().to_string(), rows));
    }
    rep.notes.push(
        "acceptance: with admission on, goodput at the top rate >= 90% of the sweep \
         peak with p99 bounded (the gate runs in safety_properties)"
            .into(),
    );
    rep
}

/// X12 deployment constants: 4 open-loop clients at 250/s each with a
/// 50/50 read/write mix over a 10 s run (arrivals stop 500 ms before
/// the horizon so in-flight tails drain). The configured lease drift
/// bound (1 ms) deliberately exceeds the injected ±400 µs clock skew:
/// the schedule probes the protocol *inside* its stated tolerance, so
/// zero violations is the required outcome, not a lucky one.
const X12_END_MS: u64 = 10_000;
const X12_WARM_MS: u64 = 500;
const X12_SKEW_US: i64 = 400;
const X12_DRIFT: Time = MS;

/// The scripted X12 fault schedule over `cluster`'s layout (DESIGN.md
/// §Nemesis):
///
/// * 2 s: partition the initial leader from every acceptor (it still
///   hears and is heard by everything else — quorum loss, not a crash;
///   the leader must step down and a follower must take over);
/// * 3.2 s: heal;
/// * 4.5 s: asymmetric partition of one matchmaker — its answers to
///   both proposers vanish while requests still reach it; an acceptor
///   reconfiguration rides through this window; healed at 5.8 s;
/// * 6 s: gray-slow one pool acceptor to 8x nominal link delays
///   (alive and correct, just late), restored at 7 s;
/// * 7.5 s: skew the two proposers' lease clocks ±400 µs (inside the
///   1 ms drift bound), restored at 8.5 s.
pub fn x12_plan(cluster: &Cluster) -> NemesisPlan {
    let p0 = cluster.layout.proposers[0];
    let p1 = cluster.layout.proposers[1];
    let mm0 = cluster.layout.initial_matchmakers()[0];
    let acceptors = cluster.layout.acceptor_pool.clone();
    let slow_acc = acceptors[0];
    let events = vec![
        NemesisEvent {
            at_ms: 2_000,
            fault: Fault::Partition { groups: vec![vec![p0], acceptors] },
        },
        NemesisEvent { at_ms: 3_200, fault: Fault::Heal },
        NemesisEvent { at_ms: 4_500, fault: Fault::OneWay { from: mm0, to: p0 } },
        NemesisEvent { at_ms: 4_500, fault: Fault::OneWay { from: mm0, to: p1 } },
        NemesisEvent { at_ms: 5_800, fault: Fault::Heal },
        NemesisEvent { at_ms: 6_000, fault: Fault::SlowNode { node: slow_acc, pct: 800 } },
        NemesisEvent { at_ms: 7_000, fault: Fault::SlowNode { node: slow_acc, pct: 100 } },
        NemesisEvent { at_ms: 7_500, fault: Fault::ClockSkew { node: p0, skew_us: X12_SKEW_US } },
        NemesisEvent { at_ms: 7_500, fault: Fault::ClockSkew { node: p1, skew_us: -X12_SKEW_US } },
        NemesisEvent { at_ms: 8_500, fault: Fault::ClockSkew { node: p0, skew_us: 0 } },
        NemesisEvent { at_ms: 8_500, fault: Fault::ClockSkew { node: p1, skew_us: 0 } },
    ];
    NemesisPlan { events }
}

/// Output of one X12 run (faulted, or the fault-free twin when the
/// plan is built but not injected).
pub struct X12Run {
    /// The scripted schedule (identical either way; see [`x12_plan`]).
    pub plan: NemesisPlan,
    /// Completion times of every acknowledged command, sorted.
    pub completions: Vec<Time>,
    /// Read and write history for the stale-read check.
    pub reads: Vec<ReadSample>,
    pub write_completions: Vec<Time>,
    pub write_issues: Vec<Time>,
    /// `LeaderSteady` announces observed (1 = startup election only).
    pub elections: usize,
    /// Reconfigurations completed across both proposers.
    pub reconfigs_completed: u64,
}

impl X12Run {
    /// Assert every completed read was linearizable w.r.t. the global
    /// write history — the "zero stale reads" leg of the X12 gate.
    pub fn check_stale_reads(&self) -> Result<(), String> {
        check_counter_reads(&self.reads, &self.write_completions, &self.write_issues)
    }
}

/// One X12 run: leases on with a 1 ms drift bound, reads routed to
/// replicas against a Counter state machine (every read checkable), and
/// — when `with_faults` — the [`x12_plan`] schedule injected into the
/// deterministic event stream. An acceptor reconfiguration is scheduled
/// on both proposers at 5 s (`reconfigure` is a no-op on a follower, so
/// exactly the then-current leader acts: the post-failover one in the
/// faulted run, the initial one in the twin). Safety is checked against
/// the widened `lease-disjoint-under-skew` envelope, not just the
/// default 1 µs one.
pub fn run_x12(seed: u64, with_faults: bool) -> X12Run {
    let duration = X12_END_MS * MS;
    let mut opts = OptFlags::default();
    opts.leases = LeaseSpec::every(50 * MS, 2 * MS, X12_DRIFT);
    let stop = duration.saturating_sub(500 * MS);
    let workload = WorkloadSpec::open_loop(250.0)
        .max_in_flight(16)
        .read_fraction(0.5)
        .payload(1i64.to_le_bytes().to_vec())
        .read_payload(Vec::new())
        .stop_at(stop);
    let mut cluster = Cluster::builder()
        .clients(4)
        .workload(workload)
        .opts(opts)
        .route_reads(true)
        .seed(seed)
        .net(NetworkModel::lan())
        .build();
    for &r in &cluster.layout.replicas.clone() {
        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
            rep.sm = Box::new(Counter::new());
        }
    }
    let plan = x12_plan(&cluster);
    if with_faults {
        plan.apply_to_sim(&mut cluster.sim);
    }
    let p0 = cluster.layout.proposers[0];
    let p1 = cluster.layout.proposers[1];
    let cfg = cluster.random_config(1);
    cluster.sim.schedule(secs(5), move |s| {
        for p in [p0, p1] {
            let cfg = cfg.clone();
            s.with_node::<Leader, _>(p, move |l, now, fx| l.reconfigure(cfg, now, fx));
        }
    });
    cluster.sim.run_until(duration);
    let mut invs = crate::check::InvariantSet::standard_with_drift(X12_DRIFT);
    if let Err(v) = invs.feed(&cluster.sim.announces) {
        panic!("X12 safety invariant violated: {v}");
    }
    let elections = cluster
        .sim
        .announces
        .iter()
        .filter(|(_, _, a)| matches!(a, crate::node::Announce::LeaderSteady { .. }))
        .count();
    let reconfigs_completed = [p0, p1]
        .iter()
        .filter_map(|&p| cluster.sim.node_mut::<Leader>(p).map(|l| l.reconfigs_completed))
        .sum();
    let mut completions: Vec<Time> = cluster.samples().iter().map(|(t, _)| *t).collect();
    completions.sort_unstable();
    let reads = cluster.read_records();
    let (write_completions, write_issues) = cluster.write_records();
    X12Run {
        plan,
        completions,
        reads,
        write_completions,
        write_issues,
        elections,
        reconfigs_completed,
    }
}

/// Longest gap between consecutive completions that *starts* inside
/// `[from, to)` — including a stall that begins in the window and ends
/// after it (service resumed late), and the whole remainder when
/// nothing completes again before `to`.
fn longest_stall(completions: &[Time], from: Time, to: Time) -> Time {
    let mut prev = from;
    let mut worst = 0;
    for &t in completions {
        if t < from {
            continue;
        }
        let gap_start = prev.max(from);
        if gap_start >= to {
            return worst;
        }
        worst = worst.max(t.saturating_sub(gap_start));
        prev = t;
    }
    let gap_start = prev.max(from);
    if gap_start < to {
        worst = worst.max(to - gap_start);
    }
    worst
}

/// Heal/restore-to-first-completion latency in ms from `t` (NaN when
/// the run ends without another completion).
fn recovery_ms(completions: &[Time], t: Time) -> f64 {
    completions
        .iter()
        .find(|&&c| c >= t)
        .map(|&c| (c - t) as f64 / 1e6)
        .unwrap_or(f64::NAN)
}

/// Completed commands/sec over `[from, to)` with every fault window
/// excluded from both the count and the span.
fn goodput_outside(completions: &[Time], windows: &[(Time, Time)], from: Time, to: Time) -> f64 {
    let inside = |t: Time| windows.iter().any(|&(a, b)| t >= a && t < b);
    let n = completions.iter().filter(|&&t| t >= from && t < to && !inside(t)).count();
    let mut span = to.saturating_sub(from);
    for &(a, b) in windows {
        let (a, b) = (a.max(from), b.min(to));
        span = span.saturating_sub(b.saturating_sub(a));
    }
    if span == 0 {
        return 0.0;
    }
    n as f64 / (span as f64 / 1e9)
}

/// X12 report: the scripted nemesis schedule against its fault-free
/// twin at the same seed. The acceptance gate
/// (`x12_nemesis_schedule_meets_acceptance` in
/// `rust/tests/safety_properties.rs`): zero invariant violations
/// (checked inside each run, against the widened drift envelope), zero
/// stale reads, every post-heal recovery bounded, and goodput outside
/// the fault windows >= 90% of the fault-free twin's. Everything here
/// is virtual-time deterministic: the same seed renders a
/// byte-identical report.
pub fn nemesis_figure(seed: u64) -> NemesisReport {
    let faulted = run_x12(seed, true);
    let clean = run_x12(seed, false);
    let warm = X12_WARM_MS as Time * MS;
    // Arrivals stop 500 ms before the horizon; measure over the span
    // that was actually offered load.
    let measured_to = (X12_END_MS as Time * MS).saturating_sub(500 * MS);
    let windows = faulted.plan.fault_windows(X12_END_MS);
    let labels =
        ["leader_partition", "mm_asym_partition", "gray_slow_acceptor", "lease_clock_skew"];
    let mut rep = NemesisReport {
        id: "X12".into(),
        title: "nemesis fault schedule vs fault-free twin (4 open-loop clients x 250/s, \
                50/50 read mix, Counter SM, leases on, 1 ms drift bound)"
            .into(),
        plan: faulted.plan.to_text(),
        ..Default::default()
    };
    for (i, &(from, to)) in windows.iter().enumerate() {
        rep.rows.push(NemesisRow {
            label: labels.get(i).copied().unwrap_or("fault").into(),
            from_ms: from as f64 / 1e6,
            to_ms: to as f64 / 1e6,
            max_stall_ms: longest_stall(&faulted.completions, from, to) as f64 / 1e6,
            recover_ms: recovery_ms(&faulted.completions, to),
        });
    }
    rep.goodput_faulted = goodput_outside(&faulted.completions, &windows, warm, measured_to);
    rep.goodput_fault_free = goodput_outside(&clean.completions, &windows, warm, measured_to);
    for (label, run) in [("faulted", &faulted), ("fault_free", &clean)] {
        match run.check_stale_reads() {
            Ok(()) => rep.notes.push(format!(
                "{label}: {} reads, zero stale; {} election(s), {} reconfiguration(s)",
                run.reads.len(),
                run.elections,
                run.reconfigs_completed
            )),
            Err(e) => rep.notes.push(format!("{label}: STALE READ: {e}")),
        }
    }
    rep
}

// X10 lives in `harness::crash` (it drives the real TCP runtime, not
// the simulator) but is re-exported here so `repro exp` resolves every
// experiment through one module.
pub use super::crash::crash_recovery_figure;

/// Machine-readable perf rows for the `--bench-json` trajectory
/// (satellite: BENCH_x*.json; schema in DESIGN.md §Bench trajectory).
/// Purpose-built short runs — not the full figures — so CI can emit a
/// row set per experiment in a few seconds of wall clock each.
pub fn bench_json_for(id: &str, seed: u64) -> Option<BenchJson> {
    let row = |label: &str, throughput: f64, p50: f64, p99: f64, offered: f64| BenchRow {
        label: label.to_string(),
        throughput,
        p50_ms: p50,
        p99_ms: p99,
        offered_per_sec: offered,
    };
    let rows = match id {
        "x3" | "batch" => [1usize, 32]
            .iter()
            .map(|&bs| {
                let r = run_batching_throughput(seed, bs, 32, secs(3));
                row(&format!("batch_{bs}"), r.throughput, r.median_ms, f64::NAN, f64::NAN)
            })
            .collect(),
        "x4" | "openloop" => {
            let closed = run_closed_loop_rate(4, 1, seed, secs(3));
            let open = run_offered_load(4, 6000.0, 16, false, seed, secs(3));
            vec![
                row("closed_loop", closed, f64::NAN, f64::NAN, f64::NAN),
                row(
                    "open_pipelined",
                    open.completed_per_sec,
                    open.latency.median,
                    open.latency.p99,
                    open.offered_per_sec,
                ),
            ]
        }
        "x5" | "retention" => [false, true]
            .iter()
            .map(|&snapshots| {
                let r = run_retention(seed, snapshots, secs(5));
                row(
                    if snapshots { "snapshots_on" } else { "snapshots_off" },
                    r.completed_per_sec,
                    f64::NAN,
                    f64::NAN,
                    f64::NAN,
                )
            })
            .collect(),
        "x6" | "shards" => [1usize, 4]
            .iter()
            .map(|&shards| {
                let r = run_sharded_scaleout(seed, shards, secs(3));
                row(
                    &format!("groups_{shards}"),
                    r.aggregate_per_sec,
                    f64::NAN,
                    f64::NAN,
                    r.offered_per_sec,
                )
            })
            .collect(),
        "x7" | "reads" => [
            ("all_through_phase2", ReadVariant::Baseline),
            ("leases_on", ReadVariant::Leased),
        ]
        .iter()
        .map(|&(label, variant)| {
            let r = run_read_scaling(seed, variant, secs(3));
            row(
                label,
                r.summary.completed_per_sec,
                r.summary.latency.median,
                r.summary.latency.p99,
                r.summary.offered_per_sec,
            )
        })
        .collect(),
        "x9" | "overload" => {
            let mut rows = Vec::new();
            for policy in [AdmissionPolicy::Off, AdmissionPolicy::Retry, AdmissionPolicy::Shed] {
                // One pre-saturation point and one ~2x-past-saturation
                // point per policy (totals 8k/s and 32k/s).
                for &rate in &[1000.0f64, 4000.0] {
                    let r = run_overload(seed, rate, policy, secs(3));
                    rows.push(row(
                        &format!("{}_{}k", policy.label(), (rate as u64 * 8) / 1000),
                        r.goodput,
                        r.p50_ms,
                        r.p99_ms,
                        r.offered_per_sec,
                    ));
                }
            }
            rows
        }
        "x10" | "recovery" => {
            // Real wall clock + real fsyncs (the TCP runtime), so the
            // bench run keeps the storm short: 2 rounds. `throughput` is
            // executed-announcement rate (3 replicas announcing); the
            // recovery rows carry restart-to-first-execution latency in
            // `p50_ms` and NaN elsewhere.
            let r = crate::harness::crash::run_crash_storm(seed, 2);
            let mut rows = vec![row("pre_crash", r.pre_tput, f64::NAN, f64::NAN, f64::NAN)];
            for (i, (ms, _)) in r.rounds.iter().enumerate() {
                rows.push(row(
                    &format!("recovery_round_{i}"),
                    f64::NAN,
                    *ms,
                    f64::NAN,
                    f64::NAN,
                ));
            }
            rows
        }
        "x12" | "nemesis" => {
            // The full faulted-vs-twin pair: goodput rows carry the
            // outside-fault-window rates; per-fault rows carry the
            // post-heal recovery latency in `p50_ms` and NaN elsewhere.
            let r = nemesis_figure(seed);
            let mut rows = vec![
                row("goodput_outside_faults", r.goodput_faulted, f64::NAN, f64::NAN, f64::NAN),
                row("fault_free_twin", r.goodput_fault_free, f64::NAN, f64::NAN, f64::NAN),
            ];
            for nr in &r.rows {
                rows.push(row(
                    &format!("recover_{}", nr.label),
                    f64::NAN,
                    nr.recover_ms,
                    f64::NAN,
                    f64::NAN,
                ));
            }
            rows
        }
        _ => return None,
    };
    Some(BenchJson { experiment: id.to_string(), seed, rows })
}

/// X2: Matchmaker Fast Paxos (§7) — fast-path success with f+1 acceptors.
/// Runs many independent single-decree instances; in each, 1–2 clients
/// race. Reports fast-path vs recovery counts; safety is asserted.
pub fn fast_paxos_experiment(seed: u64) -> FigureReport {
    use crate::msg::{Command, Msg, Value};
    use crate::roles::{Acceptor, FastProposer, Matchmaker};

    let mut fast_ok = 0usize;
    let mut recovered = 0usize;
    let trials = 50usize;
    for t in 0..trials {
        let mut sim = crate::sim::lan_sim(seed + t as u64);
        // ids: coordinator 0, matchmakers 1-3, acceptors 10,11.
        for m in 1..=3 {
            sim.add_node(m, Box::new(Matchmaker::new(m)));
        }
        sim.add_node(10, Box::new(Acceptor::new_fast(10)));
        sim.add_node(11, Box::new(Acceptor::new_fast(11)));
        let cfg = Configuration {
            id: 0,
            acceptors: vec![10, 11],
            quorum: crate::quorum::QuorumSpec::FastUnanimous,
        };
        sim.add_node(0, Box::new(FastProposer::new(0, 1, vec![1, 2, 3], cfg)));
        sim.with_node::<FastProposer, _>(0, |p, now, fx| p.open_round(now, fx));
        sim.run_until(msec(5));
        let round = sim
            .with_node::<FastProposer, _>(0, |p, _, _| p.fast_round())
            .flatten()
            .expect("fast round open");
        // Conflict in half the trials: two different values race.
        let conflict = t % 2 == 1;
        let v1 = Value::Cmd(Command { client: 100, seq: t as u64, payload: vec![1] });
        let v2 = if conflict {
            Value::Cmd(Command { client: 101, seq: t as u64, payload: vec![2] })
        } else {
            v1.clone()
        };
        sim.schedule(msec(6), move |s| {
            // Client 100 reaches acceptor 10 first; client 101 reaches 11
            // first (the adversarial interleaving). Injected via the
            // coordinator's effect queue for simplicity — the acceptors
            // reply to round.proposer either way.
            s.with_node::<FastProposer, _>(0, move |_, _, pfx| {
                pfx.send(10, Msg::FastPropose { round, value: v1.clone() });
                pfx.send(11, Msg::FastPropose { round, value: v2.clone() });
            });
        });
        sim.run_until(msec(100));
        sim.check_chosen_safety().expect("fast paxos safety");
        let chosen = sim
            .with_node::<FastProposer, _>(0, |p, _, _| p.chosen.clone())
            .flatten();
        assert!(chosen.is_some(), "trial {t} failed to decide");
        let had_fast = sim
            .announces
            .iter()
            .any(|(_, _, a)| matches!(a, crate::node::Announce::FastChosen { .. }));
        if had_fast {
            fast_ok += 1;
        } else {
            recovered += 1;
        }
        // No-conflict trials must take the fast path.
        if !conflict {
            assert!(had_fast, "conflict-free trial {t} missed the fast path");
        }
    }
    FigureReport {
        id: "X2".into(),
        title: "Matchmaker Fast Paxos: f+1 acceptors, unanimous P2, singleton P1".into(),
        series: vec![],
        notes: vec![
            format!("{trials} single-decree instances: {fast_ok} fast-path, {recovered} recovered after conflict"),
            "quorum size = f+1 = 2 (the Fast Paxos lower bound; classic Fast Paxos needs > f+1)".into(),
        ],
    }
}

/// Convenience: run every experiment, returning rendered text blocks.
pub fn run_all(seed: u64) -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = Vec::new();
    let (f9, t1) = figure9(seed);
    out.push(("F9".into(), f9.render()));
    out.push(("T1".into(), t1.render()));
    let (f10, t10) = figure10(seed);
    out.push(("F10".into(), f10.render()));
    out.push(("T-F10".into(), t10.render()));
    let (f11, t11) = figure11(seed);
    out.push(("F11".into(), f11.render()));
    out.push(("T-F11".into(), t11.render()));
    out.push(("F12/F13".into(), figure12_13(seed).render()));
    out.push(("F14".into(), figure14(seed).render()));
    let (f15, _) = figure15(seed);
    out.push(("F15".into(), f15.render()));
    out.push(("F16".into(), figure16(seed).render()));
    out.push(("F17".into(), figure17(seed).render()));
    out.push(("F18".into(), figure18(seed).render()));
    out.push(("F19".into(), figure19(seed).render()));
    out.push(("F20".into(), figure20(seed).render()));
    let (f21, t2) = figure21(seed);
    out.push(("F21".into(), f21.render()));
    out.push(("T2".into(), t2.render()));
    out.push(("X2".into(), fast_paxos_experiment(seed).render()));
    out.push(("X3".into(), batching_figure(seed).render()));
    out.push(("X4".into(), open_loop_figure(seed).render()));
    out.push(("X5".into(), retention_figure(seed).render()));
    out.push(("X6".into(), sharding_figure(seed).render()));
    out.push(("X7".into(), read_scaling_figure(seed).render()));
    out.push(("X9".into(), overload_figure(seed).render()));
    out.push(("X12".into(), nemesis_figure(seed).render()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Experiment smoke tests use short horizons to stay fast; the full
    // schedules run in benches/figures.rs and `repro exp`.

    #[test]
    fn reconfig_schedule_smoke() {
        let run = run_reconfig_schedule(1, 4, true, 42, secs(12));
        assert!(!run.samples.is_empty());
        assert!(!run.reconfig_latencies.is_empty());
        // Matchmakers should essentially always return one prior config.
        assert!(run.max_prior_configs <= 2, "H_i grew: {}", run.max_prior_configs);
    }

    #[test]
    fn horizontal_schedule_smoke() {
        let (samples, tl) = run_horizontal_schedule(1, 4, true, 42, secs(12));
        assert!(!samples.is_empty());
        assert!(!tl.t.is_empty());
    }

    #[test]
    fn fast_paxos_experiment_runs() {
        let rep = fast_paxos_experiment(7);
        assert!(rep.notes[0].contains("fast-path"));
    }

    /// Acceptance gate for the batching tentpole: with a finite egress
    /// cost, batch_size = 32 must at least double simulated throughput
    /// over batch_size = 1 on the tensor state machine path, with the
    /// mid-run reconfiguration (inside `run_batching_throughput`) active.
    #[test]
    fn batching_doubles_tensor_throughput() {
        let b1 = run_batching_throughput(42, 1, 32, secs(3));
        let b32 = run_batching_throughput(42, 32, 32, secs(3));
        assert!(b1.commands > 1000, "batch_size=1 barely ran: {}", b1.commands);
        assert!(
            b32.throughput >= 2.0 * b1.throughput,
            "batching gained only {:.2}x ({:.0} vs {:.0} cmds/s)",
            b32.throughput / b1.throughput,
            b32.throughput,
            b1.throughput
        );
    }

    /// Acceptance gate for the workload tentpole: at equal client count
    /// and equal `NetworkModel::lan()` settings, pipelined open-loop
    /// clients must sustain at least twice the chosen-commands/sec of
    /// closed-loop clients, in virtual time, with a mid-run acceptor
    /// reconfiguration in both runs (safety asserted inside the drivers).
    #[test]
    fn pipelined_open_loop_doubles_closed_loop() {
        let duration = secs(3);
        let closed = run_closed_loop_rate(4, 1, 42, duration);
        let open = run_offered_load(4, 6000.0, 16, false, 42, duration);
        assert!(
            open.delivery_ratio > 0.9,
            "pipelined open loop fell behind its arrivals: {:.2}",
            open.delivery_ratio
        );
        assert!(
            open.completed_per_sec >= 2.0 * closed,
            "pipelined open loop sustained only {:.1}x the closed-loop rate \
             ({:.0} vs {:.0} cmds/s)",
            open.completed_per_sec / closed,
            open.completed_per_sec,
            closed
        );
    }

    #[test]
    fn open_loop_without_pipelining_saturates() {
        // In-flight window 1 at an offered rate far above 1/RTT: the
        // completion rate pins at the closed-loop ceiling, arrivals queue,
        // and the tail shows it.
        let s = run_offered_load(2, 4000.0, 1, false, 11, secs(2));
        assert!(s.delivery_ratio < 0.8, "delivery ratio {:.2}", s.delivery_ratio);
        assert!(
            s.latency.p99 > 50.0,
            "saturated p99 {} ms should show client-side queueing",
            s.latency.p99
        );
    }

    #[test]
    fn open_loop_poisson_tracks_offered_rate() {
        // 2 clients x 1000/s x 2 s: ~4000 deterministic-Poisson arrivals,
        // all absorbed (far from saturation with pipelining).
        let s = run_offered_load(2, 1000.0, 16, true, 7, secs(2));
        assert!(
            (3200.0..4800.0).contains(&(s.offered as f64)),
            "offered {} not ~4000",
            s.offered
        );
        assert!(s.delivery_ratio > 0.9, "delivery ratio {:.2}", s.delivery_ratio);
    }

    /// Acceptance gate for the state-retention tentpole (X5): with
    /// snapshots on, every replica's high-water log length stays within
    /// the configured tail bound (tail + one snapshot interval of
    /// growth) across the reconfiguration storm; throughput stays within
    /// 10% of the identical no-snapshot run; and the replica that
    /// crashed and rejoined converges to the exact same state via
    /// snapshot transfer.
    #[test]
    fn retention_bounds_logs_preserves_throughput_and_recovers_replica() {
        let duration = secs(5);
        let on = run_retention(42, true, duration);
        let off = run_retention(42, false, duration);

        assert!(on.reconfigs_completed >= 4, "storm too small: {}", on.reconfigs_completed);

        // Bounded memory: tail is 1024; 4 clients x 500/s offer ≤ ~100
        // slots per 50 ms snapshot interval, so 1536 = tail + generous
        // interval growth. Without snapshots the log grows with the run.
        for r in &on.retention {
            assert!(
                r.max_log_len <= 1536,
                "replica {} log unbounded with snapshots: {}",
                r.replica,
                r.max_log_len
            );
            assert!(r.snapshots_taken > 0 || r.replica == on.rejoined);
        }
        let max_on = on.retention.iter().map(|r| r.max_log_len).max().unwrap();
        let final_off = off.retention.iter().map(|r| r.log_len).max().unwrap();
        assert!(
            final_off >= 3 * max_on.max(1),
            "no-snapshot baseline should dwarf the bounded run: {final_off} vs {max_on}"
        );

        // Throughput parity: within 10% of the no-snapshot run.
        assert!(
            on.completed_per_sec >= 0.9 * off.completed_per_sec,
            "snapshots cost too much throughput: {:.0} vs {:.0} cmds/s",
            on.completed_per_sec,
            off.completed_per_sec
        );

        // Crash-rejoin: the fresh replica caught up via snapshot
        // transfer (the prefix it missed was truncated cluster-wide) and
        // converged to the identical tensor state.
        let rejoined = on
            .retention
            .iter()
            .find(|r| r.replica == on.rejoined)
            .expect("rejoined replica stats");
        assert!(rejoined.snapshots_installed >= 1, "rejoin did not use snapshot transfer");
        for r in &on.retention {
            assert_eq!(
                r.exec_watermark, rejoined.exec_watermark,
                "replica {} did not converge",
                r.replica
            );
            assert_eq!(r.digest, rejoined.digest, "replica {} state diverged", r.replica);
        }
        // The no-snapshot baseline also converges (leader re-sends), so
        // the comparison is apples to apples.
        for r in &off.retention {
            assert_eq!(r.digest, off.retention[0].digest);
        }
    }

    // The X6 acceptance gate (sharded_scaleout_meets_acceptance) lives in
    // rust/tests/safety_properties.rs: it simulates two full saturated
    // multi-group runs, which belongs with the other slow seeded suites
    // in the release-mode CI job, not the fast debug loop. The X7 gate
    // (read_scaling_meets_acceptance) lives there too, for the same
    // reason; here only a short leased smoke runs.

    #[test]
    fn read_scaling_smoke() {
        let run = run_read_scaling(42, ReadVariant::Leased, secs(3));
        assert!(run.summary.reads > 1000, "leased reads barely ran: {}", run.summary.reads);
        assert!(run.summary.writes > 100, "writes starved: {}", run.summary.writes);
        assert!(run.reconfigs_completed >= 6, "storm too small: {}", run.reconfigs_completed);
        run.check_linearizable().expect("leased reads linearizable");
        // The leased path actually served reads from grants.
        let leased: u64 = run.read_path.iter().map(|(_, l, _)| *l).sum();
        assert!(leased > 0, "no reads took the leased path: {:?}", run.read_path);
    }

    // The X9 acceptance gate (overload_holds_goodput_past_saturation)
    // lives in rust/tests/safety_properties.rs with the X6/X7 gates:
    // it simulates a full offered-load sweep. Here a two-point smoke
    // checks the driver end to end.

    #[test]
    fn overload_smoke_survives_saturation() {
        // Below the egress ceiling the admission path is invisible...
        let low = run_overload(42, 500.0, AdmissionPolicy::Retry, secs(2));
        assert!(
            low.goodput >= 0.8 * low.offered_per_sec,
            "under-saturation run fell behind: {:.0} of {:.0}/s",
            low.goodput,
            low.offered_per_sec
        );
        // ...and well past it goodput must not collapse: the saturated
        // run still beats the low run's completion rate, and the excess
        // is explicitly accounted (abandoned client-side or pushed back),
        // not silently queued.
        let hot = run_overload(42, 4000.0, AdmissionPolicy::Retry, secs(2));
        assert!(
            hot.goodput >= low.goodput,
            "goodput collapsed past saturation: {:.0} vs {:.0}/s",
            hot.goodput,
            low.goodput
        );
        assert!(hot.abandoned > 0, "32k/s offered must overflow the bounded queues");
    }

    // The full X12 acceptance gate (faulted vs fault-free twin, goodput
    // ratio, byte-identical reports) lives in
    // rust/tests/safety_properties.rs with the other release-mode
    // gates; here one faulted run checks the driver end to end.

    #[test]
    fn x12_smoke_survives_the_schedule() {
        let run = run_x12(42, true);
        assert!(run.completions.len() > 1000, "barely ran: {}", run.completions.len());
        run.check_stale_reads().expect("x12 reads linearizable");
        // The leader partition must have forced a failover...
        assert!(run.elections >= 2, "no failover under the leader partition");
        // ...and the mid-schedule reconfiguration must have completed
        // (startup install + failover install + the 5 s reconfig).
        assert!(run.reconfigs_completed >= 3, "reconfig lost: {}", run.reconfigs_completed);
        // Service must be back after the last restore: something
        // completed in the final second of offered load.
        let last = *run.completions.last().unwrap();
        assert!(last >= secs(9), "no completions after the schedule: last at {last}");
        // The schedule's windows are what the report keys on.
        assert_eq!(run.plan.fault_windows(X12_END_MS).len(), 4);
    }

    #[test]
    fn x12_stall_and_goodput_helpers() {
        let completions = [secs(1), secs(2), secs(5), secs(6)];
        // Gap starting inside [1.5 s, 4 s): 2 s -> 5 s.
        assert_eq!(longest_stall(&completions, secs(1) + 500 * MS, secs(4)), secs(3));
        // Nothing completes in-window or after: stall runs to the end.
        assert_eq!(longest_stall(&completions, secs(7), secs(9)), secs(2));
        assert!((recovery_ms(&completions, secs(4)) - 1000.0).abs() < 1e-9);
        assert!(recovery_ms(&completions, secs(7)).is_nan());
        // 2 completions in [0, 7) outside the window that holds the
        // other 2, over 7 - 3 = 4 s of un-windowed span.
        let g = goodput_outside(&completions, &[(secs(4), secs(7))], 0, secs(7));
        assert!((g - 0.5).abs() < 1e-9, "goodput {g}");
    }

    #[test]
    fn bench_json_rows_cover_x3_to_x7() {
        // Cheap schema check only for the ids that don't simulate:
        // unknown ids yield None, known ids are listed.
        assert!(bench_json_for("nope", 1).is_none());
        // One real (short) row set: x7's two variants.
        let b = bench_json_for("x7", 42).expect("x7 rows");
        assert_eq!(b.rows.len(), 2);
        assert!(b.rows.iter().all(|r| r.throughput > 0.0));
        let j = b.to_json();
        assert!(j.contains("\"experiment\":\"x7\""));
    }

    #[test]
    fn batching_latency_stays_bounded() {
        // The flush delay bounds added latency: even a lone client (whose
        // batches never fill) must complete commands promptly.
        let run = run_batching_throughput(7, 32, 1, secs(2));
        assert!(run.commands > 100, "lone client starved: {}", run.commands);
        assert!(
            run.median_ms < 5.0,
            "batch_delay added too much latency: {} ms",
            run.median_ms
        );
    }
}
