//! Report types: the harness's textual equivalents of the paper's tables
//! and figures.

use crate::metrics::{IntervalSummary, Timeline};
use std::fmt::Write as _;

/// A reproduced figure: one or more labeled timeline series.
#[derive(Debug, Default)]
pub struct FigureReport {
    pub id: String,
    pub title: String,
    pub series: Vec<(String, Timeline)>,
    pub notes: Vec<String>,
}

impl FigureReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        for (label, tl) in &self.series {
            let _ = writeln!(out, "--- series: {label} ---");
            out.push_str(&tl.to_table());
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// A reproduced table (Table 1 / Table 2): per-client-count interval
/// summaries for `[0,10) s` vs `[10,20) s`.
#[derive(Debug, Default)]
pub struct TableReport {
    pub id: String,
    pub title: String,
    /// (clients, summary_0_10, summary_10_20)
    pub rows: Vec<(usize, IntervalSummary, IntervalSummary)>,
    pub notes: Vec<String>,
}

impl TableReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        let _ = writeln!(out, "Latency (ms)");
        let _ = writeln!(out, "{:<10} {:>12} {:>12}", "", "0s-10s", "10s-20s");
        for (clients, a, b) in &self.rows {
            let _ = writeln!(out, "[{clients} client(s)]");
            let _ = writeln!(out, "{:<10} {:>12.3} {:>12.3}", "median", a.latency.median, b.latency.median);
            let _ = writeln!(out, "{:<10} {:>12.3} {:>12.3}", "IQR", a.latency.iqr, b.latency.iqr);
            let _ = writeln!(out, "{:<10} {:>12.3} {:>12.3}", "stdev", a.latency.stdev, b.latency.stdev);
        }
        let _ = writeln!(out, "Throughput (commands/second)");
        let _ = writeln!(out, "{:<10} {:>12} {:>12}", "", "0s-10s", "10s-20s");
        for (clients, a, b) in &self.rows {
            let _ = writeln!(out, "[{clients} client(s)]");
            let _ = writeln!(out, "{:<10} {:>12.0} {:>12.0}", "median", a.throughput.median, b.throughput.median);
            let _ = writeln!(out, "{:<10} {:>12.0} {:>12.0}", "IQR", a.throughput.iqr, b.throughput.iqr);
            let _ = writeln!(out, "{:<10} {:>12.0} {:>12.0}", "stdev", a.throughput.stdev, b.throughput.stdev);
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }

    /// The paper's headline claim: reconfiguration has "little to no impact
    /// (roughly 2% changes)" on median latency. Returns the max relative
    /// median-latency change across rows.
    pub fn max_median_latency_change(&self) -> f64 {
        self.rows
            .iter()
            .map(|(_, a, b)| ((b.latency.median - a.latency.median) / a.latency.median).abs())
            .fold(0.0, f64::max)
    }

    /// Max relative median-throughput change across rows.
    pub fn max_median_throughput_change(&self) -> f64 {
        self.rows
            .iter()
            .map(|(_, a, b)| {
                ((b.throughput.median - a.throughput.median) / a.throughput.median).abs()
            })
            .fold(0.0, f64::max)
    }
}

/// A latency-throughput curve (Figure 14).
#[derive(Debug, Default)]
pub struct CurveReport {
    pub id: String,
    pub title: String,
    /// (label, rows of (clients, throughput, median_latency_ms))
    pub series: Vec<(String, Vec<(usize, f64, f64)>)>,
    pub notes: Vec<String>,
}

impl CurveReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        for (label, rows) in &self.series {
            let _ = writeln!(out, "--- series: {label} ---");
            let _ = writeln!(out, "clients\tthroughput\tmedian_ms");
            for (c, tp, lat) in rows {
                let _ = writeln!(out, "{c}\t{tp:.0}\t{lat:.3}");
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// An offered-load sweep (the X4 open-loop experiment): one row per
/// offered rate, reporting completion rate and tail latency; one series
/// per client variant (e.g. in-flight window 1 vs pipelined).
#[derive(Debug, Default)]
pub struct OpenLoopReport {
    pub id: String,
    pub title: String,
    /// (label, rows) where each row is one [`OpenLoopSummary`].
    pub series: Vec<(String, Vec<crate::metrics::OpenLoopSummary>)>,
    pub notes: Vec<String>,
}

impl OpenLoopReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        for (label, rows) in &self.series {
            let _ = writeln!(out, "--- series: {label} ---");
            let _ = writeln!(
                out,
                "offered/s\tcompleted/s\tdelivered\tp50_ms\tp99_ms"
            );
            for s in rows {
                let _ = writeln!(
                    out,
                    "{:.0}\t{:.0}\t{:.2}\t{:.3}\t{:.3}",
                    s.offered_per_sec,
                    s.completed_per_sec,
                    s.delivery_ratio,
                    s.latency.median,
                    s.latency.p99
                );
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// The X5 state-retention experiment: per-replica retention counters for
/// the snapshot-enabled and snapshot-disabled runs side by side, plus
/// the throughput/convergence acceptance notes.
#[derive(Debug, Default)]
pub struct RetentionReport {
    pub id: String,
    pub title: String,
    /// (label, per-replica rows) — one series per run variant.
    pub series: Vec<(String, Vec<crate::metrics::RetentionSummary>)>,
    pub notes: Vec<String>,
}

impl RetentionReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        for (label, rows) in &self.series {
            let _ = writeln!(out, "--- series: {label} ---");
            let _ = writeln!(
                out,
                "replica\texec_wm\ttrunc_below\tlog_len\tmax_log_len\tsnaps\tinstalled\tdigest"
            );
            for r in rows {
                let _ = writeln!(
                    out,
                    "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:#x}",
                    r.replica,
                    r.exec_watermark,
                    r.truncated_below,
                    r.log_len,
                    r.max_log_len,
                    r.snapshots_taken,
                    r.snapshots_installed,
                    r.digest
                );
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// The X6 sharded scale-out experiment: aggregate and per-group
/// chosen-command rates per shard count, with the reconfiguration-
/// perturbation and shared-matchmaker-log columns.
#[derive(Debug, Default)]
pub struct ShardReport {
    pub id: String,
    pub title: String,
    /// `(shards, offered/s, aggregate chosen/s, min unperturbed ratio,
    /// max matchmaker log entries)` — one row per shard count.
    pub rows: Vec<(usize, f64, f64, f64, usize)>,
    /// Per-group breakdown: one labeled series per shard count.
    pub groups: Vec<(String, Vec<crate::metrics::GroupSummary>)>,
    pub notes: Vec<String>,
}

impl ShardReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        let _ = writeln!(out, "shards\toffered/s\tchosen/s\tunperturbed\tmax_mm_log");
        for (shards, offered, agg, unpert, mm) in &self.rows {
            let _ = writeln!(out, "{shards}\t{offered:.0}\t{agg:.0}\t{unpert:.2}\t{mm}");
        }
        for (label, groups) in &self.groups {
            let _ = writeln!(out, "--- per-group: {label} ---");
            let _ = writeln!(out, "group\tchosen\tchosen/s");
            for g in groups {
                let _ = writeln!(out, "{}\t{}\t{:.0}", g.group, g.chosen, g.chosen_per_sec);
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// The X7 leased-read experiment: per-variant read/write-mix rows plus
/// per-replica read-path counters.
#[derive(Debug, Default)]
pub struct ReadReport {
    pub id: String,
    pub title: String,
    /// `(label, mix summary)` — one row per variant (baseline /
    /// ReadIndex-only / leased).
    pub rows: Vec<(String, crate::metrics::ReadMixSummary)>,
    /// `(label, per-replica (id, leased, indexed))`.
    pub replicas: Vec<(String, Vec<(crate::NodeId, u64, u64)>)>,
    pub notes: Vec<String>,
}

impl ReadReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        let _ = writeln!(
            out,
            "variant\toffered/s\tcompleted/s\treads\twrites\tp50_ms\tp99_ms"
        );
        for (label, s) in &self.rows {
            let _ = writeln!(
                out,
                "{label}\t{:.0}\t{:.0}\t{}\t{}\t{:.3}\t{:.3}",
                s.offered_per_sec,
                s.completed_per_sec,
                s.reads,
                s.writes,
                s.latency.median,
                s.latency.p99
            );
        }
        for (label, reps) in &self.replicas {
            let _ = writeln!(out, "--- read path: {label} ---");
            let _ = writeln!(out, "replica\tleased\tindexed");
            for (id, leased, indexed) in reps {
                let _ = writeln!(out, "{id}\t{leased}\t{indexed}");
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// One X9 overload-sweep row: the outcome of a single offered-load
/// point under one admission policy.
#[derive(Clone, Debug)]
pub struct OverloadRow {
    /// Total offered arrivals per second (all clients).
    pub offered_per_sec: f64,
    /// Completed commands per second — the goodput the X9 gate holds.
    pub goodput: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Requests abandoned (client queue overflow + shed-on-Busy).
    pub abandoned: u64,
    /// Requests the leader rejected with `Busy`.
    pub busy_rejections: u64,
    /// Leader-side `busy_rejections / (busy_rejections + admitted)`.
    pub busy_rate: f64,
    /// Leader's proposal-inbox depth at harvest (arrivals stop before
    /// the horizon, so a drained run ends near zero; mid-run depth is
    /// what the admission cap bounds).
    pub inbox_depth: usize,
    /// Adaptive controller's final effective batch size.
    pub eff_batch: usize,
    /// Adaptive controller's final effective batch delay, µs.
    pub eff_delay_us: u64,
    /// Leader's own windowed p99 (the controller's input), ms.
    pub ctl_p99_ms: f64,
}

/// The X9 overload-control experiment: an offered-load sweep past
/// saturation, one series per admission policy (off / delayed-retry /
/// shed), reporting goodput, tails, pushback counters, and the adaptive
/// batching controller's state.
#[derive(Debug, Default)]
pub struct OverloadReport {
    pub id: String,
    pub title: String,
    /// `(policy label, rows)` — one row per offered rate.
    pub series: Vec<(String, Vec<OverloadRow>)>,
    pub notes: Vec<String>,
}

impl OverloadReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        for (label, rows) in &self.series {
            let _ = writeln!(out, "--- policy: {label} ---");
            let _ = writeln!(
                out,
                "offered/s\tgoodput/s\tp50_ms\tp99_ms\tabandoned\tbusy\tbusy_rate\tinbox\tbatch\tdelay_us\tctl_p99_ms"
            );
            for r in rows {
                let _ = writeln!(
                    out,
                    "{:.0}\t{:.0}\t{:.3}\t{:.3}\t{}\t{}\t{:.3}\t{}\t{}\t{}\t{:.3}",
                    r.offered_per_sec,
                    r.goodput,
                    r.p50_ms,
                    r.p99_ms,
                    r.abandoned,
                    r.busy_rejections,
                    r.busy_rate,
                    r.inbox_depth,
                    r.eff_batch,
                    r.eff_delay_us,
                    r.ctl_p99_ms
                );
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// One X12 fault phase: its window in the run, how long service stalled,
/// and how fast it came back after the heal/restore.
#[derive(Clone, Debug)]
pub struct NemesisRow {
    /// Fault-phase label (e.g. "leader_partition").
    pub label: String,
    /// Fault window `[from, to)`, milliseconds from run start.
    pub from_ms: f64,
    pub to_ms: f64,
    /// Longest gap between consecutive command completions that starts
    /// inside the window (the unavailability this fault caused), ms.
    pub max_stall_ms: f64,
    /// Heal/restore to first completed command, ms (NaN if none).
    pub recover_ms: f64,
}

/// The X12 nemesis experiment: a scripted fault schedule (partition →
/// heal → asymmetric matchmaker partition → gray-slow acceptor → lease
/// clock skew) against its fault-free twin at the same seed, reporting
/// per-fault unavailability/recovery and outside-fault-window goodput.
#[derive(Debug, Default)]
pub struct NemesisReport {
    pub id: String,
    pub title: String,
    /// The injected schedule in `nemesis =` text form.
    pub plan: String,
    pub rows: Vec<NemesisRow>,
    /// Completed commands/sec outside every fault window, faulted run.
    pub goodput_faulted: f64,
    /// Same windows excluded, fault-free twin run.
    pub goodput_fault_free: f64,
    pub notes: Vec<String>,
}

impl NemesisReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        let _ = writeln!(out, "plan: {}", self.plan);
        let _ = writeln!(out, "fault\tfrom_ms\tto_ms\tmax_stall_ms\trecover_ms");
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}\t{:.0}\t{:.0}\t{:.3}\t{:.3}",
                r.label, r.from_ms, r.to_ms, r.max_stall_ms, r.recover_ms
            );
        }
        let _ = writeln!(
            out,
            "goodput outside fault windows: {:.0}/s faulted vs {:.0}/s fault-free \
             ({:.1}%; acceptance target >= 90%)",
            self.goodput_faulted,
            self.goodput_fault_free,
            100.0 * self.goodput_faulted / self.goodput_fault_free.max(1.0)
        );
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// One perf-trajectory row: what a `BENCH_x*.json` line carries.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    /// Variant label (e.g. "batch_32", "leases_on").
    pub label: String,
    /// Completed operations per simulated second.
    pub throughput: f64,
    /// Median latency, ms (NaN → `null` in the JSON).
    pub p50_ms: f64,
    /// 99th-percentile latency, ms (NaN → `null`).
    pub p99_ms: f64,
    /// Offered arrivals per second (NaN → `null` for closed loops).
    pub offered_per_sec: f64,
}

/// Machine-readable experiment summary, written by `repro exp <id>
/// --bench-json <path>` so the repo accumulates a perf trajectory
/// across PRs. Schema (documented in DESIGN.md §Bench trajectory):
///
/// ```json
/// {"experiment":"x7","seed":42,
///  "rows":[{"label":"leases_on","throughput":12345.0,
///           "p50_ms":0.42,"p99_ms":1.9,"offered_per_sec":16000.0}]}
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct BenchJson {
    pub experiment: String,
    pub seed: u64,
    pub rows: Vec<BenchRow>,
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

impl BenchJson {
    /// Serialize (hand-rolled: the build is dependency-free). Labels
    /// are experiment-internal identifiers (no quoting hazards beyond
    /// the basic escapes handled here).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let _ = write!(
            out,
            "{{\"experiment\":\"{}\",\"seed\":{},\"rows\":[",
            esc(&self.experiment),
            self.seed
        );
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"label\":\"{}\",\"throughput\":{},\"p50_ms\":{},\"p99_ms\":{},\
                 \"offered_per_sec\":{}}}",
                esc(&r.label),
                json_num(r.throughput),
                json_num(r.p50_ms),
                json_num(r.p99_ms),
                json_num(r.offered_per_sec)
            );
        }
        out.push_str("]}\n");
        out
    }

    /// Parse a BENCH-schema document back into a [`BenchJson`] — the
    /// other half of the round trip, used by the sweep's baseline
    /// compare (`repro sweep --compare`) to read committed
    /// `benches/baselines/BENCH_*.json` files. Dependency-free like
    /// the emitter: a tiny JSON reader that accepts exactly the value
    /// shapes the schema uses (objects, arrays, strings, numbers,
    /// `null` → NaN) and rejects everything else with a position.
    pub fn parse(text: &str) -> Result<BenchJson, String> {
        use json::Fields as _;
        let v = json::parse(text)?;
        let obj = v.as_obj("top level")?;
        let experiment = obj.get_str("experiment")?;
        let seed = obj.get_num("seed")?;
        if !seed.is_finite() || seed < 0.0 || seed.fract() != 0.0 {
            return Err(format!("\"seed\": expected a non-negative integer, got {seed}"));
        }
        let mut rows = Vec::new();
        for (i, rv) in obj.get_arr("rows")?.iter().enumerate() {
            let row = rv.as_obj(&format!("rows[{i}]"))?;
            rows.push(BenchRow {
                label: row.get_str("label")?,
                throughput: row.get_num("throughput")?,
                p50_ms: row.get_num("p50_ms")?,
                p99_ms: row.get_num("p99_ms")?,
                offered_per_sec: row.get_num("offered_per_sec")?,
            });
        }
        Ok(BenchJson { experiment, seed: seed as u64, rows })
    }
}

/// The minimal JSON reader behind [`BenchJson::parse`] (the build is
/// dependency-free, so no serde). Supports the subset the BENCH schema
/// emits; `null` maps to NaN so the emitter/parser pair round-trips
/// unmeasured metrics.
mod json {
    pub enum Value {
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>),
        Null,
    }

    impl Value {
        pub fn as_obj(&self, what: &str) -> Result<&Vec<(String, Value)>, String> {
            match self {
                Value::Obj(fields) => Ok(fields),
                _ => Err(format!("{what}: expected an object")),
            }
        }
    }

    /// Field accessors for object field lists (duplicate keys keep the
    /// first occurrence, like most readers).
    pub trait Fields {
        fn field(&self, key: &str) -> Result<&Value, String>;
        fn get_str(&self, key: &str) -> Result<String, String> {
            match self.field(key)? {
                Value::Str(s) => Ok(s.clone()),
                _ => Err(format!("\"{key}\": expected a string")),
            }
        }
        fn get_num(&self, key: &str) -> Result<f64, String> {
            match self.field(key)? {
                Value::Num(x) => Ok(*x),
                Value::Null => Ok(f64::NAN),
                _ => Err(format!("\"{key}\": expected a number or null")),
            }
        }
        fn get_arr(&self, key: &str) -> Result<&Vec<Value>, String> {
            match self.field(key)? {
                Value::Arr(items) => Ok(items),
                _ => Err(format!("\"{key}\": expected an array")),
            }
        }
    }

    impl Fields for Vec<(String, Value)> {
        fn field(&self, key: &str) -> Result<&Value, String> {
            self.iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| format!("missing field \"{key}\""))
        }
    }

    pub fn parse(text: &str) -> Result<Value, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after the document"));
        }
        Ok(v)
    }

    struct Parser<'a> {
        bytes: &'a [u8],
        pos: usize,
    }

    impl Parser<'_> {
        fn err(&self, msg: &str) -> String {
            format!("JSON error at byte {}: {msg}", self.pos)
        }

        fn skip_ws(&mut self) {
            while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.pos += 1;
            }
        }

        fn peek(&self) -> Option<u8> {
            self.bytes.get(self.pos).copied()
        }

        fn eat(&mut self, b: u8) -> Result<(), String> {
            if self.peek() == Some(b) {
                self.pos += 1;
                Ok(())
            } else {
                Err(self.err(&format!("expected {:?}", b as char)))
            }
        }

        fn value(&mut self) -> Result<Value, String> {
            self.skip_ws();
            match self.peek() {
                Some(b'{') => self.object(),
                Some(b'[') => self.array(),
                Some(b'"') => Ok(Value::Str(self.string()?)),
                Some(b'n') => {
                    self.keyword("null")?;
                    Ok(Value::Null)
                }
                Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
                _ => Err(self.err("expected a value")),
            }
        }

        fn keyword(&mut self, word: &str) -> Result<(), String> {
            if self.bytes[self.pos..].starts_with(word.as_bytes()) {
                self.pos += word.len();
                Ok(())
            } else {
                Err(self.err(&format!("expected {word}")))
            }
        }

        fn object(&mut self) -> Result<Value, String> {
            self.eat(b'{')?;
            let mut fields = Vec::new();
            self.skip_ws();
            if self.peek() == Some(b'}') {
                self.pos += 1;
                return Ok(Value::Obj(fields));
            }
            loop {
                self.skip_ws();
                let key = self.string()?;
                self.skip_ws();
                self.eat(b':')?;
                fields.push((key, self.value()?));
                self.skip_ws();
                match self.peek() {
                    Some(b',') => self.pos += 1,
                    Some(b'}') => {
                        self.pos += 1;
                        return Ok(Value::Obj(fields));
                    }
                    _ => return Err(self.err("expected ',' or '}' in object")),
                }
            }
        }

        fn array(&mut self) -> Result<Value, String> {
            self.eat(b'[')?;
            let mut items = Vec::new();
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.pos += 1;
                return Ok(Value::Arr(items));
            }
            loop {
                items.push(self.value()?);
                self.skip_ws();
                match self.peek() {
                    Some(b',') => self.pos += 1,
                    Some(b']') => {
                        self.pos += 1;
                        return Ok(Value::Arr(items));
                    }
                    _ => return Err(self.err("expected ',' or ']' in array")),
                }
            }
        }

        fn string(&mut self) -> Result<String, String> {
            self.eat(b'"')?;
            let mut out = String::new();
            loop {
                match self.peek() {
                    Some(b'"') => {
                        self.pos += 1;
                        return Ok(out);
                    }
                    Some(b'\\') => {
                        self.pos += 1;
                        match self.peek() {
                            Some(b'"') => out.push('"'),
                            Some(b'\\') => out.push('\\'),
                            Some(b'/') => out.push('/'),
                            Some(b'n') => out.push('\n'),
                            Some(b't') => out.push('\t'),
                            Some(b'r') => out.push('\r'),
                            _ => return Err(self.err("unsupported escape")),
                        }
                        self.pos += 1;
                    }
                    Some(c) if c < 0x20 => return Err(self.err("raw control byte in string")),
                    Some(_) => {
                        // Copy one UTF-8 scalar (the input is a &str,
                        // so boundaries are valid).
                        let s = &self.bytes[self.pos..];
                        let ch_len = match s[0] {
                            c if c < 0x80 => 1,
                            c if c >= 0xF0 => 4,
                            c if c >= 0xE0 => 3,
                            _ => 2,
                        };
                        out.push_str(std::str::from_utf8(&s[..ch_len]).map_err(|_| {
                            self.err("invalid UTF-8 in string")
                        })?);
                        self.pos += ch_len;
                    }
                    None => return Err(self.err("unterminated string")),
                }
            }
        }

        fn number(&mut self) -> Result<Value, String> {
            let start = self.pos;
            if self.peek() == Some(b'-') {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            {
                self.pos += 1;
            }
            std::str::from_utf8(&self.bytes[start..self.pos])
                .ok()
                .and_then(|s| s.parse::<f64>().ok())
                .map(Value::Num)
                .ok_or_else(|| self.err("malformed number"))
        }
    }
}

/// Violin-plot data (Figures 12/13): distribution quartiles per window.
#[derive(Debug, Default)]
pub struct ViolinReport {
    pub id: String,
    pub title: String,
    /// (label, p25, median, p75, p95) per group.
    pub groups: Vec<(String, f64, f64, f64, f64)>,
}

impl ViolinReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} — {} ===", self.id, self.title);
        let _ = writeln!(out, "group\tp25\tmedian\tp75\tp95");
        for (label, p25, med, p75, p95) in &self.groups {
            let _ = writeln!(out, "{label}\t{p25:.3}\t{med:.3}\t{p75:.3}\t{p95:.3}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Stats;

    fn dummy_summary(median: f64) -> IntervalSummary {
        let s = Stats { median, ..Default::default() };
        IntervalSummary { latency: s, throughput: s }
    }

    #[test]
    fn table_report_renders_and_compares() {
        let t = TableReport {
            id: "T1".into(),
            title: "test".into(),
            rows: vec![(1, dummy_summary(1.0), dummy_summary(1.01))],
            notes: vec![],
        };
        let r = t.render();
        assert!(r.contains("Latency (ms)"));
        assert!(r.contains("Throughput"));
        assert!((t.max_median_latency_change() - 0.01).abs() < 1e-9);
    }

    #[test]
    fn figure_report_renders() {
        let f = FigureReport {
            id: "F9".into(),
            title: "timeline".into(),
            series: vec![("1 client".into(), Timeline::default())],
            notes: vec!["x".into()],
        };
        let r = f.render();
        assert!(r.contains("F9"));
        assert!(r.contains("note: x"));
    }

    #[test]
    fn curve_report_renders() {
        let c = CurveReport {
            id: "F14".into(),
            title: "curves".into(),
            series: vec![("thrifty".into(), vec![(8, 19000.0, 0.4)])],
            notes: vec![],
        };
        assert!(c.render().contains("19000"));
    }

    #[test]
    fn retention_report_renders() {
        use crate::metrics::RetentionSummary;
        let row = RetentionSummary {
            replica: 11,
            exec_watermark: 9000,
            truncated_below: 8192,
            log_len: 808,
            max_log_len: 1300,
            snapshots_taken: 40,
            snapshots_installed: 1,
            digest: 0xabcd,
        };
        let r = RetentionReport {
            id: "X5".into(),
            title: "state retention".into(),
            series: vec![("snapshots on".into(), vec![row])],
            notes: vec!["bounded".into()],
        };
        let text = r.render();
        assert!(text.contains("max_log_len"));
        assert!(text.contains("8192"));
        assert!(text.contains("0xabcd"));
        assert!(text.contains("note: bounded"));
    }

    #[test]
    fn shard_report_renders() {
        use crate::metrics::GroupSummary;
        let r = ShardReport {
            id: "X6".into(),
            title: "scale-out".into(),
            rows: vec![(4, 16000.0, 15000.0, 0.97, 5)],
            groups: vec![(
                "4 groups".into(),
                vec![GroupSummary { group: 0, chosen: 9000, chosen_per_sec: 3750.0 }],
            )],
            notes: vec!["scales".into()],
        };
        let text = r.render();
        assert!(text.contains("unperturbed"));
        assert!(text.contains("15000"));
        assert!(text.contains("3750"));
        assert!(text.contains("note: scales"));
    }

    #[test]
    fn read_report_renders() {
        use crate::metrics::ReadMixSummary;
        let s = ReadMixSummary {
            offered: 16000,
            completed: 15000,
            reads: 13500,
            writes: 1500,
            offered_per_sec: 16000.0,
            completed_per_sec: 15000.0,
            latency: Stats { median: 0.4, p99: 2.0, ..Default::default() },
        };
        let r = ReadReport {
            id: "X7".into(),
            title: "leased reads".into(),
            rows: vec![("leases_on".into(), s)],
            replicas: vec![("leases_on".into(), vec![(14, 9000, 120)])],
            notes: vec!["2x".into()],
        };
        let text = r.render();
        assert!(text.contains("leases_on"));
        assert!(text.contains("15000"));
        assert!(text.contains("leased\tindexed"));
        assert!(text.contains("note: 2x"));
    }

    #[test]
    fn bench_json_schema() {
        let b = BenchJson {
            experiment: "x7".into(),
            seed: 42,
            rows: vec![
                BenchRow {
                    label: "leases_on".into(),
                    throughput: 12345.0,
                    p50_ms: 0.42,
                    p99_ms: 1.9,
                    offered_per_sec: 16000.0,
                },
                BenchRow {
                    label: "closed".into(),
                    throughput: 100.0,
                    p50_ms: f64::NAN,
                    p99_ms: f64::NAN,
                    offered_per_sec: f64::NAN,
                },
            ],
        };
        let j = b.to_json();
        assert!(j.starts_with("{\"experiment\":\"x7\",\"seed\":42,\"rows\":["));
        assert!(j.contains("\"label\":\"leases_on\""));
        assert!(j.contains("\"throughput\":12345.000"));
        // NaNs become null (valid JSON), not bare NaN.
        assert!(j.contains("\"p50_ms\":null"));
        assert!(!j.contains("NaN"));
        assert!(j.trim_end().ends_with("]}"));
    }

    #[test]
    fn bench_json_round_trips() {
        // serialize → parse → compare: the emitter and parser agree on
        // the schema, NaN → null → NaN included (compared via re-
        // serialization, since NaN != NaN).
        let b = BenchJson {
            experiment: "sweep_smoke".into(),
            seed: 42,
            rows: vec![
                BenchRow {
                    label: "b32_s4_r90_loss10_rc500_lease_snap".into(),
                    throughput: 3520.25,
                    p50_ms: 0.875,
                    p99_ms: 12.5,
                    offered_per_sec: 4000.0,
                },
                BenchRow {
                    label: "closed \"quoted\"\\slash".into(),
                    throughput: 100.0,
                    p50_ms: f64::NAN,
                    p99_ms: f64::NAN,
                    offered_per_sec: f64::NAN,
                },
            ],
        };
        let j = b.to_json();
        let parsed = BenchJson::parse(&j).expect("parse own output");
        assert_eq!(parsed.experiment, b.experiment);
        assert_eq!(parsed.seed, b.seed);
        assert_eq!(parsed.rows.len(), b.rows.len());
        assert_eq!(parsed.rows[1].label, b.rows[1].label);
        assert!(parsed.rows[1].p50_ms.is_nan());
        assert_eq!(parsed.to_json(), j, "round trip must be byte-stable");
    }

    #[test]
    fn bench_json_parse_rejects_malformed() {
        for bad in [
            "",
            "{",
            "{\"experiment\":\"x\",\"seed\":1}",           // missing rows
            "{\"experiment\":\"x\",\"seed\":1,\"rows\":3}", // rows not an array
            "{\"experiment\":\"x\",\"seed\":-1,\"rows\":[]}", // negative seed
            "{\"experiment\":\"x\",\"seed\":1,\"rows\":[{\"label\":\"a\"}]}", // row missing fields
            "{\"experiment\":\"x\",\"seed\":1,\"rows\":[]}trailing",
        ] {
            assert!(BenchJson::parse(bad).is_err(), "accepted {bad:?}");
        }
        // Whitespace and null metrics are fine.
        let ok = "{ \"experiment\": \"x3\", \"seed\": 7,\n \"rows\": [\n  {\"label\": \"a\",\
                  \"throughput\": 1.5, \"p50_ms\": null, \"p99_ms\": null, \
                  \"offered_per_sec\": null} ] }";
        let b = BenchJson::parse(ok).unwrap();
        assert_eq!((b.experiment.as_str(), b.seed), ("x3", 7));
        assert_eq!(b.rows[0].throughput, 1.5);
        assert!(b.rows[0].p99_ms.is_nan());
    }

    #[test]
    fn open_loop_report_renders() {
        use crate::metrics::OpenLoopSummary;
        let lat = Stats { median: 0.5, p99: 2.25, ..Default::default() };
        let row = OpenLoopSummary {
            offered: 4000,
            completed: 3000,
            offered_per_sec: 2000.0,
            completed_per_sec: 1500.0,
            delivery_ratio: 0.75,
            latency: lat,
        };
        let r = OpenLoopReport {
            id: "X4".into(),
            title: "offered load".into(),
            series: vec![("pipelined".into(), vec![row])],
            notes: vec!["saturates".into()],
        };
        let text = r.render();
        assert!(text.contains("p99_ms"));
        assert!(text.contains("1500"));
        assert!(text.contains("2.250"));
        assert!(text.contains("note: saturates"));
    }
}
