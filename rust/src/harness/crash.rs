//! X10 — the kill -9 crash-recovery storm (DESIGN.md §Durability).
//!
//! Everything here runs on the REAL TCP runtime ([`crate::net`]), not
//! the simulator: a full f = 1 deployment in one process (one thread
//! per node), every protocol role journaling to an fsync'd WAL under a
//! scratch directory. The storm then repeatedly
//!
//! 1. injects a reconfiguration (an out-of-band frame the harness
//!    writes straight into the proposers' sockets),
//! 2. kills one node of every role mid-reconfiguration — the runtime's
//!    shutdown is durability-equivalent to `kill -9` because nothing is
//!    flushed at exit; every WAL append was fsync'd *before* the role
//!    acted on it,
//! 3. restarts each victim from its data directory and waits for the
//!    cluster to resume choosing and executing commands.
//!
//! Afterwards the replicas' WALs are recovered *offline* (fresh
//! [`Replica`]s over the surviving directories, no network) and the run
//! asserts the durability contract: identical state digests and
//! watermarks across all replicas, watermarks covering every execution
//! any live incarnation ever announced, and reconfigurations activated
//! mid-storm.
// This driver times real sockets and real fsyncs, so the wall clock is
// the tool of the trade — the same exemption clippy.toml grants
// src/net/. The determinism lint targets roles/, sim/, and check/.
#![allow(clippy::disallowed_methods)]

use super::report::FigureReport;
use crate::config::{ClusterLayout, Configuration, DeploymentConfig, OptFlags, SnapshotSpec, StorageSpec};
use crate::msg::{Envelope, Msg};
use crate::net::{encode_frame, local_addrs, spawn_node, NodeHandle};
use crate::node::{Announce, Effects, Node, Timer};
use crate::roles::{Acceptor, Client, Leader, Matchmaker, Replica};
use crate::statemachine;
use crate::storage::wal::WalStorage;
use crate::storage::Storage;
use crate::{NodeId, Slot, Time, MS};
use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Out-of-band sender id for harness-injected control frames. Not in the
/// address map, so no node can reply to it — injection is one-way.
const HARNESS: NodeId = 9_999;

/// Port range for the storm cluster (21100/21400 belong to the net
/// integration tests).
const PORT_BASE: u16 = 21_700;

/// Result of one storm run (consumed by the X10 figure and the
/// `--bench-json` rows).
pub struct StormResult {
    /// Executed-announcement rate before the first crash (counted across
    /// all replicas, so ~3x the command rate).
    pub pre_tput: f64,
    /// Per storm round: (ms from restart until the restarted replica
    /// executed again, executions observed while re-stabilizing).
    pub rounds: Vec<(f64, u64)>,
    /// `ConfigActive` announcements observed (startup + storm).
    pub reconfigs_activated: u64,
    /// Offline-recovered `(replica, exec_watermark, state digest)`.
    pub replicas: Vec<(NodeId, Slot, u64)>,
    /// Total executed announcements across the whole run.
    pub executed_total: u64,
}

/// Proposer wrapper: the TCP runtime has no admin RPC, so the storm
/// driver triggers reconfigurations by writing a `Heartbeat` frame from
/// the reserved [`HARNESS`] id straight into the proposer's socket; this
/// wrapper turns it into a [`Leader::reconfigure`] call (`epoch` indexes
/// the target list). Everything else delegates unchanged — and since
/// `reconfigure` is a no-op on a follower, the driver can broadcast the
/// trigger to all proposers without knowing who currently leads.
struct StormLeader {
    inner: Leader,
    targets: Vec<Configuration>,
}

impl Node for StormLeader {
    fn on_msg(&mut self, now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        if from == HARNESS {
            if let Msg::Heartbeat { epoch } = msg {
                let cfg = self.targets[epoch as usize % self.targets.len()].clone();
                self.inner.reconfigure(cfg, now, fx);
            }
            return;
        }
        self.inner.on_msg(now, from, msg, fx);
    }
    fn on_timer(&mut self, now: Time, t: Timer, fx: &mut Effects) {
        self.inner.on_timer(now, t, fx);
    }
    fn on_start(&mut self, now: Time, fx: &mut Effects) {
        self.inner.on_start(now, fx);
    }
    fn role(&self) -> &'static str {
        self.inner.role()
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Everything needed to (re)build any protocol node from its data
/// directory — the in-process equivalent of `repro run --data-dir`.
struct Boot {
    layout: ClusterLayout,
    opts: OptFlags,
    targets: Vec<Configuration>,
    root: PathBuf,
}

impl Boot {
    fn wal(&self, role: &str, id: NodeId) -> Box<dyn Storage> {
        let dir = self.root.join(format!("{role}-{id}"));
        Box::new(
            WalStorage::open(dir, self.opts.storage.wal_options()).expect("open x10 wal"),
        )
    }

    fn node(&self, id: NodeId) -> Box<dyn Node> {
        let l = &self.layout;
        if l.acceptor_pool.contains(&id) {
            let mut a = Acceptor::new(id);
            a.attach_storage(self.wal("acceptor", id));
            // Recovery predates the network; the announce goes nowhere.
            a.recover(&mut Effects::new());
            Box::new(a)
        } else if l.matchmaker_pool.contains(&id) {
            let active = l.initial_matchmakers().contains(&id);
            let mut m = if active { Matchmaker::new(id) } else { Matchmaker::new_standby(id) };
            m.attach_storage(self.wal("matchmaker", id));
            m.recover();
            Box::new(m)
        } else if l.replicas.contains(&id) {
            let mut r = Replica::new(id, statemachine::by_name("counter").expect("counter sm"));
            r.announce_execs = true; // the storm counts executions
            r.snapshot = self.opts.snapshot;
            r.peers = l.replicas.clone();
            r.proposers = l.proposers.clone();
            r.attach_storage(self.wal("replica", id));
            r.recover();
            Box::new(r)
        } else if l.proposers.contains(&id) {
            let mut leader = Leader::new(
                id,
                l.f,
                l.initial_config(),
                l.initial_matchmakers(),
                l.replicas.clone(),
                l.proposers.clone(),
                self.opts,
                id as u64,
            );
            leader.attach_storage(self.wal("proposer", id));
            leader.recover();
            Box::new(StormLeader { inner: leader, targets: self.targets.clone() })
        } else {
            unreachable!("id {id} has no protocol role")
        }
    }
}

/// Spawn with rebind retries: the previous incarnation's listener is
/// released on shutdown, but the OS may take a beat to finish the
/// accept-loop teardown.
fn spawn_retry(
    id: NodeId,
    boot: &Boot,
    addrs: &BTreeMap<NodeId, String>,
) -> NodeHandle {
    let mut last = None;
    for _ in 0..100 {
        match spawn_node(id, boot.node(id), addrs.clone()) {
            Ok(h) => return h,
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(30));
            }
        }
    }
    panic!("node {id} failed to (re)bind: {}", last.unwrap());
}

/// Write one frame into a node's socket from the out-of-band harness id.
fn inject(addrs: &BTreeMap<NodeId, String>, to: NodeId, msg: Msg) {
    let Some(addr) = addrs.get(&to) else { return };
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = s.write_all(&encode_frame(&Envelope { from: HARNESS, to, msg }));
    }
}

/// Drain every handle's announce stream into the run counters.
fn drain(
    handles: &BTreeMap<NodeId, NodeHandle>,
    exec_high: &mut BTreeMap<NodeId, Slot>,
    executed_total: &mut u64,
    reconfigs: &mut u64,
) {
    for h in handles.values() {
        while let Ok((_, a)) = h.announces.try_recv() {
            match a {
                Announce::Executed { slot, replica } => {
                    *executed_total += 1;
                    let e = exec_high.entry(replica).or_insert(0);
                    *e = (*e).max(slot);
                }
                Announce::ConfigActive { .. } => *reconfigs += 1,
                _ => {}
            }
        }
    }
}

/// Run the storm: `rounds` iterations of reconfigure → kill one node of
/// every role → restart from disk → wait for recovery. Panics (failing
/// the experiment / test) on any durability violation.
pub fn run_crash_storm(seed: u64, rounds: usize) -> StormResult {
    let mut cfg = DeploymentConfig::standard(1, 2);
    cfg.state_machine = "counter".into();
    // Aggressive knobs so the storm actually exercises the machinery:
    // frequent snapshots (truncation + WAL compaction live), small WAL
    // segments (rotation live), deltas every other snapshot.
    cfg.opts.snapshot = SnapshotSpec::every(100 * MS, 1024);
    cfg.opts.storage = StorageSpec {
        enabled: true,
        fsync: true,
        segment_bytes: 64 << 10,
        full_every: 2,
    };
    let layout = cfg.layout.clone();
    let addrs = local_addrs(layout.total_nodes(), PORT_BASE);
    let data_root = crate::storage::scratch_dir(&format!("x10-{seed}"));
    std::fs::create_dir_all(&data_root).expect("create x10 scratch dir");

    // Reconfiguration targets: seed-rotated 2f+1 windows over the pool.
    let pool = layout.acceptor_pool.clone();
    let targets: Vec<Configuration> = (0..pool.len())
        .map(|i| {
            let accs: Vec<NodeId> =
                (0..3).map(|j| pool[(i + j + seed as usize) % pool.len()]).collect();
            Configuration::majority(100 + i as u64, accs)
        })
        .collect();

    let boot = Boot {
        layout: layout.clone(),
        opts: cfg.opts,
        targets,
        root: data_root.clone(),
    };

    let protocol_ids: Vec<NodeId> = layout
        .acceptor_pool
        .iter()
        .chain(&layout.matchmaker_pool)
        .chain(&layout.replicas)
        .chain(&layout.proposers)
        .copied()
        .collect();
    let mut handles: BTreeMap<NodeId, NodeHandle> = BTreeMap::new();
    for &id in &protocol_ids {
        handles.insert(id, spawn_retry(id, &boot, &addrs));
    }
    let mut client_handles = Vec::new();
    for &c in &layout.clients {
        let mut cl = Client::new(c, layout.proposers.clone(), cfg.workload.clone());
        cl.replicas = layout.replicas.clone();
        client_handles.push(spawn_node(c, Box::new(cl), addrs.clone()).expect("spawn client"));
    }

    let mut exec_high: BTreeMap<NodeId, Slot> = BTreeMap::new();
    let mut executed_total: u64 = 0;
    let mut reconfigs: u64 = 0;

    // Warm up: the cluster must be choosing briskly before we start
    // breaking it.
    let t0 = Instant::now();
    while executed_total < 150 && t0.elapsed() < Duration::from_secs(20) {
        std::thread::sleep(Duration::from_millis(50));
        drain(&handles, &mut exec_high, &mut executed_total, &mut reconfigs);
    }
    assert!(
        executed_total >= 150,
        "cluster never got going: {executed_total} executions in {:?}",
        t0.elapsed()
    );
    let pre_tput = executed_total as f64 / t0.elapsed().as_secs_f64();

    let mut round_stats: Vec<(f64, u64)> = Vec::new();
    for k in 0..rounds {
        // 1. Reconfiguration trigger (whichever proposer leads acts).
        for &p in &layout.proposers {
            inject(&addrs, p, Msg::Heartbeat { epoch: k as u64 });
        }
        std::thread::sleep(Duration::from_millis(150)); // land mid-storm
        drain(&handles, &mut exec_high, &mut executed_total, &mut reconfigs);

        // 2. kill -9 one node of every role.
        let victims = [
            layout.acceptor_pool[k % layout.acceptor_pool.len()],
            layout.matchmaker_pool[k % layout.matchmaker_pool.len()],
            layout.replicas[k % layout.replicas.len()],
            layout.proposers[k % layout.proposers.len()],
        ];
        let victim_replica = victims[2];
        let wm_at_kill = exec_high.get(&victim_replica).copied().unwrap_or(0);
        for &v in &victims {
            let h = handles.remove(&v).expect("victim handle");
            // Absorb announces still queued from the dying incarnation.
            while let Ok((_, a)) = h.announces.try_recv() {
                match a {
                    Announce::Executed { slot, replica } => {
                        executed_total += 1;
                        let e = exec_high.entry(replica).or_insert(0);
                        *e = (*e).max(slot);
                    }
                    Announce::ConfigActive { .. } => reconfigs += 1,
                    _ => {}
                }
            }
            h.shutdown();
            // Join before respawning: the WAL's segment handle must be
            // dropped before a second incarnation opens the directory.
            h.join.join().ok();
        }

        // 3. Restart every victim from its data directory.
        let restart_at = Instant::now();
        for &v in &victims {
            handles.insert(v, spawn_retry(v, &boot, &addrs));
        }

        // 4. Wait until the restarted replica executes past its durable
        //    watermark and the cluster shows clear net progress.
        let base_total = executed_total;
        let mut recovered_ms: Option<f64> = None;
        while restart_at.elapsed() < Duration::from_secs(20) {
            std::thread::sleep(Duration::from_millis(30));
            drain(&handles, &mut exec_high, &mut executed_total, &mut reconfigs);
            let wm = exec_high.get(&victim_replica).copied().unwrap_or(0);
            if recovered_ms.is_none() && wm > wm_at_kill {
                recovered_ms = Some(restart_at.elapsed().as_secs_f64() * 1e3);
            }
            if recovered_ms.is_some() && executed_total >= base_total + 90 {
                break;
            }
        }
        let rec = recovered_ms.unwrap_or_else(|| {
            panic!("round {k}: replica {victim_replica} never executed after restart")
        });
        assert!(
            executed_total >= base_total + 90,
            "round {k}: cluster stalled after restarts ({} new executions)",
            executed_total - base_total
        );
        round_stats.push((rec, executed_total - base_total));
    }

    // Quiesce: stop the clients; the leader's ack/refeed chain drains
    // every replica to a common watermark without fresh traffic.
    for h in &client_handles {
        h.shutdown();
    }
    let settle = Instant::now();
    let mut quiet_rounds = 0;
    while settle.elapsed() < Duration::from_secs(10) && quiet_rounds < 4 {
        let before = executed_total;
        std::thread::sleep(Duration::from_millis(100));
        drain(&handles, &mut exec_high, &mut executed_total, &mut reconfigs);
        let highs: Vec<Slot> = layout
            .replicas
            .iter()
            .map(|r| exec_high.get(r).copied().unwrap_or(0))
            .collect();
        let all_equal = highs.windows(2).all(|w| w[0] == w[1]);
        if executed_total == before && all_equal {
            quiet_rounds += 1;
        } else {
            quiet_rounds = 0;
        }
    }

    // Final kill: take the whole cluster down abruptly.
    for (_, h) in handles {
        h.shutdown();
        h.join.join().ok();
    }
    for h in client_handles {
        h.join.join().ok();
    }

    // Offline recovery: fresh replicas over the surviving directories.
    // What the WALs hold *is* the durability contract.
    let mut recovered: Vec<(NodeId, Slot, u64)> = Vec::new();
    for &r in &layout.replicas {
        let mut rep = Replica::new(r, statemachine::by_name("counter").expect("counter sm"));
        rep.attach_storage(Box::new(
            WalStorage::open(
                data_root.join(format!("replica-{r}")),
                cfg.opts.storage.wal_options(),
            )
            .expect("reopen replica wal"),
        ));
        rep.recover();
        recovered.push((r, rep.exec_watermark, rep.sm.digest()));
    }

    let (_, wm0, digest0) = recovered[0];
    for &(r, wm, digest) in &recovered {
        let live = exec_high.get(&r).copied().unwrap_or(0);
        assert!(
            wm > live || (wm == 0 && live == 0),
            "replica {r}: recovered watermark {wm} lost executions \
             (live incarnations announced slot {live} as executed)"
        );
        assert_eq!(
            wm, wm0,
            "replica {r}: recovered watermark diverges ({wm} vs {wm0})"
        );
        assert_eq!(
            digest, digest0,
            "replica {r}: recovered state digest diverges \
             ({digest:#x} vs {digest0:#x} at watermark {wm})"
        );
    }
    assert!(wm0 > 0, "no durable executions survived the storm");
    assert!(
        reconfigs >= 2,
        "no reconfiguration activated mid-storm ({reconfigs} ConfigActive events)"
    );

    let _ = std::fs::remove_dir_all(&data_root);
    StormResult {
        pre_tput,
        rounds: round_stats,
        reconfigs_activated: reconfigs,
        replicas: recovered,
        executed_total,
    }
}

/// X10 report: run a 3-round storm and render what survived.
pub fn crash_recovery_figure(seed: u64) -> FigureReport {
    let r = run_crash_storm(seed, 3);
    let mut fig = FigureReport {
        id: "X10".into(),
        title: "kill -9 crash-recovery storm: TCP runtime, fsync'd WALs, one node of \
                every role killed + restarted per round, mid-reconfiguration"
            .into(),
        ..Default::default()
    };
    fig.notes.push(format!(
        "pre-crash: {:.0} executed-announcements/s (3 replicas announcing)",
        r.pre_tput
    ));
    for (i, (ms, execs)) in r.rounds.iter().enumerate() {
        fig.notes.push(format!(
            "round {i}: restarted replica executing again after {ms:.0} ms; \
             {execs} executions to re-stabilize"
        ));
    }
    fig.notes.push(format!(
        "{} ConfigActive events (startup + storm reconfigurations + takeovers)",
        r.reconfigs_activated
    ));
    for (id, wm, digest) in &r.replicas {
        fig.notes.push(format!(
            "replica {id}: offline-recovered watermark {wm}, digest {digest:#x}"
        ));
    }
    fig.notes.push(
        "durability contract held: identical digests/watermarks across all replicas, \
         no announced execution lost"
            .into(),
    );
    fig
}
