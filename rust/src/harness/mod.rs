//! Experiment harness: builds simulated clusters shaped like the paper's
//! deployments and runs the §8 experiment scripts.
//!
//! * [`Cluster`] — a full Matchmaker MultiPaxos deployment in the
//!   simulator: `f+1` proposers (all running [`Leader`]), a pool of
//!   `2·(2f+1)` acceptors, a pool of `2·(2f+1)` matchmakers (first `2f+1`
//!   active), `2f+1` replicas, and N workload clients.
//! * [`HorizontalCluster`] — the baseline deployment (no matchmakers).
//! * [`experiments`] — one driver per paper table/figure (see DESIGN.md's
//!   per-experiment index).
//!
//! Clusters are built with a builder — every knob has a paper-faithful
//! default, and the workload is a first-class [`WorkloadSpec`] instead of
//! per-client field poking:
//!
//! ```
//! use matchmaker::harness::{secs, Cluster};
//! use matchmaker::sim::NetworkModel;
//! use matchmaker::workload::WorkloadSpec;
//!
//! let mut cluster = Cluster::builder()
//!     .f(1)
//!     .clients(4)
//!     .workload(WorkloadSpec::open_loop(500.0).max_in_flight(16))
//!     .net(NetworkModel::lan())
//!     .seed(7)
//!     .build();
//! cluster.sim.run_until(secs(1));
//! cluster.assert_safe();
//! ```

pub mod experiments;
pub mod report;

use crate::config::{ClusterLayout, Configuration, OptFlags};
use crate::metrics::{merge_samples, RetentionSummary, Sample};
use crate::node::Announce;
use crate::roles::{Acceptor, Client, HorizontalLeader, Leader, Matchmaker, Replica};
use crate::round::Round;
use crate::sim::{NetworkModel, Sim};
use crate::statemachine::Noop;
use crate::util::Rng;
use crate::workload::WorkloadSpec;
use crate::{NodeId, Time, MS, SEC};

/// A simulated Matchmaker MultiPaxos cluster.
pub struct Cluster {
    pub layout: ClusterLayout,
    pub sim: Sim,
    pub opts: OptFlags,
    pub f: usize,
    /// The workload every client runs (see [`WorkloadSpec`]).
    pub workload: WorkloadSpec,
    rng: Rng,
}

/// Builder for [`Cluster`]. Every knob defaults to the paper's §8.1
/// deployment: `f = 1`, 4 closed-loop clients, all optimizations on,
/// LAN network, seed 42.
#[derive(Clone, Debug)]
pub struct ClusterBuilder {
    f: usize,
    clients: usize,
    workload: WorkloadSpec,
    opts: OptFlags,
    seed: u64,
    net: NetworkModel,
    pool_factor: usize,
}

impl Default for ClusterBuilder {
    fn default() -> Self {
        ClusterBuilder {
            f: 1,
            clients: 4,
            workload: WorkloadSpec::closed_loop(),
            opts: OptFlags::default(),
            seed: 42,
            net: NetworkModel::lan(),
            pool_factor: 2,
        }
    }
}

impl ClusterBuilder {
    /// Fault-tolerance parameter (proposers = f+1, initial quorums of
    /// 2f+1 from a pool of `pool_factor·(2f+1)`).
    pub fn f(mut self, f: usize) -> Self {
        self.f = f;
        self
    }

    /// Number of workload clients.
    pub fn clients(mut self, n: usize) -> Self {
        self.clients = n;
        self
    }

    /// The workload every client runs (default:
    /// [`WorkloadSpec::closed_loop`], the paper's §8.1 client).
    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    /// Protocol optimization flags.
    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    /// Simulation seed (identical seeds give bit-identical runs).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Network model (default [`NetworkModel::lan`]).
    pub fn net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Acceptor/matchmaker pool size factor (default 2: a pool of
    /// `2·(2f+1)`, the §8.1 reconfiguration-experiment shape).
    pub fn pool_factor(mut self, k: usize) -> Self {
        self.pool_factor = k.max(1);
        self
    }

    /// Build and start the cluster: the first proposer becomes leader,
    /// the first `2f+1` acceptors form the initial configuration, and
    /// clients start their workloads.
    pub fn build(self) -> Cluster {
        let ClusterBuilder { f, clients, workload, opts, seed, net, pool_factor } = self;
        let layout = ClusterLayout::standard(f, pool_factor, clients);
        layout.validate().expect("valid layout");
        let mut sim = Sim::new(seed, net);
        let initial_cfg = layout.initial_config();
        let active_mms = layout.initial_matchmakers();

        // Acceptors: the whole pool is alive; only configured ones get
        // traffic.
        for &a in &layout.acceptor_pool {
            sim.add_node(a, Box::new(Acceptor::new(a)));
        }
        // Matchmakers: first 2f+1 active, rest standby (§6 pool).
        for (i, &m) in layout.matchmaker_pool.iter().enumerate() {
            if i < active_mms.len() {
                sim.add_node(m, Box::new(Matchmaker::new(m)));
            } else {
                sim.add_node(m, Box::new(Matchmaker::new_standby(m)));
            }
        }
        // Replicas (paper §5.3 deploys 2f+1), with the snapshot policy
        // and peer list for snapshot catch-up.
        for &r in &layout.replicas {
            let mut rep = Replica::new(r, Box::new(Noop));
            rep.snapshot = opts.snapshot;
            rep.peers = layout.replicas.clone();
            sim.add_node(r, Box::new(rep));
        }
        // Proposers: all run the Leader role; proposers[0] self-elects at
        // start (see Leader::on_start).
        for &p in &layout.proposers {
            let leader = Leader::new(
                p,
                f,
                initial_cfg.clone(),
                active_mms.clone(),
                layout.replicas.clone(),
                layout.proposers.clone(),
                opts,
                seed,
            );
            sim.add_node(p, Box::new(leader));
        }
        // Clients, each driven by the shared workload spec.
        for &c in &layout.clients {
            sim.add_node(
                c,
                Box::new(Client::new(c, layout.proposers.clone(), workload.clone())),
            );
        }
        Cluster { layout, sim, opts, f, workload, rng: Rng::new(seed ^ 0xc1a5) }
    }
}

impl Cluster {
    /// Start describing a cluster (see [`ClusterBuilder`]).
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    pub fn initial_leader(&self) -> NodeId {
        self.layout.proposers[0]
    }

    /// Draw a random configuration of `2f+1` acceptors from the pool
    /// (the §8.1 reconfiguration workload), with a fresh config id.
    pub fn random_config(&mut self, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.layout.acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    /// Draw a random matchmaker set of `2f+1` from the pool (§8.4).
    pub fn random_matchmakers(&mut self) -> Vec<NodeId> {
        self.rng.sample(&self.layout.matchmaker_pool, 2 * self.f + 1)
    }

    /// Harvest all client samples, merged and sorted by completion time.
    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.layout.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<Client>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }

    /// Sum the clients' workload counters: `(offered, completed,
    /// abandoned)`. For open-loop workloads `offered` counts arrivals
    /// whether or not they completed — the offered-load experiments
    /// compare it against the completion rate.
    pub fn workload_totals(&mut self) -> (u64, u64, u64) {
        let clients = self.layout.clients.clone();
        let (mut offered, mut completed, mut abandoned) = (0u64, 0u64, 0u64);
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<Client>(c) {
                offered += cl.offered;
                completed += cl.completed;
                abandoned += cl.abandoned;
            }
        }
        (offered, completed, abandoned)
    }

    /// Reconfiguration → active latencies (MatchA issue → ConfigActive),
    /// and → retired latencies (→ ConfigRetired), in ms, keyed by the
    /// issue times passed in.
    pub fn reconfig_latencies(&self, issue_times: &[(Time, Round)]) -> Vec<(f64, Option<f64>)> {
        let mut out = Vec::new();
        for &(t0, round) in issue_times {
            let active = self.sim.announces.iter().find_map(|(t, _, a)| match a {
                Announce::ConfigActive { round: r, .. } if *r == round => Some(*t),
                _ => None,
            });
            let retired = self.sim.announces.iter().find_map(|(t, _, a)| match a {
                Announce::ConfigRetired { round: r } if *r == round => Some(*t),
                _ => None,
            });
            if let Some(ta) = active {
                out.push((
                    (ta.saturating_sub(t0)) as f64 / 1e6,
                    retired.map(|tr| (tr.saturating_sub(t0)) as f64 / 1e6),
                ));
            }
        }
        out
    }

    /// Assert the global safety invariant (used by tests after every
    /// experiment): at most one value chosen per slot.
    pub fn assert_safe(&self) {
        self.sim.check_chosen_safety().expect("chosen-safety invariant");
    }

    /// Harvest per-replica state-retention counters (log lengths,
    /// snapshot counts, digests) — the X5 experiment's raw material.
    pub fn retention_stats(&mut self) -> Vec<RetentionSummary> {
        let replicas = self.layout.replicas.clone();
        let mut out = Vec::with_capacity(replicas.len());
        for r in replicas {
            if let Some(rep) = self.sim.node_mut::<Replica>(r) {
                out.push(RetentionSummary {
                    replica: r,
                    exec_watermark: rep.exec_watermark,
                    truncated_below: rep.truncated_below,
                    log_len: rep.log_len(),
                    max_log_len: rep.max_log_len,
                    snapshots_taken: rep.snapshots_taken,
                    snapshots_installed: rep.snapshots_installed,
                    digest: rep.sm.digest(),
                });
            }
        }
        out
    }
}

/// A simulated Horizontal MultiPaxos cluster (baseline, §7.2).
pub struct HorizontalCluster {
    pub sim: Sim,
    pub leader: NodeId,
    pub acceptor_pool: Vec<NodeId>,
    pub replicas: Vec<NodeId>,
    pub clients: Vec<NodeId>,
    pub f: usize,
    rng: Rng,
}

/// Builder for [`HorizontalCluster`]; defaults mirror [`ClusterBuilder`]
/// plus the α window (`alpha = 8`, the §8.1 baseline setting).
#[derive(Clone, Debug)]
pub struct HorizontalClusterBuilder {
    f: usize,
    clients: usize,
    alpha: u64,
    workload: WorkloadSpec,
    seed: u64,
    net: NetworkModel,
}

impl Default for HorizontalClusterBuilder {
    fn default() -> Self {
        HorizontalClusterBuilder {
            f: 1,
            clients: 4,
            alpha: 8,
            workload: WorkloadSpec::closed_loop(),
            seed: 42,
            net: NetworkModel::lan(),
        }
    }
}

impl HorizontalClusterBuilder {
    pub fn f(mut self, f: usize) -> Self {
        self.f = f;
        self
    }

    pub fn clients(mut self, n: usize) -> Self {
        self.clients = n;
        self
    }

    /// The α concurrency window (§7.2): slot `s` may only be proposed
    /// once slot `s - α` is chosen.
    pub fn alpha(mut self, alpha: u64) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    pub fn build(self) -> HorizontalCluster {
        let HorizontalClusterBuilder { f, clients: n_clients, alpha, workload, seed, net } = self;
        let mut sim = Sim::new(seed, net);
        let leader: NodeId = 0;
        let acceptor_pool: Vec<NodeId> =
            (1..=(2 * (2 * f + 1)) as NodeId).collect();
        let replicas: Vec<NodeId> = (acceptor_pool.last().unwrap() + 1
            ..acceptor_pool.last().unwrap() + 1 + (2 * f + 1) as NodeId)
            .collect();
        let clients: Vec<NodeId> = (replicas.last().unwrap() + 1
            ..replicas.last().unwrap() + 1 + n_clients as NodeId)
            .collect();
        for &a in &acceptor_pool {
            sim.add_node(a, Box::new(Acceptor::new(a)));
        }
        for &r in &replicas {
            sim.add_node(r, Box::new(Replica::new(r, Box::new(Noop))));
        }
        let initial = Configuration::majority(0, acceptor_pool[..2 * f + 1].to_vec());
        sim.add_node(
            leader,
            Box::new(HorizontalLeader::new(leader, initial, replicas.clone(), alpha, seed)),
        );
        for &c in &clients {
            sim.add_node(c, Box::new(Client::new(c, vec![leader], workload.clone())));
        }
        HorizontalCluster { sim, leader, acceptor_pool, replicas, clients, f, rng: Rng::new(seed ^ 0x70f) }
    }
}

impl HorizontalCluster {
    /// Start describing a horizontal-baseline cluster.
    pub fn builder() -> HorizontalClusterBuilder {
        HorizontalClusterBuilder::default()
    }

    pub fn random_config(&mut self, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<Client>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }
}

/// Seconds helper for experiment scripts.
pub fn secs(x: u64) -> Time {
    x * SEC
}

/// Milliseconds helper.
pub fn msec(x: u64) -> Time {
    x * MS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn cluster_serves_commands() {
        let mut c = Cluster::builder().seed(42).build();
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100, "got {} samples", samples.len());
        c.assert_safe();
    }

    #[test]
    fn cluster_reconfigures_without_loss() {
        let mut c = Cluster::builder().seed(42).build();
        let leader = c.initial_leader();
        let cfg = c.random_config(1);
        c.sim.schedule(msec(500), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100);
        c.assert_safe();
        // Reconfiguration happened.
        let leader_node = c.sim.node_mut::<Leader>(leader).unwrap();
        assert!(leader_node.reconfigs_completed >= 2); // startup + ours
        assert!(leader_node.gc_completed >= 1);
    }

    #[test]
    fn horizontal_cluster_serves() {
        let mut c = HorizontalCluster::builder().seed(42).build();
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100);
        c.sim.check_chosen_safety().unwrap();
    }

    #[test]
    fn deterministic_same_seed() {
        let run = |seed| {
            let mut c = Cluster::builder().clients(2).seed(seed).build();
            c.sim.run_until(msec(500));
            c.samples().len()
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn pipelined_workload_multiplies_throughput() {
        // Same 2 clients, window 8 vs window 1: the pipelined cluster
        // must complete several times as many commands.
        let completed = |spec: WorkloadSpec| {
            let mut c = Cluster::builder().clients(2).workload(spec).seed(9).build();
            c.sim.run_until(secs(1));
            c.assert_safe();
            c.samples().len()
        };
        let closed = completed(WorkloadSpec::closed_loop());
        let piped = completed(WorkloadSpec::pipelined(8));
        assert!(
            piped as f64 >= 3.0 * closed as f64,
            "pipelining gained only {piped} vs {closed}"
        );
    }

    #[test]
    fn open_loop_tracks_offered_rate() {
        // 2 clients at 500/s each for 2 s ≈ 2000 arrivals, all completed
        // (the system is far from saturation at this rate).
        let spec = WorkloadSpec::open_loop(500.0).max_in_flight(16);
        let mut c = Cluster::builder().clients(2).workload(spec).seed(3).build();
        c.sim.run_until(secs(2));
        c.assert_safe();
        let (offered, completed, abandoned) = c.workload_totals();
        assert!((1900..=2100).contains(&(offered as usize)), "offered {offered}");
        assert_eq!(abandoned, 0);
        // In-flight tail at cutoff may be unfinished; everything else is.
        assert!(completed + 64 >= offered, "completed {completed} of {offered}");
    }
}
