//! Experiment harness: builds simulated clusters shaped like the paper's
//! deployments and runs the §8 experiment scripts.
//!
//! * [`Cluster`] — a full Matchmaker MultiPaxos deployment in the
//!   simulator: `f+1` proposers (all running [`Leader`]), a pool of
//!   `2·(2f+1)` acceptors, a pool of `2·(2f+1)` matchmakers (first `2f+1`
//!   active), `2f+1` replicas, and N closed-loop clients.
//! * [`HorizontalCluster`] — the baseline deployment (no matchmakers).
//! * [`experiments`] — one driver per paper table/figure (see DESIGN.md's
//!   per-experiment index).

pub mod experiments;
pub mod report;

use crate::config::{ClusterLayout, Configuration, OptFlags};
use crate::metrics::{merge_samples, Sample};
use crate::node::Announce;
use crate::roles::{Acceptor, Client, HorizontalLeader, Leader, Matchmaker, Replica};
use crate::round::Round;
use crate::sim::{NetworkModel, Sim};
use crate::statemachine::Noop;
use crate::util::Rng;
use crate::{NodeId, Time, MS, SEC};

/// A simulated Matchmaker MultiPaxos cluster.
pub struct Cluster {
    pub layout: ClusterLayout,
    pub sim: Sim,
    pub opts: OptFlags,
    pub f: usize,
    rng: Rng,
}

impl Cluster {
    /// Build and start a cluster: the first proposer becomes leader, the
    /// first `2f+1` acceptors form the initial configuration, clients start
    /// issuing immediately.
    pub fn new(f: usize, n_clients: usize, opts: OptFlags, seed: u64, net: NetworkModel) -> Cluster {
        let layout = ClusterLayout::standard(f, 2, n_clients);
        layout.validate().expect("valid layout");
        let mut sim = Sim::new(seed, net);
        let initial_cfg = layout.initial_config();
        let active_mms = layout.initial_matchmakers();

        // Acceptors: the whole pool is alive; only configured ones get
        // traffic.
        for &a in &layout.acceptor_pool {
            sim.add_node(a, Box::new(Acceptor::new(a)));
        }
        // Matchmakers: first 2f+1 active, rest standby (§6 pool).
        for (i, &m) in layout.matchmaker_pool.iter().enumerate() {
            if i < active_mms.len() {
                sim.add_node(m, Box::new(Matchmaker::new(m)));
            } else {
                sim.add_node(m, Box::new(Matchmaker::new_standby(m)));
            }
        }
        // Replicas (paper §5.3 deploys 2f+1).
        for &r in &layout.replicas {
            sim.add_node(r, Box::new(Replica::new(r, Box::new(Noop))));
        }
        // Proposers: all run the Leader role; proposers[0] self-elects at
        // start (see Leader::on_start).
        for &p in &layout.proposers {
            let leader = Leader::new(
                p,
                f,
                initial_cfg.clone(),
                active_mms.clone(),
                layout.replicas.clone(),
                layout.proposers.clone(),
                opts,
                seed,
            );
            sim.add_node(p, Box::new(leader));
        }
        // Clients.
        for &c in &layout.clients {
            sim.add_node(c, Box::new(Client::new(c, layout.proposers.clone())));
        }
        Cluster { layout, sim, opts, f, rng: Rng::new(seed ^ 0xc1a5) }
    }

    /// Convenience: default LAN network.
    pub fn lan(f: usize, n_clients: usize, opts: OptFlags, seed: u64) -> Cluster {
        Cluster::new(f, n_clients, opts, seed, NetworkModel::default())
    }

    pub fn initial_leader(&self) -> NodeId {
        self.layout.proposers[0]
    }

    /// Draw a random configuration of `2f+1` acceptors from the pool
    /// (the §8.1 reconfiguration workload), with a fresh config id.
    pub fn random_config(&mut self, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.layout.acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    /// Draw a random matchmaker set of `2f+1` from the pool (§8.4).
    pub fn random_matchmakers(&mut self) -> Vec<NodeId> {
        self.rng.sample(&self.layout.matchmaker_pool, 2 * self.f + 1)
    }

    /// Harvest all client samples, merged and sorted by completion time.
    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.layout.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<Client>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }

    /// Reconfiguration → active latencies (MatchA issue → ConfigActive),
    /// and → retired latencies (→ ConfigRetired), in ms, keyed by the
    /// issue times passed in.
    pub fn reconfig_latencies(&self, issue_times: &[(Time, Round)]) -> Vec<(f64, Option<f64>)> {
        let mut out = Vec::new();
        for &(t0, round) in issue_times {
            let active = self.sim.announces.iter().find_map(|(t, _, a)| match a {
                Announce::ConfigActive { round: r, .. } if *r == round => Some(*t),
                _ => None,
            });
            let retired = self.sim.announces.iter().find_map(|(t, _, a)| match a {
                Announce::ConfigRetired { round: r } if *r == round => Some(*t),
                _ => None,
            });
            if let Some(ta) = active {
                out.push((
                    (ta.saturating_sub(t0)) as f64 / 1e6,
                    retired.map(|tr| (tr.saturating_sub(t0)) as f64 / 1e6),
                ));
            }
        }
        out
    }

    /// Assert the global safety invariant (used by tests after every
    /// experiment): at most one value chosen per slot.
    pub fn assert_safe(&self) {
        self.sim.check_chosen_safety().expect("chosen-safety invariant");
    }
}

/// A simulated Horizontal MultiPaxos cluster (baseline, §7.2).
pub struct HorizontalCluster {
    pub sim: Sim,
    pub leader: NodeId,
    pub acceptor_pool: Vec<NodeId>,
    pub replicas: Vec<NodeId>,
    pub clients: Vec<NodeId>,
    pub f: usize,
    rng: Rng,
}

impl HorizontalCluster {
    pub fn new(f: usize, n_clients: usize, alpha: u64, seed: u64, net: NetworkModel) -> HorizontalCluster {
        let mut sim = Sim::new(seed, net);
        let leader: NodeId = 0;
        let acceptor_pool: Vec<NodeId> =
            (1..=(2 * (2 * f + 1)) as NodeId).collect();
        let replicas: Vec<NodeId> = (acceptor_pool.last().unwrap() + 1
            ..acceptor_pool.last().unwrap() + 1 + (2 * f + 1) as NodeId)
            .collect();
        let clients: Vec<NodeId> = (replicas.last().unwrap() + 1
            ..replicas.last().unwrap() + 1 + n_clients as NodeId)
            .collect();
        for &a in &acceptor_pool {
            sim.add_node(a, Box::new(Acceptor::new(a)));
        }
        for &r in &replicas {
            sim.add_node(r, Box::new(Replica::new(r, Box::new(Noop))));
        }
        let initial = Configuration::majority(0, acceptor_pool[..2 * f + 1].to_vec());
        sim.add_node(
            leader,
            Box::new(HorizontalLeader::new(leader, initial, replicas.clone(), alpha, seed)),
        );
        for &c in &clients {
            sim.add_node(c, Box::new(Client::new(c, vec![leader])));
        }
        HorizontalCluster { sim, leader, acceptor_pool, replicas, clients, f, rng: Rng::new(seed ^ 0x70f) }
    }

    pub fn random_config(&mut self, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<Client>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }
}

/// Seconds helper for experiment scripts.
pub fn secs(x: u64) -> Time {
    x * SEC
}

/// Milliseconds helper.
pub fn msec(x: u64) -> Time {
    x * MS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_serves_commands() {
        let mut c = Cluster::lan(1, 4, OptFlags::default(), 42);
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100, "got {} samples", samples.len());
        c.assert_safe();
    }

    #[test]
    fn cluster_reconfigures_without_loss() {
        let mut c = Cluster::lan(1, 4, OptFlags::default(), 42);
        let leader = c.initial_leader();
        let cfg = c.random_config(1);
        c.sim.schedule(msec(500), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100);
        c.assert_safe();
        // Reconfiguration happened.
        let leader_node = c.sim.node_mut::<Leader>(leader).unwrap();
        assert!(leader_node.reconfigs_completed >= 2); // startup + ours
        assert!(leader_node.gc_completed >= 1);
    }

    #[test]
    fn horizontal_cluster_serves() {
        let mut c = HorizontalCluster::new(1, 4, 8, 42, NetworkModel::default());
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100);
        c.sim.check_chosen_safety().unwrap();
    }

    #[test]
    fn deterministic_same_seed() {
        let run = |seed| {
            let mut c = Cluster::lan(1, 2, OptFlags::default(), seed);
            c.sim.run_until(msec(500));
            c.samples().len()
        };
        assert_eq!(run(7), run(7));
    }
}
