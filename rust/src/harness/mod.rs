//! Experiment harness: builds simulated clusters shaped like the paper's
//! deployments and runs the §8 experiment scripts.
//!
//! * [`Cluster`] — a full Matchmaker MultiPaxos deployment in the
//!   simulator: `f+1` proposers (all running [`Leader`]), a pool of
//!   `2·(2f+1)` acceptors, a pool of `2·(2f+1)` matchmakers (first `2f+1`
//!   active), `2f+1` replicas, and N workload clients.
//! * [`HorizontalCluster`] — the baseline deployment (no matchmakers).
//! * [`experiments`] — one driver per paper table/figure (see DESIGN.md's
//!   per-experiment index).
//!
//! Clusters are built with a builder — every knob has a paper-faithful
//! default, and the workload is a first-class [`WorkloadSpec`] instead of
//! per-client field poking:
//!
//! ```
//! use matchmaker::harness::{secs, Cluster};
//! use matchmaker::sim::NetworkModel;
//! use matchmaker::workload::WorkloadSpec;
//!
//! let mut cluster = Cluster::builder()
//!     .f(1)
//!     .clients(4)
//!     .workload(WorkloadSpec::open_loop(500.0).max_in_flight(16))
//!     .net(NetworkModel::lan())
//!     .seed(7)
//!     .build();
//! cluster.sim.run_until(secs(1));
//! cluster.assert_safe();
//! ```

pub mod crash;
pub mod experiments;
pub mod report;

use crate::config::{ClusterLayout, Configuration, GroupLayout, OptFlags};
use crate::metrics::{group_load_summary, merge_samples, GroupLoadSummary, RetentionSummary, Sample};
use crate::node::Announce;
use crate::roles::{
    Acceptor, Client, HorizontalLeader, Leader, Matchmaker, Replica, ShardClient,
};
use crate::round::Round;
use crate::sim::{NetworkModel, Sim};
use crate::statemachine::Noop;
use crate::util::Rng;
use crate::workload::WorkloadSpec;
use crate::{GroupId, NodeId, Time, MS, SEC};

/// A simulated Matchmaker MultiPaxos cluster.
pub struct Cluster {
    pub layout: ClusterLayout,
    pub sim: Sim,
    pub opts: OptFlags,
    pub f: usize,
    /// The workload every client runs (see [`WorkloadSpec`]).
    pub workload: WorkloadSpec,
    rng: Rng,
}

/// Builder for [`Cluster`]. Every knob defaults to the paper's §8.1
/// deployment: `f = 1`, 4 closed-loop clients, all optimizations on,
/// LAN network, seed 42.
#[derive(Clone, Debug)]
pub struct ClusterBuilder {
    f: usize,
    clients: usize,
    workload: WorkloadSpec,
    opts: OptFlags,
    seed: u64,
    net: NetworkModel,
    pool_factor: usize,
    route_reads: bool,
}

impl Default for ClusterBuilder {
    fn default() -> Self {
        ClusterBuilder {
            f: 1,
            clients: 4,
            workload: WorkloadSpec::closed_loop(),
            opts: OptFlags::default(),
            seed: 42,
            net: NetworkModel::lan(),
            pool_factor: 2,
            route_reads: true,
        }
    }
}

impl ClusterBuilder {
    /// Fault-tolerance parameter (proposers = f+1, initial quorums of
    /// 2f+1 from a pool of `pool_factor·(2f+1)`).
    pub fn f(mut self, f: usize) -> Self {
        self.f = f;
        self
    }

    /// Number of workload clients.
    pub fn clients(mut self, n: usize) -> Self {
        self.clients = n;
        self
    }

    /// The workload every client runs (default:
    /// [`WorkloadSpec::closed_loop`], the paper's §8.1 client).
    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    /// Protocol optimization flags.
    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    /// Simulation seed (identical seeds give bit-identical runs).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Network model (default [`NetworkModel::lan`]).
    pub fn net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Acceptor/matchmaker pool size factor (default 2: a pool of
    /// `2·(2f+1)`, the §8.1 reconfiguration-experiment shape).
    pub fn pool_factor(mut self, k: usize) -> Self {
        self.pool_factor = k.max(1);
        self
    }

    /// Whether clients know the replica set and send read-classified
    /// requests there (default true). `route_reads(false)` is the
    /// all-through-Phase-2 baseline: reads ride the log like writes —
    /// the X7 comparison point.
    pub fn route_reads(mut self, on: bool) -> Self {
        self.route_reads = on;
        self
    }

    /// Build and start the cluster: the first proposer becomes leader,
    /// the first `2f+1` acceptors form the initial configuration, and
    /// clients start their workloads.
    pub fn build(self) -> Cluster {
        let ClusterBuilder { f, clients, workload, opts, seed, net, pool_factor, route_reads } =
            self;
        let layout = ClusterLayout::standard(f, pool_factor, clients);
        layout.validate().expect("valid layout");
        let mut sim = Sim::new(seed, net);
        let initial_cfg = layout.initial_config();
        let active_mms = layout.initial_matchmakers();

        // Acceptors: the whole pool is alive; only configured ones get
        // traffic.
        for &a in &layout.acceptor_pool {
            sim.add_node(a, Box::new(Acceptor::new(a)));
        }
        // Matchmakers: first 2f+1 active, rest standby (§6 pool).
        for (i, &m) in layout.matchmaker_pool.iter().enumerate() {
            if i < active_mms.len() {
                sim.add_node(m, Box::new(Matchmaker::new(m)));
            } else {
                sim.add_node(m, Box::new(Matchmaker::new_standby(m)));
            }
        }
        // Replicas (paper §5.3 deploys 2f+1), with the snapshot policy
        // and peer list for snapshot catch-up.
        for &r in &layout.replicas {
            let mut rep = Replica::new(r, Box::new(Noop));
            rep.snapshot = opts.snapshot;
            rep.peers = layout.replicas.clone();
            rep.proposers = layout.proposers.clone();
            sim.add_node(r, Box::new(rep));
        }
        // Proposers: all run the Leader role; proposers[0] self-elects at
        // start (see Leader::on_start).
        for &p in &layout.proposers {
            let leader = Leader::new(
                p,
                f,
                initial_cfg.clone(),
                active_mms.clone(),
                layout.replicas.clone(),
                layout.proposers.clone(),
                opts,
                seed,
            );
            sim.add_node(p, Box::new(leader));
        }
        // Clients, each driven by the shared workload spec. With
        // `route_reads` (the default) they know the replica set, so
        // read-classified requests take the replica read path.
        for &c in &layout.clients {
            let mut cl = Client::new(c, layout.proposers.clone(), workload.clone());
            if route_reads {
                cl.replicas = layout.replicas.clone();
            }
            cl.shed_on_busy = opts.admission.enabled && opts.admission.shed;
            sim.add_node(c, Box::new(cl));
        }
        Cluster { layout, sim, opts, f, workload, rng: Rng::new(seed ^ 0xc1a5) }
    }
}

impl Cluster {
    /// Start describing a cluster (see [`ClusterBuilder`]).
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    pub fn initial_leader(&self) -> NodeId {
        self.layout.proposers[0]
    }

    /// Draw a random configuration of `2f+1` acceptors from the pool
    /// (the §8.1 reconfiguration workload), with a fresh config id.
    pub fn random_config(&mut self, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.layout.acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    /// Draw a random matchmaker set of `2f+1` from the pool (§8.4).
    pub fn random_matchmakers(&mut self) -> Vec<NodeId> {
        self.rng.sample(&self.layout.matchmaker_pool, 2 * self.f + 1)
    }

    /// Harvest all client samples, merged and sorted by completion time.
    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.layout.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<Client>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }

    /// Sum the clients' workload counters: `(offered, completed,
    /// abandoned)`. For open-loop workloads `offered` counts arrivals
    /// whether or not they completed — the offered-load experiments
    /// compare it against the completion rate.
    pub fn workload_totals(&mut self) -> (u64, u64, u64) {
        let clients = self.layout.clients.clone();
        let (mut offered, mut completed, mut abandoned) = (0u64, 0u64, 0u64);
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<Client>(c) {
                offered += cl.offered;
                completed += cl.completed;
                abandoned += cl.abandoned;
            }
        }
        (offered, completed, abandoned)
    }

    /// Reconfiguration → active latencies (MatchA issue → ConfigActive),
    /// and → retired latencies (→ ConfigRetired), in ms, keyed by the
    /// issue times passed in.
    pub fn reconfig_latencies(&self, issue_times: &[(Time, Round)]) -> Vec<(f64, Option<f64>)> {
        let mut out = Vec::new();
        for &(t0, round) in issue_times {
            let active = self.sim.announces.iter().find_map(|(t, _, a)| match a {
                Announce::ConfigActive { round: r, .. } if *r == round => Some(*t),
                _ => None,
            });
            let retired = self.sim.announces.iter().find_map(|(t, _, a)| match a {
                Announce::ConfigRetired { round: r, .. } if *r == round => Some(*t),
                _ => None,
            });
            if let Some(ta) = active {
                out.push((
                    (ta.saturating_sub(t0)) as f64 / 1e6,
                    retired.map(|tr| (tr.saturating_sub(t0)) as f64 / 1e6),
                ));
            }
        }
        out
    }

    /// Total reads completed across all clients (replica-served and
    /// through-the-log baseline reads both count).
    pub fn reads_completed(&mut self) -> u64 {
        let clients = self.layout.clients.clone();
        let mut total = 0u64;
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<Client>(c) {
                total += cl.reads_completed;
            }
        }
        total
    }

    /// Harvest every client's completed-read records `(issued_at,
    /// completed_at, result)`, merged — the linearizable-read checker's
    /// input ([`crate::metrics::check_counter_reads`]). Copies (like
    /// [`Cluster::write_records`]) so repeated harvests agree — a
    /// drained second harvest would make the stale-read check pass
    /// vacuously.
    pub fn read_records(&mut self) -> Vec<crate::metrics::ReadSample> {
        let clients = self.layout.clients.clone();
        let mut all = Vec::new();
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<Client>(c) {
                all.extend(cl.reads.iter().cloned());
            }
        }
        all
    }

    /// Harvest the global write history: `(completion times of
    /// acknowledged writes, issue times of all writes ever sent)`.
    pub fn write_records(&mut self) -> (Vec<Time>, Vec<Time>) {
        let clients = self.layout.clients.clone();
        let (mut completions, mut issues) = (Vec::new(), Vec::new());
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<Client>(c) {
                completions.extend(cl.writes.iter().map(|(_, done)| *done));
                issues.extend(cl.write_issues.iter().copied());
            }
        }
        (completions, issues)
    }

    /// Per-replica read-path counters: `(replica, reads served from a
    /// lease grant, reads served via ReadIndex)`.
    pub fn read_path_stats(&mut self) -> Vec<(NodeId, u64, u64)> {
        let replicas = self.layout.replicas.clone();
        let mut out = Vec::with_capacity(replicas.len());
        for r in replicas {
            if let Some(rep) = self.sim.node_mut::<Replica>(r) {
                out.push((r, rep.reads_leased, rep.reads_indexed));
            }
        }
        out
    }

    /// Leader-side overload signals for the (single) group: inbox
    /// depth, Busy pushback counters, windowed p99 — see
    /// [`GroupLoadSummary`]. `busy_rejections` sums over all proposers
    /// (a deposed leader's rejections still happened); depth/p99 come
    /// from the current leader.
    pub fn group_load(&mut self) -> GroupLoadSummary {
        let admitted = chosen_commands(&self.sim.announces, 0);
        let proposers = self.layout.proposers.clone();
        leader_load(&mut self.sim, 0, &proposers, admitted)
    }

    /// Total [`crate::msg::Msg::Busy`] pushbacks the clients saw.
    pub fn busy_observed(&mut self) -> u64 {
        let clients = self.layout.clients.clone();
        let mut total = 0u64;
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<Client>(c) {
                total += cl.busy_observed;
            }
        }
        total
    }

    /// Assert the protocol safety catalog (used by tests after every
    /// experiment): the same machine-checked invariants the model
    /// checker explores ([`crate::check::InvariantSet`], standard /
    /// lenient tier — harness runs may include crashes and drops), fed
    /// the full announcement history of the run.
    pub fn assert_safe(&self) {
        if let Err(v) = crate::check::InvariantSet::check_all(&self.sim.announces) {
            panic!("safety invariant violated: {v}");
        }
    }

    /// Harvest per-replica state-retention counters (log lengths,
    /// snapshot counts, digests) — the X5 experiment's raw material.
    pub fn retention_stats(&mut self) -> Vec<RetentionSummary> {
        let replicas = self.layout.replicas.clone();
        let mut out = Vec::with_capacity(replicas.len());
        for r in replicas {
            if let Some(rep) = self.sim.node_mut::<Replica>(r) {
                out.push(RetentionSummary {
                    replica: r,
                    exec_watermark: rep.exec_watermark,
                    truncated_below: rep.truncated_below,
                    log_len: rep.log_len(),
                    max_log_len: rep.max_log_len,
                    snapshots_taken: rep.snapshots_taken,
                    snapshots_installed: rep.snapshots_installed,
                    digest: rep.sm.digest(),
                });
            }
        }
        out
    }
}

/// A sharded multi-group Matchmaker MultiPaxos deployment in the
/// simulator: N independent consensus groups — each with its own leader
/// (`f+1` proposers), acceptor pool, and `2f+1` replicas — sharing **one
/// matchmaker set** (§6: a single matchmaker set serves many protocol
/// instances). Clients are [`ShardClient`]s that hash every key to its
/// home group, so the deployment scales command throughput with the
/// group count while reconfigurations of any group flow through the
/// same shared matchmakers (whose log is keyed `(group, round)` with
/// per-group GC).
pub struct ShardedCluster {
    pub sim: Sim,
    pub f: usize,
    pub opts: OptFlags,
    /// The workload every client runs (in-flight/rate bounds are per
    /// client, spread across groups by key hash).
    pub workload: WorkloadSpec,
    /// The shared matchmaker pool (first `2f+1` active).
    pub matchmaker_pool: Vec<NodeId>,
    /// Per-group role slices, indexed by [`GroupId`].
    pub groups: Vec<GroupLayout>,
    /// Shard-routing client ids.
    pub clients: Vec<NodeId>,
    rng: Rng,
}

/// Builder for [`ShardedCluster`]; the single-group defaults mirror
/// [`ClusterBuilder`], with `shards(n)` multiplying the per-group roles.
#[derive(Clone, Debug)]
pub struct ShardedClusterBuilder {
    shards: usize,
    f: usize,
    clients: usize,
    workload: WorkloadSpec,
    opts: OptFlags,
    seed: u64,
    net: NetworkModel,
    pool_factor: usize,
    route_reads: bool,
}

impl Default for ShardedClusterBuilder {
    fn default() -> Self {
        ShardedClusterBuilder {
            shards: 1,
            f: 1,
            clients: 4,
            workload: WorkloadSpec::closed_loop(),
            opts: OptFlags::default(),
            seed: 42,
            net: NetworkModel::lan(),
            pool_factor: 2,
            route_reads: true,
        }
    }
}

impl ShardedClusterBuilder {
    /// Number of independent consensus groups (≥ 1).
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self
    }

    /// Fault-tolerance parameter (per group).
    pub fn f(mut self, f: usize) -> Self {
        self.f = f;
        self
    }

    /// Number of shard-routing workload clients.
    pub fn clients(mut self, n: usize) -> Self {
        self.clients = n;
        self
    }

    /// The workload every client runs.
    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    /// Protocol optimization flags (applied to every group's leader).
    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    /// Simulation seed (identical seeds give bit-identical runs).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Network model (default [`NetworkModel::lan`]).
    pub fn net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Per-group acceptor-pool size factor (default 2).
    pub fn pool_factor(mut self, k: usize) -> Self {
        self.pool_factor = k.max(1);
        self
    }

    /// Whether shard clients route read-classified requests to their
    /// key's home-group replicas (default true); `false` is the
    /// all-through-Phase-2 baseline.
    pub fn route_reads(mut self, on: bool) -> Self {
        self.route_reads = on;
        self
    }

    /// Build and start the cluster: one shared matchmaker pool, then per
    /// group its proposers/acceptors/replicas, then the clients. Every
    /// group's first proposer self-elects at start.
    pub fn build(self) -> ShardedCluster {
        let ShardedClusterBuilder {
            shards,
            f,
            clients,
            workload,
            opts,
            seed,
            net,
            pool_factor,
            route_reads,
        } = self;
        let mut sim = Sim::new(seed, net);
        let mut next: NodeId = 0;
        let mut take = |n: usize| -> Vec<NodeId> {
            let ids: Vec<NodeId> = (next..next + n as NodeId).collect();
            next += n as NodeId;
            ids
        };
        let matchmaker_pool = take(pool_factor * (2 * f + 1));
        let groups: Vec<GroupLayout> = (0..shards)
            .map(|_| GroupLayout {
                proposers: take(f + 1),
                acceptor_pool: take(pool_factor * (2 * f + 1)),
                replicas: take(2 * f + 1),
            })
            .collect();
        let client_ids = take(clients);
        let active_mms = matchmaker_pool[..2 * f + 1].to_vec();

        // Shared matchmakers: first 2f+1 active, rest standby (§6 pool).
        for (i, &m) in matchmaker_pool.iter().enumerate() {
            if i < active_mms.len() {
                sim.add_node(m, Box::new(Matchmaker::new(m)));
            } else {
                sim.add_node(m, Box::new(Matchmaker::new_standby(m)));
            }
        }
        for (g, layout) in groups.iter().enumerate() {
            let g = g as GroupId;
            for &a in &layout.acceptor_pool {
                sim.add_node(a, Box::new(Acceptor::new(a)));
            }
            for &r in &layout.replicas {
                let mut rep = Replica::new(r, Box::new(Noop));
                rep.group = g;
                rep.snapshot = opts.snapshot;
                rep.peers = layout.replicas.clone();
                rep.proposers = layout.proposers.clone();
                sim.add_node(r, Box::new(rep));
            }
            let initial_cfg =
                Configuration::majority(0, layout.acceptor_pool[..2 * f + 1].to_vec());
            for &p in &layout.proposers {
                let mut leader = Leader::new(
                    p,
                    f,
                    initial_cfg.clone(),
                    active_mms.clone(),
                    layout.replicas.clone(),
                    layout.proposers.clone(),
                    opts,
                    seed,
                );
                leader.group = g;
                sim.add_node(p, Box::new(leader));
            }
        }
        let proposer_lists: Vec<Vec<NodeId>> =
            groups.iter().map(|gl| gl.proposers.clone()).collect();
        let replica_lists: Vec<Vec<NodeId>> =
            groups.iter().map(|gl| gl.replicas.clone()).collect();
        for &c in &client_ids {
            let mut cl = ShardClient::new(c, proposer_lists.clone(), workload.clone());
            if route_reads {
                cl.replicas_per_group(replica_lists.clone());
            }
            cl.shed_on_busy = opts.admission.enabled && opts.admission.shed;
            sim.add_node(c, Box::new(cl));
        }
        ShardedCluster {
            sim,
            f,
            opts,
            workload,
            matchmaker_pool,
            groups,
            clients: client_ids,
            rng: Rng::new(seed ^ 0x5aa2d),
        }
    }
}

impl ShardedCluster {
    /// Start describing a sharded cluster (see [`ShardedClusterBuilder`]).
    pub fn builder() -> ShardedClusterBuilder {
        ShardedClusterBuilder::default()
    }

    /// Number of consensus groups.
    pub fn shards(&self) -> usize {
        self.groups.len()
    }

    /// The initially active matchmakers (first `2f+1` of the pool).
    pub fn active_matchmakers(&self) -> Vec<NodeId> {
        self.matchmaker_pool[..2 * self.f + 1].to_vec()
    }

    /// Group `g`'s initial (self-elected) leader.
    pub fn group_leader(&self, g: usize) -> NodeId {
        self.groups[g].proposers[0]
    }

    /// Draw a random configuration of `2f+1` acceptors from group `g`'s
    /// pool, with a fresh config id.
    pub fn random_config(&mut self, g: usize, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.groups[g].acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    /// Harvest all client samples, merged and sorted by completion time.
    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<ShardClient>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }

    /// Sum the clients' workload counters: `(offered, completed,
    /// abandoned)`.
    pub fn workload_totals(&mut self) -> (u64, u64, u64) {
        let clients = self.clients.clone();
        let (mut offered, mut completed, mut abandoned) = (0u64, 0u64, 0u64);
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<ShardClient>(c) {
                offered += cl.offered;
                completed += cl.completed;
                abandoned += cl.abandoned;
            }
        }
        (offered, completed, abandoned)
    }

    /// Chosen-command completion times for one group, from the announce
    /// stream: one entry per client command (batches flattened),
    /// deduplicated by slot. The per-group throughput series the X6
    /// experiment windows over.
    pub fn group_chosen_times(&self, g: GroupId) -> Vec<Time> {
        let mut seen_slots = std::collections::BTreeSet::new();
        let mut out = Vec::new();
        for (t, _, a) in &self.sim.announces {
            if let Announce::Chosen { group, slot, value, .. } = a {
                if *group != g || !seen_slots.insert(*slot) {
                    continue;
                }
                let n = match value {
                    crate::msg::Value::Cmd(_) => 1,
                    crate::msg::Value::Batch(cmds) => cmds.len(),
                    _ => 0,
                };
                out.extend(std::iter::repeat(*t).take(n));
            }
        }
        out
    }

    /// Retained matchmaker-log sizes `(matchmaker, total entries across
    /// groups)` for the active set — the shared-matchmaker memory bound.
    pub fn matchmaker_log_lens(&mut self) -> Vec<(NodeId, usize)> {
        let mms = self.active_matchmakers();
        mms.into_iter()
            .filter_map(|m| {
                self.sim.node_mut::<Matchmaker>(m).map(|mm| (m, mm.total_log_len()))
            })
            .collect()
    }

    /// Total reads completed across all shard clients.
    pub fn reads_completed(&mut self) -> u64 {
        let clients = self.clients.clone();
        let mut total = 0u64;
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<ShardClient>(c) {
                total += cl.reads_completed;
            }
        }
        total
    }

    /// Harvest every shard client's completed-read records, merged.
    /// Copies (like [`ShardedCluster::write_records`]) so repeated
    /// harvests agree.
    pub fn read_records(&mut self) -> Vec<crate::metrics::ReadSample> {
        let clients = self.clients.clone();
        let mut all = Vec::new();
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<ShardClient>(c) {
                all.extend(cl.reads.iter().cloned());
            }
        }
        all
    }

    /// Harvest the global write history across all shard clients:
    /// `(completions, issues)` — see [`Cluster::write_records`].
    pub fn write_records(&mut self) -> (Vec<Time>, Vec<Time>) {
        let clients = self.clients.clone();
        let (mut completions, mut issues) = (Vec::new(), Vec::new());
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<ShardClient>(c) {
                completions.extend(cl.writes.iter().map(|(_, done)| *done));
                issues.extend(cl.write_issues.iter().copied());
            }
        }
        (completions, issues)
    }

    /// Per-group leader-side overload signals — one
    /// [`GroupLoadSummary`] per group, the X9 experiment's hot-group
    /// map. Shard clients steer around hot groups with the same signal
    /// delivered in-band (`Msg::Busy` marks a lane hot); this is the
    /// out-of-band view for reports and operators.
    pub fn group_load(&mut self) -> Vec<GroupLoadSummary> {
        let shards = self.shards();
        let mut out = Vec::with_capacity(shards);
        for g in 0..shards {
            let g = g as GroupId;
            let admitted = chosen_commands(&self.sim.announces, g);
            let proposers = self.groups[g as usize].proposers.clone();
            out.push(leader_load(&mut self.sim, g, &proposers, admitted));
        }
        out
    }

    /// Total [`crate::msg::Msg::Busy`] pushbacks the shard clients saw.
    pub fn busy_observed(&mut self) -> u64 {
        let clients = self.clients.clone();
        let mut total = 0u64;
        for c in clients {
            if let Some(cl) = self.sim.node_mut::<ShardClient>(c) {
                total += cl.busy_observed;
            }
        }
        total
    }

    /// Assert the protocol safety catalog per group — the model
    /// checker's standard [`crate::check::InvariantSet`] over the whole
    /// sharded run's announcement history (announces carry `GroupId`, so
    /// one catalog checks every group independently).
    pub fn assert_safe(&self) {
        if let Err(v) = crate::check::InvariantSet::check_all(&self.sim.announces) {
            panic!("safety invariant violated: {v}");
        }
    }
}

/// A simulated Horizontal MultiPaxos cluster (baseline, §7.2).
pub struct HorizontalCluster {
    pub sim: Sim,
    pub leader: NodeId,
    pub acceptor_pool: Vec<NodeId>,
    pub replicas: Vec<NodeId>,
    pub clients: Vec<NodeId>,
    pub f: usize,
    rng: Rng,
}

/// Builder for [`HorizontalCluster`]; defaults mirror [`ClusterBuilder`]
/// plus the α window (`alpha = 8`, the §8.1 baseline setting).
#[derive(Clone, Debug)]
pub struct HorizontalClusterBuilder {
    f: usize,
    clients: usize,
    alpha: u64,
    workload: WorkloadSpec,
    seed: u64,
    net: NetworkModel,
}

impl Default for HorizontalClusterBuilder {
    fn default() -> Self {
        HorizontalClusterBuilder {
            f: 1,
            clients: 4,
            alpha: 8,
            workload: WorkloadSpec::closed_loop(),
            seed: 42,
            net: NetworkModel::lan(),
        }
    }
}

impl HorizontalClusterBuilder {
    pub fn f(mut self, f: usize) -> Self {
        self.f = f;
        self
    }

    pub fn clients(mut self, n: usize) -> Self {
        self.clients = n;
        self
    }

    /// The α concurrency window (§7.2): slot `s` may only be proposed
    /// once slot `s - α` is chosen.
    pub fn alpha(mut self, alpha: u64) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    pub fn build(self) -> HorizontalCluster {
        let HorizontalClusterBuilder { f, clients: n_clients, alpha, workload, seed, net } = self;
        let mut sim = Sim::new(seed, net);
        let leader: NodeId = 0;
        let acceptor_pool: Vec<NodeId> =
            (1..=(2 * (2 * f + 1)) as NodeId).collect();
        let replicas: Vec<NodeId> = (acceptor_pool.last().unwrap() + 1
            ..acceptor_pool.last().unwrap() + 1 + (2 * f + 1) as NodeId)
            .collect();
        let clients: Vec<NodeId> = (replicas.last().unwrap() + 1
            ..replicas.last().unwrap() + 1 + n_clients as NodeId)
            .collect();
        for &a in &acceptor_pool {
            sim.add_node(a, Box::new(Acceptor::new(a)));
        }
        for &r in &replicas {
            sim.add_node(r, Box::new(Replica::new(r, Box::new(Noop))));
        }
        let initial = Configuration::majority(0, acceptor_pool[..2 * f + 1].to_vec());
        sim.add_node(
            leader,
            Box::new(HorizontalLeader::new(leader, initial, replicas.clone(), alpha, seed)),
        );
        for &c in &clients {
            sim.add_node(c, Box::new(Client::new(c, vec![leader], workload.clone())));
        }
        HorizontalCluster { sim, leader, acceptor_pool, replicas, clients, f, rng: Rng::new(seed ^ 0x70f) }
    }
}

impl HorizontalCluster {
    /// Start describing a horizontal-baseline cluster.
    pub fn builder() -> HorizontalClusterBuilder {
        HorizontalClusterBuilder::default()
    }

    pub fn random_config(&mut self, id: u64) -> Configuration {
        let acceptors = self.rng.sample(&self.acceptor_pool, 2 * self.f + 1);
        Configuration::majority(id, acceptors)
    }

    pub fn samples(&mut self) -> Vec<Sample> {
        let clients = self.clients.clone();
        let mut per_client = Vec::with_capacity(clients.len());
        for c in clients {
            let samples = self
                .sim
                .node_mut::<Client>(c)
                .map(|cl| std::mem::take(&mut cl.samples))
                .unwrap_or_default();
            per_client.push(samples);
        }
        merge_samples(per_client)
    }
}

/// Chosen client commands for one group (batches flattened, slots
/// deduplicated across leader retries) from an announce history — the
/// "admitted" denominator of [`GroupLoadSummary::busy_rate`].
fn chosen_commands(announces: &[(Time, NodeId, Announce)], g: GroupId) -> u64 {
    let mut seen_slots = std::collections::BTreeSet::new();
    let mut n = 0u64;
    for (_, _, a) in announces {
        if let Announce::Chosen { group, slot, value, .. } = a {
            if *group != g || !seen_slots.insert(*slot) {
                continue;
            }
            n += match value {
                crate::msg::Value::Cmd(_) => 1,
                crate::msg::Value::Batch(cmds) => cmds.len() as u64,
                _ => 0,
            };
        }
    }
    n
}

/// Harvest one group's leader-side load counters: `busy_rejections`
/// sums over every proposer (a deposed leader's pushbacks still
/// happened); inbox depth and windowed p99 come from the proposer that
/// currently leads (falling back to the first if none claims it).
fn leader_load(sim: &mut Sim, g: GroupId, proposers: &[NodeId], admitted: u64) -> GroupLoadSummary {
    let mut rejections = 0u64;
    let mut lead: Option<(usize, Time)> = None;
    for &p in proposers {
        if let Some(l) = sim.node_mut::<Leader>(p) {
            rejections += l.busy_rejections;
            if l.is_leader || lead.is_none() {
                lead = Some((l.inbox_depth(), l.windowed_p99()));
            }
        }
    }
    let (inbox, p99) = lead.unwrap_or((0, 0));
    group_load_summary(g, inbox, rejections, admitted, p99)
}

/// Seconds helper for experiment scripts.
pub fn secs(x: u64) -> Time {
    x * SEC
}

/// Milliseconds helper.
pub fn msec(x: u64) -> Time {
    x * MS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn cluster_serves_commands() {
        let mut c = Cluster::builder().seed(42).build();
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100, "got {} samples", samples.len());
        c.assert_safe();
    }

    #[test]
    fn cluster_reconfigures_without_loss() {
        let mut c = Cluster::builder().seed(42).build();
        let leader = c.initial_leader();
        let cfg = c.random_config(1);
        c.sim.schedule(msec(500), move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100);
        c.assert_safe();
        // Reconfiguration happened.
        let leader_node = c.sim.node_mut::<Leader>(leader).unwrap();
        assert!(leader_node.reconfigs_completed >= 2); // startup + ours
        assert!(leader_node.gc_completed >= 1);
    }

    #[test]
    fn horizontal_cluster_serves() {
        let mut c = HorizontalCluster::builder().seed(42).build();
        c.sim.run_until(secs(1));
        let samples = c.samples();
        assert!(samples.len() > 100);
        c.sim.check_chosen_safety().unwrap();
    }

    #[test]
    fn deterministic_same_seed() {
        let run = |seed| {
            let mut c = Cluster::builder().clients(2).seed(seed).build();
            c.sim.run_until(msec(500));
            c.samples().len()
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn pipelined_workload_multiplies_throughput() {
        // Same 2 clients, window 8 vs window 1: the pipelined cluster
        // must complete several times as many commands.
        let completed = |spec: WorkloadSpec| {
            let mut c = Cluster::builder().clients(2).workload(spec).seed(9).build();
            c.sim.run_until(secs(1));
            c.assert_safe();
            c.samples().len()
        };
        let closed = completed(WorkloadSpec::closed_loop());
        let piped = completed(WorkloadSpec::pipelined(8));
        assert!(
            piped as f64 >= 3.0 * closed as f64,
            "pipelining gained only {piped} vs {closed}"
        );
    }

    #[test]
    fn sharded_cluster_serves_commands_across_groups() {
        let mut c = ShardedCluster::builder()
            .shards(2)
            .clients(4)
            .workload(WorkloadSpec::pipelined(4))
            .seed(42)
            .build();
        c.sim.run_until(secs(1));
        c.assert_safe();
        let samples = c.samples();
        assert!(samples.len() > 200, "got {} samples", samples.len());
        // Both groups chose commands (keys hash to both).
        for g in 0..2 {
            let chosen = c.group_chosen_times(g).len();
            assert!(chosen > 50, "group {g} chose only {chosen} commands");
        }
    }

    #[test]
    fn sharded_single_group_matches_unsharded_shape() {
        // shards(1) must behave like a plain cluster: same roles, same
        // safety, commands flow.
        let mut c = ShardedCluster::builder().shards(1).clients(2).seed(7).build();
        c.sim.run_until(msec(500));
        c.assert_safe();
        assert!(!c.samples().is_empty());
        assert_eq!(c.shards(), 1);
    }

    #[test]
    fn sharded_group_reconfigures_independently() {
        let mut c = ShardedCluster::builder()
            .shards(2)
            .clients(4)
            .workload(WorkloadSpec::pipelined(4))
            .seed(11)
            .build();
        let leader0 = c.group_leader(0);
        let cfg = c.random_config(0, 1);
        c.sim.schedule(msec(400), move |s| {
            s.with_node::<Leader, _>(leader0, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        c.sim.run_until(secs(1));
        c.assert_safe();
        // Group 0 reconfigured (startup + ours); group 1 only started.
        let l0 = c.sim.node_mut::<Leader>(leader0).unwrap();
        assert!(l0.reconfigs_completed >= 2);
        assert!(l0.gc_completed >= 1);
        let leader1 = c.group_leader(1);
        let l1 = c.sim.node_mut::<Leader>(leader1).unwrap();
        assert_eq!(l1.reconfigs_completed, 1);
        // The shared matchmaker log holds one live entry per group after
        // GC (the retired group-0 round was collected).
        for (m, len) in c.matchmaker_log_lens() {
            assert!(len <= 3, "matchmaker {m} log holds {len} entries");
        }
        // Both groups kept serving.
        for g in 0..2 {
            assert!(!c.group_chosen_times(g).is_empty(), "group {g} starved");
        }
    }

    #[test]
    fn sharded_matchmaker_set_migration_serves_all_groups() {
        // Group 0's leader migrates the shared matchmaker set (§6
        // stop-and-copy carries every group's log); the control plane
        // hands the new set to group 1's leaders; group 1 must then be
        // able to reconfigure its acceptors against the *new* set —
        // i.e. nobody is left matchmaking at the stopped old one.
        let mut c = ShardedCluster::builder()
            .shards(2)
            .clients(4)
            .workload(WorkloadSpec::pipelined(2))
            .seed(13)
            .build();
        let leader0 = c.group_leader(0);
        // Migrate to the standby half of the pool.
        let new_set = c.matchmaker_pool[2 * c.f + 1..].to_vec();
        assert_eq!(new_set.len(), 2 * c.f + 1);
        let set_for_schedule = new_set.clone();
        c.sim.schedule(msec(300), move |s| {
            let mms = set_for_schedule.clone();
            s.with_node::<Leader, _>(leader0, |l, now, fx| {
                l.reconfigure_matchmakers(mms, now, fx)
            });
        });
        // Control plane: propagate the chosen set to group 1's leaders
        // (the §6 meta-Paxos completes in a few LAN round trips).
        let group1 = c.groups[1].proposers.clone();
        let set_for_group1 = new_set.clone();
        c.sim.schedule(msec(600), move |s| {
            for &p in &group1 {
                s.with_node::<Leader, _>(p, |l, _, _| {
                    l.set_matchmakers(set_for_group1.clone())
                });
            }
        });
        // Group 1 now reconfigures its acceptors through the new set.
        let leader1 = c.group_leader(1);
        let cfg = c.random_config(1, 7);
        c.sim.schedule(msec(700), move |s| {
            s.with_node::<Leader, _>(leader1, |l, now, fx| l.reconfigure(cfg.clone(), now, fx));
        });
        c.sim.run_until(secs(2));
        c.assert_safe();
        // The migration completed...
        assert!(c
            .sim
            .announces
            .iter()
            .any(|(_, _, a)| matches!(a, Announce::MatchmakersReconfigured { .. })));
        let l0 = c.sim.node_mut::<Leader>(leader0).unwrap();
        assert_eq!(l0.matchmakers, new_set);
        // ... group 1's reconfiguration went through the new set (its
        // GC ran there too), and both groups kept serving.
        let l1 = c.sim.node_mut::<Leader>(leader1).unwrap();
        assert_eq!(l1.matchmakers, new_set);
        assert!(l1.reconfigs_completed >= 2, "group 1 stuck: {}", l1.reconfigs_completed);
        assert!(l1.gc_completed >= 1);
        for g in 0..2 {
            let late = c
                .group_chosen_times(g)
                .iter()
                .any(|&t| t > msec(1200));
            assert!(late, "group {g} stopped serving after the migration");
        }
    }

    #[test]
    fn sharded_deterministic_same_seed() {
        let run = |seed| {
            let mut c = ShardedCluster::builder().shards(2).clients(2).seed(seed).build();
            c.sim.run_until(msec(400));
            (c.samples().len(), c.sim.delivered)
        };
        assert_eq!(run(9), run(9));
    }

    #[test]
    fn admission_sheds_and_reports_group_load() {
        // A one-slot inbox under 16k/s offered: the leader must push
        // back with Busy, shedding clients must observe and abandon,
        // and group_load must report consistent counters.
        let opts = OptFlags::default()
            .with_admission(crate::config::AdmissionSpec::slo(1, 1_000, true));
        let spec = WorkloadSpec::open_loop(4000.0).max_in_flight(32);
        let mut c = Cluster::builder().clients(4).workload(spec).opts(opts).seed(5).build();
        c.sim.run_until(secs(1));
        c.assert_safe();
        let load = c.group_load();
        assert!(load.busy_rejections > 0, "no pushback at inbox=1 under load");
        assert!(load.busy_rate > 0.0 && load.busy_rate < 1.0, "rate {}", load.busy_rate);
        // Every client-observed Busy was sent by a leader (stale Busys
        // for already-shed seqs are dropped client-side, so ≤).
        let observed = c.busy_observed();
        assert!(observed > 0 && observed <= load.busy_rejections);
        let (_, completed, abandoned) = c.workload_totals();
        assert!(abandoned > 0, "shedding clients must abandon");
        assert!(completed > 0, "admitted traffic still completes");
    }

    #[test]
    fn sharded_group_load_reports_all_groups() {
        let mut c = ShardedCluster::builder()
            .shards(2)
            .clients(2)
            .workload(WorkloadSpec::pipelined(4))
            .seed(17)
            .build();
        c.sim.run_until(msec(500));
        c.assert_safe();
        let load = c.group_load();
        assert_eq!(load.len(), 2);
        for (g, l) in load.iter().enumerate() {
            assert_eq!(l.group as usize, g);
            // Admission is off by default: nothing was rejected and
            // busy_rate stays 0, but chosen traffic registers.
            assert_eq!(l.busy_rejections, 0);
            assert_eq!(l.busy_rate, 0.0);
        }
        assert_eq!(c.busy_observed(), 0);
    }

    #[test]
    fn open_loop_tracks_offered_rate() {
        // 2 clients at 500/s each for 2 s ≈ 2000 arrivals, all completed
        // (the system is far from saturation at this rate).
        let spec = WorkloadSpec::open_loop(500.0).max_in_flight(16);
        let mut c = Cluster::builder().clients(2).workload(spec).seed(3).build();
        c.sim.run_until(secs(2));
        c.assert_safe();
        let (offered, completed, abandoned) = c.workload_totals();
        assert!((1900..=2100).contains(&(offered as usize)), "offered {offered}");
        assert_eq!(abandoned, 0);
        // In-flight tail at cutoff may be unfinished; everything else is.
        assert!(completed + 64 >= offered, "completed {completed} of {offered}");
    }
}
