//! The perf-regression gate: diff a sweep/bench run against committed
//! baselines (`repro sweep --compare <dir>`, DESIGN.md §Sweeps).
//!
//! Semantics: every row of a baseline file is a **pinned
//! configuration**. Both sides are scored over the BENCH-schema fields
//! with the same composite ([`super::score`]), matched by label, and a
//! row whose score fell more than [`TOLERANCE`] below its baseline —
//! or that disappeared — fails the compare. Improvements pass and
//! print their delta. The diagnostic names the offending configuration
//! *and* the axis (throughput / p50 / p99) that degraded most, so a
//! regression points at its cause instead of just a scalar.

use super::score::{composite_score, ScoreInputs};
use crate::harness::report::{BenchJson, BenchRow};
use std::fmt::Write as _;
use std::path::Path;

/// Maximum tolerated relative composite-score drop per pinned row.
pub const TOLERANCE: f64 = 0.10;

/// One compared row.
#[derive(Clone, Debug)]
pub struct RowDelta {
    pub label: String,
    pub baseline_score: f64,
    pub current_score: f64,
    /// `(current - baseline) / baseline`; positive = improvement.
    /// `0` when the baseline score is 0 (nothing to regress from).
    pub delta: f64,
    /// The metric that moved most against us ("throughput", "p50_ms",
    /// "p99_ms", or "none").
    pub axis: &'static str,
    pub regressed: bool,
}

/// Outcome of comparing one baseline file.
#[derive(Clone, Debug, Default)]
pub struct CompareOutcome {
    pub experiment: String,
    pub deltas: Vec<RowDelta>,
    /// Pinned labels missing from the current run (always failures:
    /// a silently dropped configuration is not a pass).
    pub missing: Vec<String>,
    pub notes: Vec<String>,
}

impl CompareOutcome {
    /// Every failure this outcome carries, as diagnostics.
    pub fn failures(&self) -> Vec<String> {
        let mut out = Vec::new();
        for d in &self.deltas {
            if d.regressed {
                out.push(format!(
                    "{}: configuration {} regressed {:.1}% (score {:.3} -> {:.3}, \
                     worst axis: {})",
                    self.experiment,
                    d.label,
                    -d.delta * 100.0,
                    d.baseline_score,
                    d.current_score,
                    d.axis
                ));
            }
        }
        for label in &self.missing {
            out.push(format!(
                "{}: pinned configuration {} is missing from the current run",
                self.experiment, label
            ));
        }
        out
    }

    pub fn passed(&self) -> bool {
        self.failures().is_empty()
    }

    /// Human-readable per-row report (deltas for every pinned config,
    /// improvements included).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "--- compare: {} ---", self.experiment);
        for d in &self.deltas {
            let verdict = if d.regressed {
                "REGRESSION"
            } else if d.delta > 0.0 {
                "improved"
            } else {
                "ok"
            };
            let _ = writeln!(
                out,
                "{:<44} {:>10.3} -> {:>10.3}  {:>+7.1}%  {}",
                d.label,
                d.baseline_score,
                d.current_score,
                d.delta * 100.0,
                verdict
            );
        }
        for m in &self.missing {
            let _ = writeln!(out, "{m:<44} MISSING from current run");
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }
}

/// Relative degradation of one metric (positive = got worse). Missing
/// values (NaN) on either side contribute nothing.
fn degradation(baseline: f64, current: f64, lower_is_better: bool) -> f64 {
    if !baseline.is_finite() || !current.is_finite() || baseline <= 0.0 {
        return 0.0;
    }
    if lower_is_better {
        (current - baseline) / baseline
    } else {
        (baseline - current) / baseline
    }
}

/// The metric that moved most against the current run.
fn worst_axis(baseline: &BenchRow, current: &BenchRow) -> &'static str {
    let axes = [
        ("throughput", degradation(baseline.throughput, current.throughput, false)),
        ("p50_ms", degradation(baseline.p50_ms, current.p50_ms, true)),
        ("p99_ms", degradation(baseline.p99_ms, current.p99_ms, true)),
    ];
    let mut worst = ("none", 0.0);
    for (name, d) in axes {
        if d > worst.1 {
            worst = (name, d);
        }
    }
    worst.0
}

/// Diff `current` against `baseline`, row by row, matched by label.
/// Rows only in `current` are ignored (a grown sweep is fine); rows
/// only in `baseline` are failures. Scores come from the BENCH-schema
/// fields on both sides, so emitter and parser disagree on nothing.
pub fn compare_rows(baseline: &BenchJson, current: &BenchJson) -> CompareOutcome {
    let mut out = CompareOutcome { experiment: baseline.experiment.clone(), ..Default::default() };
    for brow in &baseline.rows {
        let Some(crow) = current.rows.iter().find(|r| r.label == brow.label) else {
            // A measured pin that disappeared is a failure; a bootstrap
            // pin (score 0 — no measured numbers yet, see DESIGN.md
            // §Sweeps) reserves the label without gating on it.
            if composite_score(&ScoreInputs::from_bench_row(brow)) > 0.0 {
                out.missing.push(brow.label.clone());
            } else {
                out.notes.push(format!(
                    "bootstrap pin {} absent from this run — regenerate baselines",
                    brow.label
                ));
            }
            continue;
        };
        let bscore = composite_score(&ScoreInputs::from_bench_row(brow));
        let cscore = composite_score(&ScoreInputs::from_bench_row(crow));
        let delta = if bscore > 0.0 { (cscore - bscore) / bscore } else { 0.0 };
        out.deltas.push(RowDelta {
            label: brow.label.clone(),
            baseline_score: bscore,
            current_score: cscore,
            delta,
            axis: if delta < 0.0 { worst_axis(brow, crow) } else { "none" },
            regressed: delta < -TOLERANCE,
        });
    }
    out
}

/// Compare every `BENCH_*.json` under `dir` (sorted by file name):
///
/// * sweep baselines (`experiment` starting with `sweep_`) diff
///   against `current_sweep` — the rows this invocation just produced;
/// * experiment baselines (x3..x7, x9, x12) re-run their deterministic bench
///   rows via [`crate::harness::experiments::bench_json_for`] at the
///   **file's** recorded seed and diff against those;
/// * wall-clock baselines (x10) are pinned for the trajectory but
///   skipped by the gate — their numbers depend on the machine, not
///   the code.
///
/// Returns the rendered report, or `Err(report)` if any pinned row
/// regressed or went missing.
pub fn compare_dir(
    dir: &Path,
    current_sweep: &BenchJson,
    root_seed: u64,
) -> Result<String, String> {
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("read baseline dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(format!("no BENCH_*.json baselines under {}", dir.display()));
    }

    let mut report = String::new();
    let mut failures: Vec<String> = Vec::new();
    for path in files {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("?").to_string();
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read baseline {}: {e}", path.display()))?;
        let baseline = BenchJson::parse(&text).map_err(|e| format!("{name}: {e}"))?;

        let outcome = if baseline.experiment.starts_with("sweep_") {
            if baseline.experiment != current_sweep.experiment {
                let _ = writeln!(
                    report,
                    "note: {name} pins {:?} but this run is {:?} — skipped \
                     (run the matching --mode to gate it)",
                    baseline.experiment, current_sweep.experiment
                );
                continue;
            }
            if baseline.seed != root_seed {
                failures.push(format!(
                    "{name}: baseline pinned at root seed {} but this run used {} — \
                     re-run with --seed {} (labels would not line up)",
                    baseline.seed, root_seed, baseline.seed
                ));
                continue;
            }
            compare_rows(&baseline, current_sweep)
        } else if baseline.experiment == "x10" || baseline.experiment == "recovery" {
            let _ = writeln!(
                report,
                "note: {name} pins wall-clock recovery rows — trajectory only, not gated"
            );
            continue;
        } else {
            match crate::harness::experiments::bench_json_for(&baseline.experiment, baseline.seed)
            {
                Some(current) => compare_rows(&baseline, &current),
                None => {
                    failures.push(format!(
                        "{name}: unknown experiment {:?} — stale baseline?",
                        baseline.experiment
                    ));
                    continue;
                }
            }
        };
        report.push_str(&outcome.render());
        failures.extend(outcome.failures());
    }

    if failures.is_empty() {
        let _ = writeln!(report, "compare: all pinned configurations within {:.0}%", TOLERANCE * 100.0);
        Ok(report)
    } else {
        for f in &failures {
            let _ = writeln!(report, "FAIL: {f}");
        }
        Err(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(label: &str, tput: f64, p50: f64, p99: f64) -> BenchRow {
        BenchRow {
            label: label.into(),
            throughput: tput,
            p50_ms: p50,
            p99_ms: p99,
            offered_per_sec: 4000.0,
        }
    }

    fn bench(rows: Vec<BenchRow>) -> BenchJson {
        BenchJson { experiment: "sweep_smoke".into(), seed: 42, rows }
    }

    #[test]
    fn identical_runs_pass() {
        let b = bench(vec![row("a", 1000.0, 0.5, 2.0), row("b", 500.0, 1.0, 4.0)]);
        let out = compare_rows(&b, &b.clone());
        assert!(out.passed(), "{:?}", out.failures());
        assert!(out.deltas.iter().all(|d| d.delta.abs() < 1e-12));
    }

    #[test]
    fn degraded_run_fails_naming_config_and_axis() {
        let baseline = bench(vec![row("good", 1000.0, 0.5, 2.0), row("bad", 1000.0, 0.5, 2.0)]);
        // "bad" loses 50% throughput — well past the 10% tolerance.
        let current = bench(vec![row("good", 1000.0, 0.5, 2.0), row("bad", 500.0, 0.5, 2.0)]);
        let out = compare_rows(&baseline, &current);
        assert!(!out.passed());
        let failures = out.failures();
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("bad"), "{failures:?}");
        assert!(failures[0].contains("throughput"), "{failures:?}");
        assert!(!failures.iter().any(|f| f.contains("good")), "{failures:?}");
    }

    #[test]
    fn latency_regression_names_the_latency_axis() {
        let baseline = bench(vec![row("cfg", 1000.0, 0.5, 2.0)]);
        let current = bench(vec![row("cfg", 1000.0, 0.5, 9.0)]);
        let out = compare_rows(&baseline, &current);
        assert!(!out.passed());
        assert!(out.failures()[0].contains("p99_ms"), "{:?}", out.failures());
    }

    #[test]
    fn improved_run_passes_and_reports_the_delta() {
        let baseline = bench(vec![row("cfg", 1000.0, 0.5, 2.0)]);
        let current = bench(vec![row("cfg", 1500.0, 0.4, 1.5)]);
        let out = compare_rows(&baseline, &current);
        assert!(out.passed());
        assert!(out.deltas[0].delta > 0.0);
        let rendered = out.render();
        assert!(rendered.contains("improved"), "{rendered}");
        assert!(rendered.contains('+'), "delta missing from {rendered}");
    }

    #[test]
    fn small_wobble_within_tolerance_passes() {
        let baseline = bench(vec![row("cfg", 1000.0, 0.5, 2.0)]);
        let current = bench(vec![row("cfg", 950.0, 0.5, 2.1)]);
        let out = compare_rows(&baseline, &current);
        assert!(out.passed(), "{:?}", out.failures());
    }

    #[test]
    fn missing_pinned_config_fails_extra_rows_pass() {
        let baseline = bench(vec![row("kept", 1000.0, 0.5, 2.0), row("gone", 1.0, 0.5, 2.0)]);
        let current = bench(vec![row("kept", 1000.0, 0.5, 2.0), row("new", 9.0, 0.5, 2.0)]);
        let out = compare_rows(&baseline, &current);
        let failures = out.failures();
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("gone"), "{failures:?}");
        assert!(failures[0].contains("missing"), "{failures:?}");
    }

    #[test]
    fn missing_bootstrap_pin_is_a_note_not_a_failure() {
        // A bootstrap baseline row (all-null metrics, score 0) reserves
        // its label; its absence must not fail the gate.
        let baseline = bench(vec![row("pinned_later", f64::NAN, f64::NAN, f64::NAN)]);
        let current = bench(vec![row("something_else", 900.0, 0.5, 2.0)]);
        let out = compare_rows(&baseline, &current);
        assert!(out.passed(), "{:?}", out.failures());
        assert_eq!(out.notes.len(), 1);
        assert!(out.notes[0].contains("pinned_later"), "{:?}", out.notes);
    }

    #[test]
    fn zero_score_baseline_cannot_regress() {
        // A degenerate pinned row (zero completed) can't fail the gate
        // on a relative delta — there is nothing to regress from.
        let baseline = bench(vec![row("dead", 0.0, f64::NAN, f64::NAN)]);
        let current = bench(vec![row("dead", 0.0, f64::NAN, f64::NAN)]);
        assert!(compare_rows(&baseline, &current).passed());
        let better = bench(vec![row("dead", 100.0, 1.0, 2.0)]);
        assert!(compare_rows(&baseline, &better).passed());
    }
}
