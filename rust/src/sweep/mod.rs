//! `repro sweep` — deterministic parameter-space sweeps with committed
//! perf baselines and a regression gate (DESIGN.md §Sweeps).
//!
//! The pipeline, end to end:
//!
//! 1. [`space`] enumerates the parameter grid (batching × shards ×
//!    read mix × loss × reconfig cadence × leases × snapshots ×
//!    admission × nemesis) or draws a seeded sample of it;
//! 2. [`runner`] executes each configuration as a self-contained
//!    seeded simulation, in parallel across cores, each seed derived
//!    from `(root seed, label)` so any row replays in isolation;
//! 3. [`score`] folds each run into a composite health score
//!    (throughput × latency factors × staleness × log growth);
//! 4. this module renders the artifacts — a strict BENCH-schema JSON
//!    (`BENCH_sweep_<mode>.json`) and a richer CSV — and ranks
//!    configurations;
//! 5. [`compare`] diffs against committed baselines under
//!    `benches/baselines/`, failing on a >10% composite regression.
//!
//! Everything downstream of the root seed is deterministic: two sweeps
//! with the same mode and seed produce byte-identical artifacts, on
//! any machine, at any `--jobs` level.

pub mod compare;
pub mod runner;
pub mod score;
pub mod space;

pub use compare::{compare_dir, compare_rows, CompareOutcome, RowDelta, TOLERANCE};
pub use runner::{run_config, run_sweep, SweepRow};
pub use score::{composite_score, ScoreInputs, LOG_GROWTH_NORM};
pub use space::{ParameterSpace, SweepConfig};

use crate::harness::report::{BenchJson, BenchRow};
use crate::{Time, SEC};
use std::fmt::Write as _;

/// How many configurations the smoke sweep samples from the grid.
pub const SMOKE_CONFIGS: usize = 56;

/// A sweep preset: which slice of the space runs, and for how long.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepMode {
    /// CI fast loop: a seeded sample of [`SMOKE_CONFIGS`] grid points,
    /// 1 s of virtual time each.
    Smoke,
    /// Release job: the full cartesian grid, 2 s of virtual time each.
    Full,
}

impl SweepMode {
    pub fn parse(s: &str) -> Option<SweepMode> {
        match s {
            "smoke" => Some(SweepMode::Smoke),
            "full" => Some(SweepMode::Full),
            _ => None,
        }
    }

    /// The BENCH `experiment` name ("sweep_smoke" / "sweep_full").
    pub fn name(&self) -> &'static str {
        match self {
            SweepMode::Smoke => "sweep_smoke",
            SweepMode::Full => "sweep_full",
        }
    }

    /// Virtual-time horizon per configuration.
    pub fn duration(&self) -> Time {
        match self {
            SweepMode::Smoke => SEC,
            SweepMode::Full => 2 * SEC,
        }
    }

    /// The mode's configuration list — a pure function of the root
    /// seed (the smoke sample is drawn with it; the full grid ignores
    /// it).
    pub fn configs(&self, root_seed: u64) -> Vec<SweepConfig> {
        let space = ParameterSpace::default();
        match self {
            SweepMode::Smoke => space.sample(SMOKE_CONFIGS, root_seed),
            SweepMode::Full => space.grid(),
        }
    }
}

/// Render sweep rows as a strict BENCH-schema document (the same shape
/// `repro exp --bench-json` emits, so baselines and experiment benches
/// share parsers, emitters, and the compare gate).
pub fn to_bench_json(rows: &[SweepRow], mode: SweepMode, root_seed: u64) -> BenchJson {
    BenchJson {
        experiment: mode.name().to_string(),
        seed: root_seed,
        rows: rows
            .iter()
            .map(|r| BenchRow {
                label: r.config.label(),
                throughput: r.throughput,
                p50_ms: r.p50_ms,
                p99_ms: r.p99_ms,
                offered_per_sec: r.offered_per_sec,
            })
            .collect(),
    }
}

/// The richer CSV report: BENCH columns plus the health components the
/// BENCH schema doesn't carry (delivery, staleness, log growth,
/// violations, seed, composite score). One row per configuration, in
/// run order.
pub fn to_csv(rows: &[SweepRow]) -> String {
    let mut out = String::from(
        "label,seed,throughput,p50_ms,p99_ms,offered_per_sec,delivery_ratio,\
         stale_reads,max_log_len,violation,score\n",
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{},{},{:.3},{:.4},{:.4},{:.3},{:.4},{},{},{},{:.4}",
            r.config.label(),
            r.seed,
            r.throughput,
            r.p50_ms,
            r.p99_ms,
            r.offered_per_sec,
            r.delivery_ratio,
            r.stale_reads.map_or("unchecked".to_string(), |n| n.to_string()),
            r.max_log_len,
            r.violation.as_deref().unwrap_or("").replace(',', ";"),
            r.score,
        );
    }
    out
}

/// Indices of `rows` ranked best-first by composite score, ties broken
/// by label so the ranking is total and deterministic.
pub fn ranked(rows: &[SweepRow]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..rows.len()).collect();
    idx.sort_by(|&a, &b| {
        rows[b]
            .score
            .partial_cmp(&rows[a].score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| rows[a].config.label().cmp(&rows[b].config.label()))
    });
    idx
}

/// Human summary printed after a sweep: totals, violations, and the
/// best/worst-ranked configurations.
pub fn render_summary(rows: &[SweepRow], mode: SweepMode, root_seed: u64) -> String {
    let mut out = String::new();
    let violations = rows.iter().filter(|r| r.violation.is_some()).count();
    let _ = writeln!(
        out,
        "sweep {}: {} configurations, root seed {}, {} violation(s)",
        mode.name(),
        rows.len(),
        root_seed,
        violations
    );
    let order = ranked(rows);
    let show = |out: &mut String, i: usize| {
        let r = &rows[i];
        let _ = writeln!(
            out,
            "  {:<44} score {:>10.3}  tput {:>9.1}/s  p99 {:>7.3} ms{}",
            r.config.label(),
            r.score,
            r.throughput,
            r.p99_ms,
            r.violation.as_deref().map(|v| format!("  VIOLATION: {v}")).unwrap_or_default(),
        );
    };
    let top = order.len().min(5);
    let _ = writeln!(out, "top {top}:");
    for &i in order.iter().take(top) {
        show(&mut out, i);
    }
    if order.len() > top {
        let _ = writeln!(out, "bottom {top}:");
        for &i in order.iter().rev().take(top).rev() {
            show(&mut out, i);
        }
    }
    for r in rows.iter().filter(|r| r.violation.is_some()) {
        let _ = writeln!(
            out,
            "VIOLATION {} (seed {}): {}",
            r.config.label(),
            r.seed,
            r.violation.as_deref().unwrap_or("")
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_row(label_batch: usize, score: f64) -> SweepRow {
        let config = SweepConfig {
            batch_size: label_batch,
            shards: 1,
            read_pct: 0,
            loss_pm: 0,
            reconfig_ms: None,
            leases: false,
            snapshots: false,
            admission: false,
            nemesis: false,
        };
        SweepRow {
            seed: config.seed(42),
            config,
            throughput: score,
            p50_ms: 0.5,
            p99_ms: 2.0,
            offered_per_sec: 4000.0,
            delivery_ratio: 0.99,
            stale_reads: None,
            max_log_len: 100,
            violation: None,
            score,
        }
    }

    #[test]
    fn modes_parse_and_describe_themselves() {
        assert_eq!(SweepMode::parse("smoke"), Some(SweepMode::Smoke));
        assert_eq!(SweepMode::parse("full"), Some(SweepMode::Full));
        assert_eq!(SweepMode::parse("bogus"), None);
        assert_eq!(SweepMode::Smoke.name(), "sweep_smoke");
        assert_eq!(SweepMode::Full.name(), "sweep_full");
        assert_eq!(SweepMode::Smoke.configs(42).len(), SMOKE_CONFIGS);
        assert!(SMOKE_CONFIGS >= 50, "smoke mode must run at least 50 configurations");
        assert_eq!(SweepMode::Full.configs(42).len(), ParameterSpace::default().len());
    }

    #[test]
    fn bench_json_round_trips_through_shared_schema() {
        let rows = vec![fake_row(1, 900.0), fake_row(8, 1200.0)];
        let j = to_bench_json(&rows, SweepMode::Smoke, 42);
        let parsed = BenchJson::parse(&j.to_json()).expect("sweep BENCH output must parse");
        assert_eq!(parsed, j);
        assert_eq!(parsed.experiment, "sweep_smoke");
        assert_eq!(parsed.rows[0].label, rows[0].config.label());
    }

    #[test]
    fn csv_has_header_and_one_line_per_row() {
        let rows = vec![fake_row(1, 900.0), fake_row(8, 1200.0)];
        let csv = to_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("label,seed,throughput"));
        assert!(lines[1].starts_with(&rows[0].config.label()));
    }

    #[test]
    fn ranking_is_best_first_and_deterministic() {
        let rows = vec![fake_row(1, 900.0), fake_row(8, 1200.0), fake_row(32, 1100.0)];
        let order = ranked(&rows);
        assert_eq!(order, vec![1, 2, 0]);
        assert_eq!(order, ranked(&rows));
        let summary = render_summary(&rows, SweepMode::Smoke, 42);
        assert!(summary.contains("3 configurations"));
        assert!(summary.contains("b8_"), "{summary}");
    }
}
