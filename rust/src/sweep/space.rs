//! The sweep's parameter space: every knob the repro's perf claims
//! depend on, swept as an axis (DESIGN.md §Sweeps).
//!
//! A [`SweepConfig`] is one point in the space — all-integer fields so
//! labels round-trip exactly and the grid order is total. The
//! [`ParameterSpace`] enumerates the full cartesian grid in a fixed
//! axis order, or draws a seeded-random sample from it (the smoke
//! sweep); both are deterministic functions of their inputs.

use crate::util::{Fnv, Rng};
use crate::{Time, MS};

/// One configuration of the sweep: a single simulated run's knobs.
/// Fields are integers (percent / per-mille / ms) so that `label()` is
/// an exact, parseable identity and configs are `Eq`/`Ord`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SweepConfig {
    /// Phase 2 batch size (`OptFlags::batch_size`; 1 = unbatched).
    pub batch_size: usize,
    /// Consensus groups sharing one matchmaker set (1 = unsharded).
    pub shards: usize,
    /// Percent of requests issued as linearizable reads (0..=100).
    pub read_pct: u8,
    /// Network message-drop probability in per-mille (10 = 1%).
    pub loss_pm: u32,
    /// Reconfiguration cadence in ms (`None` = no reconfig storm).
    pub reconfig_ms: Option<u64>,
    /// Leader read leases on (`LeaseSpec`)?
    pub leases: bool,
    /// Replica snapshots + log truncation on (`SnapshotSpec`)?
    pub snapshots: bool,
    /// Leader overload control on (`AdmissionSpec`: bounded proposal
    /// inbox + Busy pushback + adaptive batching)?
    pub admission: bool,
    /// Nemesis fault storm on (`nemesis::NemesisPlan::storm`: seeded
    /// short one-way cuts and heals over the protocol nodes)?
    pub nemesis: bool,
}

impl SweepConfig {
    /// The config's identity: a stable label every artifact keys on
    /// (BENCH rows, CSV rows, compare diagnostics, `--only`).
    pub fn label(&self) -> String {
        format!(
            "b{}_s{}_r{}_loss{}_rc{}_{}_{}_{}_{}",
            self.batch_size,
            self.shards,
            self.read_pct,
            self.loss_pm,
            match self.reconfig_ms {
                Some(ms) => ms.to_string(),
                None => "off".to_string(),
            },
            if self.leases { "lease" } else { "nolease" },
            if self.snapshots { "snap" } else { "nosnap" },
            if self.admission { "adm" } else { "noadm" },
            if self.nemesis { "nem" } else { "nonem" },
        )
    }

    /// Drop probability as a fraction.
    pub fn loss_rate(&self) -> f64 {
        self.loss_pm as f64 / 1000.0
    }

    /// Read fraction as a fraction.
    pub fn read_fraction(&self) -> f64 {
        self.read_pct as f64 / 100.0
    }

    /// Reconfiguration cadence in virtual time.
    pub fn reconfig_every(&self) -> Option<Time> {
        self.reconfig_ms.map(|ms| ms * MS)
    }

    /// The run's simulation seed, derived from the root seed and the
    /// config's label (DESIGN.md §Sweeps: `splitmix64(root) ^
    /// fnv1a64(label)`), so any row is replayable in isolation with
    /// `repro sweep --only LABEL --seed ROOT` — no dependence on the
    /// config's position in the grid or on which other configs ran.
    pub fn seed(&self, root: u64) -> u64 {
        let mut h = Fnv::new();
        h.write_str(&self.label());
        splitmix64(root) ^ h.finish()
    }
}

/// One splitmix64 step — the standard seed spreader, so nearby root
/// seeds don't produce correlated per-config seeds.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// The axes of the sweep. `ParameterSpace::default()` is the full
/// space the release-job sweep grids over; tests shrink the axes to
/// keep runtimes bounded.
#[derive(Clone, Debug)]
pub struct ParameterSpace {
    pub batch_sizes: Vec<usize>,
    pub shards: Vec<usize>,
    pub read_pcts: Vec<u8>,
    pub loss_pms: Vec<u32>,
    pub reconfig_ms: Vec<Option<u64>>,
    pub leases: Vec<bool>,
    pub snapshots: Vec<bool>,
    pub admission: Vec<bool>,
    pub nemesis: Vec<bool>,
}

impl Default for ParameterSpace {
    fn default() -> Self {
        ParameterSpace {
            batch_sizes: vec![1, 8, 32],
            shards: vec![1, 2, 4],
            read_pcts: vec![0, 50, 90],
            loss_pms: vec![0, 10],
            reconfig_ms: vec![None, Some(500)],
            leases: vec![false, true],
            snapshots: vec![false, true],
            admission: vec![false, true],
            nemesis: vec![false, true],
        }
    }
}

impl ParameterSpace {
    /// Number of grid points.
    pub fn len(&self) -> usize {
        self.batch_sizes.len()
            * self.shards.len()
            * self.read_pcts.len()
            * self.loss_pms.len()
            * self.reconfig_ms.len()
            * self.leases.len()
            * self.snapshots.len()
            * self.admission.len()
            * self.nemesis.len()
    }

    /// Whether the space is empty (an axis with no values).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The full cartesian grid in fixed axis order (batch → shards →
    /// read mix → loss → reconfig cadence → leases → snapshots →
    /// admission → nemesis), so grid position is a pure function of
    /// the axes.
    pub fn grid(&self) -> Vec<SweepConfig> {
        let mut out = Vec::with_capacity(self.len());
        for &batch_size in &self.batch_sizes {
            for &shards in &self.shards {
                for &read_pct in &self.read_pcts {
                    for &loss_pm in &self.loss_pms {
                        for &reconfig_ms in &self.reconfig_ms {
                            for &leases in &self.leases {
                                for &snapshots in &self.snapshots {
                                    for &admission in &self.admission {
                                        for &nemesis in &self.nemesis {
                                            out.push(SweepConfig {
                                                batch_size,
                                                shards,
                                                read_pct,
                                                loss_pm,
                                                reconfig_ms,
                                                leases,
                                                snapshots,
                                                admission,
                                                nemesis,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// A seeded-random sample of `n` **distinct** grid points: shuffle
    /// the grid with the root-seeded RNG and take a prefix. Identical
    /// `(axes, n, seed)` → identical sample, in identical order.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<SweepConfig> {
        let mut grid = self.grid();
        let mut rng = Rng::new(splitmix64(seed ^ 0x53ee_b0a7));
        rng.shuffle(&mut grid);
        grid.truncate(n);
        grid
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::US;

    #[test]
    fn grid_is_full_cartesian_product() {
        let space = ParameterSpace::default();
        let grid = space.grid();
        assert_eq!(grid.len(), space.len());
        assert_eq!(grid.len(), 3 * 3 * 3 * 2 * 2 * 2 * 2 * 2 * 2);
        // Labels are unique — they're the artifact key.
        let mut labels: Vec<String> = grid.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), grid.len());
    }

    #[test]
    fn sample_is_deterministic_and_distinct() {
        let space = ParameterSpace::default();
        let a = space.sample(56, 42);
        let b = space.sample(56, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 56);
        let mut labels: Vec<String> = a.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 56, "sampled configs must be distinct");
        // A different seed draws a different prefix.
        assert_ne!(space.sample(56, 43), a);
    }

    #[test]
    fn seed_depends_only_on_root_and_label() {
        let cfg = SweepConfig {
            batch_size: 8,
            shards: 2,
            read_pct: 50,
            loss_pm: 10,
            reconfig_ms: Some(500),
            leases: true,
            snapshots: false,
            admission: false,
            nemesis: false,
        };
        assert_eq!(cfg.seed(42), cfg.clone().seed(42));
        assert_ne!(cfg.seed(42), cfg.seed(43));
        let mut other = cfg.clone();
        other.batch_size = 1;
        assert_ne!(cfg.seed(42), other.seed(42));
    }

    #[test]
    fn label_encodes_every_axis() {
        let cfg = SweepConfig {
            batch_size: 32,
            shards: 4,
            read_pct: 90,
            loss_pm: 10,
            reconfig_ms: Some(500),
            leases: true,
            snapshots: true,
            admission: true,
            nemesis: true,
        };
        assert_eq!(cfg.label(), "b32_s4_r90_loss10_rc500_lease_snap_adm_nem");
        let cfg = SweepConfig {
            reconfig_ms: None,
            leases: false,
            snapshots: false,
            admission: false,
            nemesis: false,
            ..cfg
        };
        assert_eq!(cfg.label(), "b32_s4_r90_loss10_rcoff_nolease_nosnap_noadm_nonem");
    }

    #[test]
    fn conversions() {
        let cfg = SweepConfig {
            batch_size: 1,
            shards: 1,
            read_pct: 90,
            loss_pm: 10,
            reconfig_ms: Some(500),
            leases: false,
            snapshots: false,
            admission: false,
            nemesis: false,
        };
        assert!((cfg.loss_rate() - 0.01).abs() < 1e-12);
        assert!((cfg.read_fraction() - 0.9).abs() < 1e-12);
        assert_eq!(cfg.reconfig_every(), Some(500 * MS));
        assert_eq!(cfg.reconfig_every().unwrap() / US, 500_000);
    }
}
