//! Executes sweep configurations on the deterministic simulator, in
//! parallel across cores (DESIGN.md §Sweeps).
//!
//! Each configuration is a self-contained run: its own `Sim`, its own
//! seed derived from `(root seed, label)` — so results are independent
//! of worker count, scheduling order, and which other configs ran.
//! Workers pull config indices from an atomic counter and write rows
//! into their grid slot; the returned vector is in input order, and
//! two sweeps with the same root seed are byte-identical.

use super::score::{composite_score, ScoreInputs};
use super::space::SweepConfig;
use crate::config::{AdmissionSpec, LeaseSpec, OptFlags, SnapshotSpec};
use crate::harness::{Cluster, ShardedCluster};
use crate::metrics::{check_counter_reads, open_loop_summary};
use crate::nemesis::NemesisPlan;
use crate::roles::{Leader, Replica};
use crate::sim::NetworkModel;
use crate::statemachine::Counter;
use crate::workload::WorkloadSpec;
use crate::{Time, MS, US};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One executed configuration: the BENCH-schema fields plus the extra
/// health components the richer CSV/JSON report carries.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub config: SweepConfig,
    /// The run's derived simulation seed (`SweepConfig::seed`).
    pub seed: u64,
    /// Completed operations per simulated second.
    pub throughput: f64,
    /// Median latency, ms (NaN if nothing completed).
    pub p50_ms: f64,
    /// 99th-percentile latency, ms (NaN if nothing completed).
    pub p99_ms: f64,
    /// Offered arrivals per second.
    pub offered_per_sec: f64,
    /// `completed / offered`.
    pub delivery_ratio: f64,
    /// Stale linearizable reads (`None` = not checked: sharded runs,
    /// or a zero read mix).
    pub stale_reads: Option<u64>,
    /// High-water chosen-log length across all replicas.
    pub max_log_len: u64,
    /// First safety/linearizability violation, if any (zeroes the
    /// score and is carried into the CSV).
    pub violation: Option<String>,
    /// The composite health score ([`super::score::composite_score`]).
    pub score: f64,
}

impl SweepRow {
    /// The score inputs this row folds into its composite.
    pub fn score_inputs(&self) -> ScoreInputs {
        ScoreInputs {
            throughput: if self.violation.is_some() { 0.0 } else { self.throughput },
            p50_ms: self.p50_ms,
            p99_ms: self.p99_ms,
            stale_reads: self.stale_reads,
            max_log_len: Some(self.max_log_len),
        }
    }
}

/// The shared per-run workload: 4 open-loop clients at 1000 arrivals/s
/// each, in-flight bound 32, 8-byte `+1` counter increments (so the
/// unsharded staleness check has counter semantics), read mix per
/// config. Arrivals stop short of the horizon so in-flight tails drain.
fn workload_for(cfg: &SweepConfig, duration: Time) -> WorkloadSpec {
    let stop = duration.saturating_sub(300 * MS).max(duration / 2);
    WorkloadSpec::open_loop(1000.0)
        .max_in_flight(32)
        .payload(1i64.to_le_bytes().to_vec())
        .read_payload(Vec::new())
        .read_fraction(cfg.read_fraction())
        .keys(256)
        .stop_at(stop)
}

fn opts_for(cfg: &SweepConfig) -> OptFlags {
    let mut opts = OptFlags::default().with_batching(cfg.batch_size, 500 * US);
    if cfg.leases {
        opts = opts.with_leases(LeaseSpec::every(50 * MS, 5 * MS, 100 * US));
    }
    if cfg.snapshots {
        opts = opts.with_snapshots(SnapshotSpec::every(100 * MS, 1024));
    }
    if cfg.admission {
        // Delayed-retry policy (shed = false): pushback never abandons
        // requests, so the axis perturbs queueing/latency, not the
        // delivery ratio the composite score keys on.
        opts = opts.with_admission(AdmissionSpec::slo(32, 20_000, false));
    }
    opts
}

fn net_for(cfg: &SweepConfig) -> NetworkModel {
    NetworkModel { drop_prob: cfg.loss_rate(), ..NetworkModel::lan() }
}

/// Reconfiguration-storm issue times: from 30% to 90% of the run at
/// the configured cadence, capped at 8 (a 500 ms cadence over a 1 s
/// smoke run gives 1–2 storms; the cap bounds full-mode runs).
fn storm_times(cfg: &SweepConfig, duration: Time) -> Vec<Time> {
    let Some(every) = cfg.reconfig_every() else { return Vec::new() };
    let mut out = Vec::new();
    let mut t = duration * 3 / 10;
    while t < duration * 9 / 10 && out.len() < 8 {
        out.push(t);
        t += every;
    }
    out
}

/// The nemesis axis: a seeded storm of short one-way cuts and heals
/// over the run's protocol nodes (proposers, acceptors, matchmakers —
/// clients and replicas stay connected so arrivals keep flowing). Each
/// cut is shorter than the election timeout, so the axis measures
/// degradation under gray asymmetry, not failover; the dedicated X12
/// experiment covers the latter. Deterministic in the row's seed.
fn inject_storm(targets: Vec<crate::NodeId>, seed: u64, duration: Time, sim: &mut crate::sim::Sim) {
    NemesisPlan::storm(seed, &targets, duration / MS).apply_to_sim(sim);
}

/// Run one configuration for `duration` of virtual time and score it.
/// Pure function of `(cfg, root_seed, duration)` — the isolation
/// guarantee behind `repro sweep --only`.
pub fn run_config(cfg: &SweepConfig, root_seed: u64, duration: Time) -> SweepRow {
    let seed = cfg.seed(root_seed);
    if cfg.shards > 1 {
        run_sharded(cfg, seed, duration)
    } else {
        run_single(cfg, seed, duration)
    }
}

/// Unsharded run: a full [`Cluster`] with Counter replicas, so reads
/// (when the mix has any) are linearizability-checked against the
/// global write history — the staleness component of the score.
fn run_single(cfg: &SweepConfig, seed: u64, duration: Time) -> SweepRow {
    let mut cluster = Cluster::builder()
        .clients(4)
        .workload(workload_for(cfg, duration))
        .opts(opts_for(cfg))
        .net(net_for(cfg))
        .seed(seed)
        .build();
    for &r in &cluster.layout.replicas.clone() {
        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
            rep.sm = Box::new(Counter::new());
        }
    }
    let leader = cluster.initial_leader();
    for (i, at) in storm_times(cfg, duration).into_iter().enumerate() {
        let target = cluster.random_config(i as u64 + 1);
        cluster.sim.schedule(at, move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(target.clone(), now, fx));
        });
    }
    if cfg.nemesis {
        let mut targets = cluster.layout.proposers.clone();
        targets.extend_from_slice(&cluster.layout.acceptor_pool);
        targets.extend_from_slice(&cluster.layout.matchmaker_pool);
        inject_storm(targets, seed, duration, &mut cluster.sim);
    }
    cluster.sim.run_until(duration);

    let mut violation =
        crate::check::InvariantSet::check_all(&cluster.sim.announces).err().map(|v| v.to_string());
    let samples = cluster.samples();
    let (offered, _, _) = cluster.workload_totals();
    let mut stale_reads = None;
    if cfg.read_pct > 0 {
        let reads = cluster.read_records();
        let (completions, issues) = cluster.write_records();
        match check_counter_reads(&reads, &completions, &issues) {
            Ok(()) => stale_reads = Some(0),
            Err(e) => {
                stale_reads = Some(1);
                violation.get_or_insert(e);
            }
        }
    }
    let max_log_len =
        cluster.retention_stats().iter().map(|r| r.max_log_len as u64).max().unwrap_or(0);
    finish_row(cfg, seed, duration, &samples, offered, stale_reads, max_log_len, violation)
}

/// Sharded run: a [`ShardedCluster`] of `cfg.shards` groups behind one
/// matchmaker set, Noop state machines (per-key counter semantics
/// don't compose across groups, so staleness is left to the dedicated
/// sharded property suites and reported as unchecked here).
fn run_sharded(cfg: &SweepConfig, seed: u64, duration: Time) -> SweepRow {
    let mut cluster = ShardedCluster::builder()
        .shards(cfg.shards)
        .clients(4)
        .workload(workload_for(cfg, duration))
        .opts(opts_for(cfg))
        .net(net_for(cfg))
        .seed(seed)
        .build();
    let leader = cluster.group_leader(0);
    for (i, at) in storm_times(cfg, duration).into_iter().enumerate() {
        let target = cluster.random_config(0, i as u64 + 1);
        cluster.sim.schedule(at, move |s| {
            s.with_node::<Leader, _>(leader, |l, now, fx| l.reconfigure(target.clone(), now, fx));
        });
    }
    if cfg.nemesis {
        let mut targets = cluster.matchmaker_pool.clone();
        for g in &cluster.groups {
            targets.extend_from_slice(&g.proposers);
            targets.extend_from_slice(&g.acceptor_pool);
        }
        inject_storm(targets, seed, duration, &mut cluster.sim);
    }
    cluster.sim.run_until(duration);

    let violation =
        crate::check::InvariantSet::check_all(&cluster.sim.announces).err().map(|v| v.to_string());
    let samples = cluster.samples();
    let (offered, _, _) = cluster.workload_totals();
    let replicas: Vec<crate::NodeId> =
        cluster.groups.iter().flat_map(|g| g.replicas.iter().copied()).collect();
    let mut max_log_len = 0u64;
    for r in replicas {
        if let Some(rep) = cluster.sim.node_mut::<Replica>(r) {
            max_log_len = max_log_len.max(rep.max_log_len as u64);
        }
    }
    finish_row(cfg, seed, duration, &samples, offered, None, max_log_len, violation)
}

#[allow(clippy::too_many_arguments)]
fn finish_row(
    cfg: &SweepConfig,
    seed: u64,
    duration: Time,
    samples: &[crate::metrics::Sample],
    offered: u64,
    stale_reads: Option<u64>,
    max_log_len: u64,
    violation: Option<String>,
) -> SweepRow {
    let summary = open_loop_summary(samples, offered, duration);
    let mut row = SweepRow {
        config: cfg.clone(),
        seed,
        throughput: summary.map_or(0.0, |s| s.completed_per_sec),
        p50_ms: summary.map_or(f64::NAN, |s| s.latency.median),
        p99_ms: summary.map_or(f64::NAN, |s| s.latency.p99),
        offered_per_sec: summary
            .map_or(offered as f64 / (duration as f64 / 1e9), |s| s.offered_per_sec),
        delivery_ratio: summary.map_or(0.0, |s| s.delivery_ratio),
        stale_reads,
        max_log_len,
        violation,
        score: 0.0,
    };
    row.score = composite_score(&row.score_inputs());
    row
}

/// Run every configuration, `jobs` at a time (`0` = one per available
/// core). Rows come back in input order regardless of scheduling, so
/// the sweep's artifacts are deterministic for a fixed root seed.
pub fn run_sweep(
    configs: &[SweepConfig],
    root_seed: u64,
    duration: Time,
    jobs: usize,
) -> Vec<SweepRow> {
    let jobs = if jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        jobs
    }
    .min(configs.len().max(1));

    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<SweepRow>>> = Mutex::new(vec![None; configs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= configs.len() {
                    break;
                }
                let row = run_config(&configs[i], root_seed, duration);
                slots.lock().expect("sweep worker panicked").as_mut_slice()[i] = Some(row);
            });
        }
    });
    slots
        .into_inner()
        .expect("sweep worker panicked")
        .into_iter()
        .map(|r| r.expect("every config slot filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SEC;

    fn quick_cfg() -> SweepConfig {
        SweepConfig {
            batch_size: 8,
            shards: 1,
            read_pct: 0,
            loss_pm: 0,
            reconfig_ms: None,
            leases: false,
            snapshots: false,
            admission: false,
            nemesis: false,
        }
    }

    #[test]
    fn single_config_runs_and_scores() {
        let row = run_config(&quick_cfg(), 42, SEC / 2);
        assert!(row.violation.is_none(), "{:?}", row.violation);
        assert!(row.throughput > 100.0, "throughput {}", row.throughput);
        assert!(row.score > 0.0);
        assert!(row.p50_ms.is_finite());
        assert_eq!(row.stale_reads, None, "all-write mix is not staleness-checked");
    }

    #[test]
    fn sharded_config_runs_and_scores() {
        let cfg = SweepConfig { shards: 2, ..quick_cfg() };
        let row = run_config(&cfg, 42, SEC / 2);
        assert!(row.violation.is_none(), "{:?}", row.violation);
        assert!(row.throughput > 100.0, "throughput {}", row.throughput);
        assert_eq!(row.stale_reads, None);
    }

    #[test]
    fn read_mix_is_staleness_checked_when_unsharded() {
        let cfg = SweepConfig { read_pct: 50, leases: true, ..quick_cfg() };
        let row = run_config(&cfg, 42, SEC / 2);
        assert_eq!(row.stale_reads, Some(0), "violation: {:?}", row.violation);
        assert!(row.score > 0.0);
    }

    #[test]
    fn admission_config_runs_and_scores() {
        // The admission axis must not perturb a healthy (unsaturated)
        // run: full score, no violation, nothing abandoned to pushback.
        let cfg = SweepConfig { admission: true, ..quick_cfg() };
        let row = run_config(&cfg, 42, SEC / 2);
        assert!(row.violation.is_none(), "{:?}", row.violation);
        assert!(row.throughput > 100.0, "throughput {}", row.throughput);
        assert!(row.score > 0.0);
        assert!(row.delivery_ratio > 0.8, "delivery {}", row.delivery_ratio);
    }

    #[test]
    fn nemesis_config_runs_and_scores() {
        // The nemesis axis degrades, never corrupts: every cut is
        // shorter than the election timeout and every cut heals, so
        // the run stays safe, serves linearizable reads, and keeps
        // scoring.
        let cfg = SweepConfig { nemesis: true, read_pct: 50, ..quick_cfg() };
        let row = run_config(&cfg, 42, SEC / 2);
        assert!(row.violation.is_none(), "{:?}", row.violation);
        assert_eq!(row.stale_reads, Some(0));
        assert!(row.throughput > 100.0, "throughput {}", row.throughput);
        assert!(row.score > 0.0);
    }

    #[test]
    fn storm_times_respect_cadence_and_cap() {
        let cfg = SweepConfig { reconfig_ms: Some(100), ..quick_cfg() };
        let times = storm_times(&cfg, SEC);
        assert!(times.len() >= 2 && times.len() <= 8, "{times:?}");
        assert_eq!(times[0], SEC * 3 / 10);
        assert!(storm_times(&quick_cfg(), SEC).is_empty());
    }
}
