//! The composite health score: one scalar per sweep row, used to rank
//! configurations and to gate the baseline compare (DESIGN.md §Sweeps).
//!
//! The score folds throughput, tail latency, read staleness, and log
//! growth into a product of factors, each monotone in its component:
//!
//! ```text
//! score = throughput                         (ops/s; 0 completed → 0)
//!       × 1 / (1 + p50_ms)                   (missing/NaN → 1)
//!       × 1 / (1 + p99_ms)                   (missing/NaN → 1)
//!       × 0 if any stale read else 1         (unchecked → 1)
//!       × 1 / (1 + max_log_len / 10_000)     (unchecked → 1)
//! ```
//!
//! Multiplicative factors keep the score monotone in every component
//! (more throughput is never worse, higher p99 is never better) while
//! letting missing components degrade to a neutral `1` — a BENCH row
//! that carries only throughput still scores, so the compare gate can
//! diff rows produced by emitters that don't measure every column.
//! Stale reads are a correctness failure, not a tradeoff, so they zero
//! the score outright.

use crate::harness::report::BenchRow;

/// Chosen-log high-water mark at which the log-growth factor halves —
/// roughly the X5 acceptance bound (tail + interval growth).
pub const LOG_GROWTH_NORM: f64 = 10_000.0;

/// Everything the score consumes. `f64::NAN` marks an unmeasured
/// latency; `None` marks an unchecked component.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScoreInputs {
    /// Completed operations per simulated second.
    pub throughput: f64,
    /// Median latency, ms.
    pub p50_ms: f64,
    /// 99th-percentile latency, ms.
    pub p99_ms: f64,
    /// Stale linearizable reads observed (`None` = staleness not
    /// checked for this configuration, e.g. sharded runs).
    pub stale_reads: Option<u64>,
    /// High-water chosen-log length across replicas (`None` = not
    /// harvested, e.g. rows parsed from BENCH files).
    pub max_log_len: Option<u64>,
}

impl ScoreInputs {
    /// Score a bare BENCH-schema row (throughput/p50/p99 only; the
    /// staleness and log-growth components are neutral). This is what
    /// the compare gate uses on both sides of a diff, so baseline and
    /// current rows are always scored over the same fields.
    pub fn from_bench_row(r: &BenchRow) -> ScoreInputs {
        ScoreInputs {
            throughput: r.throughput,
            p50_ms: r.p50_ms,
            p99_ms: r.p99_ms,
            stale_reads: None,
            max_log_len: None,
        }
    }
}

/// A latency factor: `1 / (1 + ms)`, neutral (`1`) when the component
/// was not measured. Strictly decreasing in `ms` over `[0, ∞)`.
fn latency_factor(ms: f64) -> f64 {
    if ms.is_finite() && ms >= 0.0 {
        1.0 / (1.0 + ms)
    } else {
        1.0
    }
}

/// Compute the composite health score. Degenerate rows (zero or
/// non-finite throughput — a run that completed nothing) score 0.
pub fn composite_score(s: &ScoreInputs) -> f64 {
    if !s.throughput.is_finite() || s.throughput <= 0.0 {
        return 0.0;
    }
    let stale = match s.stale_reads {
        Some(n) if n > 0 => 0.0,
        _ => 1.0,
    };
    let log = match s.max_log_len {
        Some(len) => 1.0 / (1.0 + len as f64 / LOG_GROWTH_NORM),
        None => 1.0,
    };
    s.throughput * latency_factor(s.p50_ms) * latency_factor(s.p99_ms) * stale * log
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ScoreInputs {
        ScoreInputs {
            throughput: 1000.0,
            p50_ms: 0.5,
            p99_ms: 2.0,
            stale_reads: Some(0),
            max_log_len: Some(1000),
        }
    }

    #[test]
    fn monotone_in_throughput() {
        let lo = composite_score(&base());
        let hi = composite_score(&ScoreInputs { throughput: 2000.0, ..base() });
        assert!(hi > lo, "more throughput must score higher: {hi} vs {lo}");
    }

    #[test]
    fn monotone_in_p50() {
        let good = composite_score(&base());
        let bad = composite_score(&ScoreInputs { p50_ms: 5.0, ..base() });
        assert!(bad < good, "higher p50 must score lower: {bad} vs {good}");
    }

    #[test]
    fn monotone_in_p99() {
        let good = composite_score(&base());
        let bad = composite_score(&ScoreInputs { p99_ms: 50.0, ..base() });
        assert!(bad < good, "higher p99 must score lower: {bad} vs {good}");
    }

    #[test]
    fn monotone_in_log_growth() {
        let good = composite_score(&base());
        let bad = composite_score(&ScoreInputs { max_log_len: Some(100_000), ..base() });
        assert!(bad < good, "more log growth must score lower: {bad} vs {good}");
    }

    #[test]
    fn stale_reads_zero_the_score() {
        assert!(composite_score(&base()) > 0.0);
        assert_eq!(composite_score(&ScoreInputs { stale_reads: Some(1), ..base() }), 0.0);
        assert_eq!(composite_score(&ScoreInputs { stale_reads: Some(7), ..base() }), 0.0);
    }

    #[test]
    fn degenerate_rows_score_zero() {
        // Zero completed commands.
        assert_eq!(composite_score(&ScoreInputs { throughput: 0.0, ..base() }), 0.0);
        // Nonsense throughput (an emitter bug) must not rank first.
        assert_eq!(composite_score(&ScoreInputs { throughput: f64::NAN, ..base() }), 0.0);
        assert_eq!(
            composite_score(&ScoreInputs { throughput: f64::INFINITY, ..base() }),
            0.0
        );
        assert_eq!(composite_score(&ScoreInputs { throughput: -5.0, ..base() }), 0.0);
    }

    #[test]
    fn missing_components_are_neutral() {
        // Missing p99 (closed-loop BENCH rows): the p99 factor is 1,
        // so the score equals the same row with p99 = 0.
        let no_p99 = composite_score(&ScoreInputs { p99_ms: f64::NAN, ..base() });
        let zero_p99 = composite_score(&ScoreInputs { p99_ms: 0.0, ..base() });
        assert!((no_p99 - zero_p99).abs() < 1e-9);
        // Unchecked staleness / log growth: neutral, not zero.
        let unchecked = composite_score(&ScoreInputs {
            stale_reads: None,
            max_log_len: None,
            ..base()
        });
        assert!(unchecked > 0.0);
    }

    #[test]
    fn ranking_is_stable_across_recomputation() {
        // Scoring is a pure function: ranking a fixed row set twice
        // gives the same order (no hidden state, no clock).
        let rows: Vec<ScoreInputs> = (1..=20)
            .map(|i| ScoreInputs {
                throughput: 100.0 * i as f64,
                p50_ms: 0.1 * i as f64,
                p99_ms: 0.7 * (21 - i) as f64,
                stale_reads: Some(0),
                max_log_len: Some(500 * i as u64),
            })
            .collect();
        let rank = |rows: &[ScoreInputs]| -> Vec<usize> {
            let mut idx: Vec<usize> = (0..rows.len()).collect();
            idx.sort_by(|&a, &b| {
                composite_score(&rows[b])
                    .partial_cmp(&composite_score(&rows[a]))
                    .unwrap()
                    .then(a.cmp(&b))
            });
            idx
        };
        assert_eq!(rank(&rows), rank(&rows));
    }

    #[test]
    fn bench_row_scoring_uses_only_bench_fields() {
        let r = BenchRow {
            label: "x".into(),
            throughput: 1000.0,
            p50_ms: 0.5,
            p99_ms: f64::NAN,
            offered_per_sec: 2000.0,
        };
        let s = ScoreInputs::from_bench_row(&r);
        assert_eq!(s.stale_reads, None);
        assert_eq!(s.max_log_len, None);
        assert!(composite_score(&s) > 0.0);
    }
}
