//! Quorum systems (Flexible Paxos, §2.3).
//!
//! A configuration `C = (A; P1; P2)` is a set of acceptors `A` plus two sets
//! of quorums `P1` (Phase 1) and `P2` (Phase 2) such that every Phase 1
//! quorum intersects every Phase 2 quorum. Throughout the codebase "Paxos"
//! means Flexible Paxos: proposers gather an arbitrary P1 quorum in Phase 1
//! and an arbitrary P2 quorum in Phase 2.

use crate::util::Rng;
use crate::NodeId;
use std::collections::BTreeSet;

/// The quorum structure of a configuration, interpreted over an ordered
/// acceptor list `A`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum QuorumSpec {
    /// Simple majorities: every subset of size `⌊|A|/2⌋+1` is both a P1 and
    /// a P2 quorum. This is classic Paxos with `|A| = 2f+1`.
    Majority,
    /// Flexible quorums: any `p1` acceptors form a P1 quorum and any `p2`
    /// acceptors form a P2 quorum. Requires `p1 + p2 > |A|`.
    Flexible { p1: usize, p2: usize },
    /// The Matchmaker Fast Paxos configuration from §7: a fixed set of
    /// `f+1` acceptors with singleton P1 quorums and a single unanimous P2
    /// quorum. (Every singleton intersects the full set.)
    FastUnanimous,
    /// Fully explicit quorum lists (used by tests and by grid-style
    /// deployments). Each inner set lists acceptor *indices into `A`*.
    Explicit {
        p1: Vec<BTreeSet<usize>>,
        p2: Vec<BTreeSet<usize>>,
    },
}

impl QuorumSpec {
    /// Size threshold helpers for the counting-based specs.
    fn thresholds(&self, n: usize) -> Option<(usize, usize)> {
        match self {
            QuorumSpec::Majority => {
                let q = n / 2 + 1;
                Some((q, q))
            }
            QuorumSpec::Flexible { p1, p2 } => Some((*p1, *p2)),
            QuorumSpec::FastUnanimous => Some((1, n)),
            QuorumSpec::Explicit { .. } => None,
        }
    }

    /// Is `acked ⊆ acceptors` a Phase 1 quorum?
    pub fn is_p1_quorum(&self, acceptors: &[NodeId], acked: &BTreeSet<NodeId>) -> bool {
        self.is_quorum(acceptors, acked, true)
    }

    /// Is `acked ⊆ acceptors` a Phase 2 quorum?
    pub fn is_p2_quorum(&self, acceptors: &[NodeId], acked: &BTreeSet<NodeId>) -> bool {
        self.is_quorum(acceptors, acked, false)
    }

    fn is_quorum(&self, acceptors: &[NodeId], acked: &BTreeSet<NodeId>, phase1: bool) -> bool {
        let members: usize = acked.iter().filter(|a| acceptors.contains(a)).count();
        if let Some((q1, q2)) = self.thresholds(acceptors.len()) {
            return members >= if phase1 { q1 } else { q2 };
        }
        let QuorumSpec::Explicit { p1, p2 } = self else {
            unreachable!()
        };
        let qs = if phase1 { p1 } else { p2 };
        qs.iter().any(|q| {
            q.iter()
                .all(|&idx| idx < acceptors.len() && acked.contains(&acceptors[idx]))
        })
    }

    /// Minimum number of acceptors a thrifty leader must target so that the
    /// targeted set contains a P2 quorum (used by the thriftiness
    /// optimization, §8.1). For `Explicit` this returns the size of the
    /// smallest P2 quorum.
    pub fn min_p2_size(&self, n: usize) -> usize {
        match self.thresholds(n) {
            Some((_, q2)) => q2.min(n),
            None => {
                let QuorumSpec::Explicit { p2, .. } = self else {
                    unreachable!()
                };
                p2.iter().map(|q| q.len()).min().unwrap_or(n)
            }
        }
    }

    /// Sample a concrete P2 quorum to target (thrifty Phase 2A fan-out).
    pub fn sample_p2(&self, acceptors: &[NodeId], rng: &mut Rng) -> Vec<NodeId> {
        match self {
            QuorumSpec::Explicit { p2, .. } => {
                if p2.is_empty() {
                    return acceptors.to_vec();
                }
                let q = &p2[rng.gen_range(p2.len() as u64) as usize];
                q.iter()
                    .filter_map(|&i| acceptors.get(i).copied())
                    .collect()
            }
            _ => {
                let k = self.min_p2_size(acceptors.len());
                // Hot path (thrifty Phase 2 fan-out): partial Fisher-Yates
                // over an index bitmap instead of cloning the pool.
                let n = acceptors.len();
                if k >= n {
                    return acceptors.to_vec();
                }
                let mut picked = Vec::with_capacity(k);
                let mut idx: [usize; 16];
                if n <= 16 {
                    idx = [0; 16];
                    for (i, slot) in idx.iter_mut().enumerate().take(n) {
                        *slot = i;
                    }
                    for i in 0..k {
                        let j = i + rng.gen_range((n - i) as u64) as usize;
                        idx.swap(i, j);
                        picked.push(acceptors[idx[i]]);
                    }
                } else {
                    return rng.sample(acceptors, k);
                }
                picked
            }
        }
    }

    /// Validate the spec over an acceptor set of size `n` with descriptive
    /// errors, for configuration load time. Rejects `Flexible` specs whose
    /// quorums cannot intersect (`p1 + p2 <= n`), zero or oversized
    /// thresholds, and `Explicit` specs with empty quorum lists or
    /// acceptor indices outside `0..n` (which the membership test in
    /// [`QuorumSpec::is_p1_quorum`]/[`is_p2_quorum`](QuorumSpec::is_p2_quorum)
    /// would otherwise silently treat as unsatisfiable).
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if n == 0 {
            return Err("acceptor set is empty".into());
        }
        match self {
            QuorumSpec::Majority | QuorumSpec::FastUnanimous => Ok(()),
            QuorumSpec::Flexible { p1, p2 } => {
                if *p1 == 0 || *p2 == 0 {
                    return Err(format!(
                        "flexible quorum sizes must be positive (p1 = {p1}, p2 = {p2})"
                    ));
                }
                if *p1 > n || *p2 > n {
                    return Err(format!(
                        "flexible quorum size exceeds |A| = {n} (p1 = {p1}, p2 = {p2})"
                    ));
                }
                if p1 + p2 <= n {
                    return Err(format!(
                        "flexible quorums do not intersect: p1 + p2 = {} must exceed |A| = {n}",
                        p1 + p2
                    ));
                }
                Ok(())
            }
            QuorumSpec::Explicit { p1, p2 } => {
                for (phase, quorums) in [("P1", p1), ("P2", p2)] {
                    if quorums.is_empty() {
                        return Err(format!("{phase} quorum list is empty"));
                    }
                    for q in quorums {
                        if q.is_empty() {
                            return Err(format!("{phase} contains an empty quorum"));
                        }
                        if let Some(&bad) = q.iter().find(|&&i| i >= n) {
                            return Err(format!(
                                "{phase} quorum acceptor index {bad} is out of bounds for \
                                 |A| = {n} (indices are positions in the acceptor list)"
                            ));
                        }
                    }
                }
                if !self.intersects(n) {
                    return Err(
                        "some P1 quorum does not intersect some P2 quorum".to_string()
                    );
                }
                Ok(())
            }
        }
    }

    /// Check the Flexible Paxos intersection property: every P1 quorum
    /// intersects every P2 quorum over an acceptor set of size `n`.
    /// Used by config validation and property tests.
    pub fn intersects(&self, n: usize) -> bool {
        match self {
            QuorumSpec::Majority => n > 0,
            QuorumSpec::Flexible { p1, p2 } => *p1 > 0 && *p2 > 0 && p1 + p2 > n,
            QuorumSpec::FastUnanimous => n > 0,
            QuorumSpec::Explicit { p1, p2 } => {
                !p1.is_empty()
                    && !p2.is_empty()
                    && p1.iter().all(|q1| {
                        p2.iter().all(|q2| q1.intersection(q2).next().is_some())
                    })
            }
        }
    }
}

/// Majority count for a set of `n` nodes: `⌊n/2⌋ + 1`. Matchmaker quorums
/// (f+1 of 2f+1) and replica-ack thresholds use this.
pub fn majority(n: usize) -> usize {
    n / 2 + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(ids: &[NodeId]) -> BTreeSet<NodeId> {
        ids.iter().copied().collect()
    }

    #[test]
    fn majority_quorums() {
        let acc = [10, 11, 12];
        let q = QuorumSpec::Majority;
        assert!(!q.is_p1_quorum(&acc, &set(&[10])));
        assert!(q.is_p1_quorum(&acc, &set(&[10, 11])));
        assert!(q.is_p2_quorum(&acc, &set(&[11, 12])));
        // Foreign ids don't count.
        assert!(!q.is_p1_quorum(&acc, &set(&[10, 99])));
    }

    #[test]
    fn flexible_quorums() {
        let acc = [1, 2, 3, 4];
        let q = QuorumSpec::Flexible { p1: 3, p2: 2 };
        assert!(q.intersects(4));
        assert!(!q.is_p1_quorum(&acc, &set(&[1, 2])));
        assert!(q.is_p1_quorum(&acc, &set(&[1, 2, 3])));
        assert!(q.is_p2_quorum(&acc, &set(&[3, 4])));
        let bad = QuorumSpec::Flexible { p1: 2, p2: 2 };
        assert!(!bad.intersects(4));
    }

    #[test]
    fn fast_unanimous() {
        let acc = [1, 2];
        let q = QuorumSpec::FastUnanimous;
        assert!(q.is_p1_quorum(&acc, &set(&[2])));
        assert!(!q.is_p2_quorum(&acc, &set(&[2])));
        assert!(q.is_p2_quorum(&acc, &set(&[1, 2])));
        assert!(q.intersects(2));
    }

    #[test]
    fn explicit_quorums() {
        // 2x2 grid: P1 = rows, P2 = columns.
        let acc = [0, 1, 2, 3];
        let q = QuorumSpec::Explicit {
            p1: vec![set_usize(&[0, 1]), set_usize(&[2, 3])],
            p2: vec![set_usize(&[0, 2]), set_usize(&[1, 3])],
        };
        assert!(q.intersects(4));
        assert!(q.is_p1_quorum(&acc, &set(&[0, 1])));
        assert!(!q.is_p1_quorum(&acc, &set(&[0, 2])));
        assert!(q.is_p2_quorum(&acc, &set(&[1, 3])));
        assert!(!q.is_p2_quorum(&acc, &set(&[0, 1])));
    }

    fn set_usize(ids: &[usize]) -> BTreeSet<usize> {
        ids.iter().copied().collect()
    }

    #[test]
    fn thrifty_sampling_yields_p2_quorum() {
        let mut rng = Rng::new(1);
        let acc = [5, 6, 7, 8, 9];
        for q in [
            QuorumSpec::Majority,
            QuorumSpec::Flexible { p1: 4, p2: 2 },
            QuorumSpec::FastUnanimous,
        ] {
            for _ in 0..20 {
                let picked = q.sample_p2(&acc, &mut rng);
                assert!(q.is_p2_quorum(&acc, &picked.iter().copied().collect()));
            }
        }
    }

    #[test]
    fn validate_rejects_bad_flexible() {
        assert!(QuorumSpec::Flexible { p1: 2, p2: 2 }.validate(4).is_err());
        assert!(QuorumSpec::Flexible { p1: 0, p2: 3 }.validate(3).is_err());
        assert!(QuorumSpec::Flexible { p1: 5, p2: 1 }.validate(3).is_err());
        QuorumSpec::Flexible { p1: 3, p2: 2 }.validate(4).unwrap();
        let err = QuorumSpec::Flexible { p1: 1, p2: 2 }.validate(4).unwrap_err();
        assert!(err.contains("must exceed |A| = 4"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_explicit() {
        let oob = QuorumSpec::Explicit {
            p1: vec![set_usize(&[0, 4])],
            p2: vec![set_usize(&[0, 1])],
        };
        let err = oob.validate(3).unwrap_err();
        assert!(err.contains("out of bounds"), "{err}");
        let empty = QuorumSpec::Explicit { p1: vec![], p2: vec![set_usize(&[0])] };
        assert!(empty.validate(3).is_err());
        let empty_q = QuorumSpec::Explicit {
            p1: vec![set_usize(&[])],
            p2: vec![set_usize(&[0])],
        };
        assert!(empty_q.validate(3).is_err());
        let disjoint = QuorumSpec::Explicit {
            p1: vec![set_usize(&[0])],
            p2: vec![set_usize(&[1])],
        };
        assert!(disjoint.validate(3).is_err());
        // The 2x2 grid from `explicit_quorums` is valid.
        QuorumSpec::Explicit {
            p1: vec![set_usize(&[0, 1]), set_usize(&[2, 3])],
            p2: vec![set_usize(&[0, 2]), set_usize(&[1, 3])],
        }
        .validate(4)
        .unwrap();
    }

    #[test]
    fn validate_counting_specs() {
        QuorumSpec::Majority.validate(3).unwrap();
        QuorumSpec::FastUnanimous.validate(2).unwrap();
        assert!(QuorumSpec::Majority.validate(0).is_err());
    }

    #[test]
    fn majority_fn() {
        assert_eq!(majority(1), 1);
        assert_eq!(majority(3), 2);
        assert_eq!(majority(4), 3);
        assert_eq!(majority(5), 3);
    }
}
