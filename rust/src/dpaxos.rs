//! Reproduction of the DPaxos garbage-collection bug (§7).
//!
//! The paper discovered that DPaxos [30] — a Paxos variant in which every
//! ballot may use a different subset of acceptors, with "intents" recorded
//! during leader election — has an unsafe garbage collection protocol: the
//! scripted 3-zone scenario in §7 chooses *two different values*. This
//! module contains (a) a miniature DPaxos engine faithful to the fragment
//! the counter-example needs, (b) the exact §7 schedule, asserting the
//! divergence, and (c) the same schedule run through Matchmaker Paxos
//! machinery, where GC is simply not permitted at that point and the
//! second value can never be chosen.
//!
//! DPaxos deployment in the trace: `f_d = 1, f_z = 0`, three zones of
//! three nodes (A..I), delegate quorums — a replication quorum is two
//! nodes in one zone, a leader-election quorum is two nodes in each of two
//! zones.

use std::collections::{BTreeMap, BTreeSet};

/// Node names A..I as indices 0..9.
pub type DNode = usize;

/// One DPaxos acceptor's state.
#[derive(Clone, Debug, Default)]
pub struct DState {
    /// Highest ballot promised.
    pub ballot: i64,
    /// Ballot of the last accepted value.
    pub vote_ballot: i64,
    /// Last accepted value.
    pub vote_value: Option<char>,
    /// Intents observed: (ballot, replication quorum).
    pub intents: Vec<(i64, BTreeSet<DNode>)>,
}

/// The miniature DPaxos engine. Message loss is modeled by the caller
/// simply not invoking `accept` on a node.
pub struct DPaxos {
    pub nodes: Vec<DState>,
    /// All values ever chosen (ballot → value): the safety observable.
    pub chosen: BTreeMap<i64, char>,
}

impl Default for DPaxos {
    fn default() -> Self {
        Self::new()
    }
}

impl DPaxos {
    pub fn new() -> DPaxos {
        DPaxos {
            nodes: vec![
                DState { ballot: -1, vote_ballot: -1, ..Default::default() };
                9
            ],
            chosen: BTreeMap::new(),
        }
    }

    /// Leader election (prepare) in `ballot` with leader-election quorum
    /// `quorum` and intent `intent`. Returns the set of intents reported by
    /// the contacted nodes (for quorum expansion) and the highest-ballot
    /// accepted value seen.
    pub fn prepare(
        &mut self,
        ballot: i64,
        quorum: &BTreeSet<DNode>,
        intent: &BTreeSet<DNode>,
    ) -> (Vec<(i64, BTreeSet<DNode>)>, Option<(i64, char)>) {
        let mut reported: Vec<(i64, BTreeSet<DNode>)> = Vec::new();
        let mut best: Option<(i64, char)> = None;
        for &n in quorum {
            let st = &mut self.nodes[n];
            if st.ballot > ballot {
                continue; // refuses
            }
            st.ballot = ballot;
            for it in &st.intents {
                if it.0 < ballot {
                    reported.push(it.clone());
                }
            }
            if let Some(v) = st.vote_value {
                if best.map_or(true, |(b, _)| st.vote_ballot > b) {
                    best = Some((st.vote_ballot, v));
                }
            }
            // Record the new intent.
            st.intents.push((ballot, intent.clone()));
        }
        (reported, best)
    }

    /// Send an accept (propose) for `value` in `ballot` to one node.
    /// Returns true if the node accepted.
    pub fn accept(&mut self, ballot: i64, node: DNode, value: char) -> bool {
        let st = &mut self.nodes[node];
        if st.ballot > ballot {
            return false;
        }
        st.ballot = ballot;
        st.vote_ballot = ballot;
        st.vote_value = Some(value);
        true
    }

    /// A value is chosen once every node of a replication quorum accepted
    /// it in the same ballot. The caller declares it after driving accepts.
    pub fn declare_chosen(&mut self, ballot: i64, quorum: &BTreeSet<DNode>) -> Option<char> {
        let mut val: Option<char> = None;
        for &n in quorum {
            let st = &self.nodes[n];
            if st.vote_ballot != ballot {
                return None;
            }
            match (val, st.vote_value) {
                (None, v) => val = v,
                (Some(a), Some(b)) if a == b => {}
                _ => return None,
            }
        }
        if let Some(v) = val {
            self.chosen.insert(ballot, v);
        }
        val
    }

    /// DPaxos's (buggy) garbage collection: once *some* node is seen to
    /// have accepted in `ballot`, all intents in ballots `< ballot` are
    /// discarded everywhere.
    pub fn garbage_collect(&mut self, ballot: i64) {
        for st in &mut self.nodes {
            st.intents.retain(|(b, _)| *b >= ballot);
        }
    }

    /// True iff two distinct values appear in `chosen` — the safety
    /// violation.
    pub fn diverged(&self) -> bool {
        let vals: BTreeSet<char> = self.chosen.values().copied().collect();
        vals.len() > 1
    }
}

/// Node name helper: 'A' → 0, ... 'I' → 8.
pub fn n(c: char) -> DNode {
    (c as u8 - b'A') as usize
}

fn set(names: &str) -> BTreeSet<DNode> {
    names.chars().map(n).collect()
}

/// Replay the exact §7 counter-example. Returns the engine afterwards;
/// `diverged()` is true — x is chosen in ballot 0 AND z in ballot 2.
pub fn replay_bug() -> DPaxos {
    let mut d = DPaxos::new();

    // Proposer 1, ballot 0, value x: LE quorum {A,B,D,E}, intent {B,C}.
    let (intents, best) = d.prepare(0, &set("ABDE"), &set("BC"));
    assert!(intents.is_empty() && best.is_none());
    // No prior value: proposes x to B and C; both accept; x chosen.
    assert!(d.accept(0, n('B'), 'x'));
    assert!(d.accept(0, n('C'), 'x'));
    assert_eq!(d.declare_chosen(0, &set("BC")), Some('x'));

    // Proposer 2, ballot 1, value y: LE quorum {E,F,H,I}, intent {G,H}.
    let (intents, _) = d.prepare(1, &set("EFHI"), &set("GH"));
    // E reports the intent {B,C} from ballot 0 → expand to C.
    assert!(intents.iter().any(|(b, q)| *b == 0 && *q == set("BC")));
    let (_, best) = d.prepare(1, &set("C"), &set("GH"));
    // Learns x was accepted in ballot 0 → ditches y, proposes x.
    assert_eq!(best, Some((0, 'x')));
    assert!(d.accept(1, n('G'), 'x'));
    // The propose message to H is dropped (we simply don't deliver it).

    // Garbage collection: sees G accepted in ballot 1, discards all
    // intents in ballots < 1 — THE BUG: x's intent {B,C} is forgotten
    // even though x was only *partially* accepted in ballot 1.
    d.garbage_collect(1);

    // Proposer 3, ballot 2, value z: LE quorum {D,E,H,I}, intent {E,F}.
    let (intents, best) = d.prepare(2, &set("DEHI"), &set("EF"));
    // It sees intent {G,H} (ballot 1) but H is already in its LE quorum,
    // so no expansion. The ballot-0 intent {B,C} is gone.
    assert!(intents.iter().all(|(b, _)| *b >= 1));
    // H never accepted, G is not contacted → no accepted value visible.
    assert_eq!(best, None);
    // Proposer 3 believes nothing was chosen and proposes z to E and F...
    assert!(d.accept(2, n('E'), 'z'));
    assert!(d.accept(2, n('F'), 'z'));
    // ...and z is chosen. But x was already chosen in ballot 0!
    assert_eq!(d.declare_chosen(2, &set("EF")), Some('z'));
    d
}

/// The same schedule through Matchmaker Paxos roles: the matchmakers'
/// refusal discipline + the §5 GC scenarios make the divergence
/// impossible — proposer 3 *must* learn x. Returns every value chosen.
pub fn replay_matchmaker() -> Vec<crate::msg::Value> {
    use crate::config::Configuration;
    use crate::msg::{Command, Msg, Value};
    use crate::node::{Announce, Effects, Node};
    use crate::roles::{Acceptor, Matchmaker, Proposer};
    use crate::NodeId;
    use std::collections::VecDeque;

    // ids: matchmakers 1..3, acceptors 10..18 map to A..I.
    let mms: Vec<NodeId> = vec![1, 2, 3];
    let acc_id = |c: char| 10 + n(c) as NodeId;
    let mut mm_nodes: Vec<Matchmaker> = mms.iter().map(|&i| Matchmaker::new(i)).collect();
    let mut acc_nodes: BTreeMap<NodeId, Acceptor> =
        "ABCDEFGHI".chars().map(|c| (acc_id(c), Acceptor::new(acc_id(c)))).collect();

    let val = |tag: u8| Value::Cmd(Command { client: 100, seq: tag as u64, payload: vec![tag] });
    let cfg = |id: u64, names: &str| {
        Configuration::majority(id, names.chars().map(acc_id).collect())
    };

    let mut chosen: Vec<Value> = Vec::new();

    // Synchronous pump with a drop-filter on (to, round-agnostic) pairs.
    let run = |p: &mut Proposer, pid: NodeId, fx: Effects, drop_to: &[NodeId],
                   mm_nodes: &mut Vec<Matchmaker>,
                   acc_nodes: &mut BTreeMap<NodeId, Acceptor>,
                   chosen: &mut Vec<Value>| {
        let mut q: VecDeque<(NodeId, NodeId, Msg)> = VecDeque::new();
        for (to, m) in fx.msgs {
            q.push_back((pid, to, m));
        }
        while let Some((from, to, msg)) = q.pop_front() {
            if drop_to.contains(&to) && matches!(msg, Msg::Phase2A { .. }) {
                continue; // the dropped propose message
            }
            let mut fx = Effects::new();
            if to == pid {
                p.on_msg(0, from, msg, &mut fx);
            } else if let Some(i) = mms.iter().position(|&m| m == to) {
                mm_nodes[i].on_msg(0, from, msg, &mut fx);
            } else if let Some(a) = acc_nodes.get_mut(&to) {
                a.on_msg(0, from, msg, &mut fx);
            }
            for a in fx.announces {
                if let Announce::Chosen { value, .. } = a {
                    chosen.push(value);
                }
            }
            for (dst, m) in fx.msgs {
                q.push_back((to, dst, m));
            }
        }
    };

    // Proposer 1 (id 20): round (0,20,0), config {B,C}, value x.
    let mut p1 = Proposer::new(20, 1, mms.clone(), cfg(0, "BC"));
    let mut fx = Effects::new();
    p1.propose(val(1), cfg(0, "BC"), 0, &mut fx);
    run(&mut p1, 20, fx, &[], &mut mm_nodes, &mut acc_nodes, &mut chosen);
    assert_eq!(p1.chosen, Some(val(1))); // x chosen

    // Proposer 2 (id 21): higher round, config {G,H}; its Phase2A to H is
    // dropped. It learns x via Phase 1 (through C0 = {B,C}) and proposes x
    // — but x is NOT chosen in this round (G only).
    // Crucially, Matchmaker Paxos gives proposer 2 no legal way to GC:
    // Scenario 1 (chosen in its round) fails, Scenario 2 (k = -1) fails,
    // Scenario 3 requires informing a P2 quorum of {G,H} — impossible with
    // H unreachable. So no GarbageA is sent.
    let mut p2 = Proposer::new(21, 1, mms.clone(), cfg(1, "GH"));
    let mut fx = Effects::new();
    p2.propose(val(2), cfg(1, "GH"), 0, &mut fx);
    run(&mut p2, 21, fx, &[acc_id('H')], &mut mm_nodes, &mut acc_nodes, &mut chosen);
    assert_eq!(p2.chosen, None); // stuck: H's vote never arrives

    // Proposer 3 (id 22): round above p2's, config {E,F}, value z. The
    // matchmakers return H = {C0, C1}; Phase 1 intersects {B,C} (and
    // {G,H}) and discovers x. Proposer 3 proposes x, not z.
    let mut p3 = Proposer::new(22, 1, mms.clone(), cfg(2, "EF"));
    let mut fx = Effects::new();
    p3.propose(val(3), cfg(2, "EF"), 0, &mut fx);
    run(&mut p3, 22, fx, &[], &mut mm_nodes, &mut acc_nodes, &mut chosen);
    assert_eq!(p3.chosen, Some(val(1))); // x again — no divergence

    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dpaxos_bug_reproduces() {
        let d = replay_bug();
        assert!(d.diverged(), "the §7 schedule must choose two values");
        assert_eq!(d.chosen[&0], 'x');
        assert_eq!(d.chosen[&2], 'z');
    }

    #[test]
    fn matchmaker_fixes_the_schedule() {
        let chosen = replay_matchmaker();
        assert!(!chosen.is_empty());
        let first = &chosen[0];
        assert!(
            chosen.iter().all(|v| v == first),
            "matchmaker run must never diverge: {chosen:?}"
        );
    }

    #[test]
    fn dpaxos_without_gc_is_safe_on_this_schedule() {
        // Control: the same schedule *without* the GC step does not
        // diverge — proposer 3 would see the {B,C} intent and expand.
        let mut d = DPaxos::new();
        d.prepare(0, &set("ABDE"), &set("BC"));
        d.accept(0, n('B'), 'x');
        d.accept(0, n('C'), 'x');
        d.declare_chosen(0, &set("BC"));
        let (intents, _) = d.prepare(1, &set("EFHI"), &set("GH"));
        assert!(intents.iter().any(|(b, _)| *b == 0));
        let (_, best) = d.prepare(1, &set("C"), &set("GH"));
        assert_eq!(best, Some((0, 'x')));
        d.accept(1, n('G'), 'x');
        // NO garbage collection here.
        let (intents, _) = d.prepare(2, &set("DEHI"), &set("EF"));
        // The ballot-0 intent {B,C} is visible → proposer 3 expands to B/C
        // and learns x.
        assert!(intents.iter().any(|(b, q)| *b == 0 && *q == set("BC")));
        let (_, best) = d.prepare(2, &set("BC"), &set("EF"));
        assert_eq!(best.map(|(_, v)| v), Some('x'));
    }
}
