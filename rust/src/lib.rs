//! # Matchmaker Paxos / Matchmaker MultiPaxos
//!
//! A production-quality reproduction of *"Matchmaker Paxos: A Reconfigurable
//! Consensus Protocol"* (Whittaker et al., 2020): a reconfigurable consensus
//! protocol (Matchmaker Paxos), a reconfigurable state machine replication
//! protocol (Matchmaker MultiPaxos), the paper's optimizations, garbage
//! collection of retired configurations, matchmaker reconfiguration, and the
//! baselines the paper compares against (MultiPaxos with horizontal
//! reconfiguration; stop-the-world reconfiguration via the ablation flags).
//!
//! ## Architecture
//!
//! Protocol logic is written *sans-io*: every role (acceptor, matchmaker,
//! leader, replica, client, ...) is a pure state machine implementing
//! [`node::Node`] — it consumes messages and timer expirations and emits
//! [`node::Effects`] (outbound messages, timer requests, announcements).
//! The same role code is driven by two harnesses:
//!
//! * [`sim`] — a deterministic discrete-event simulator with virtual time,
//!   per-link delay models, message drops, partitions, and crash/restart
//!   failure injection. All of the paper's evaluation (§8) is regenerated on
//!   this substrate (see [`harness`]).
//! * [`net`] — a TCP runtime (std::net + threads) for real multi-process deployments
//!   (`repro run --role ...`).
//!
//! Steady-state Phase 2 is batched and pipelined: with
//! `OptFlags::batch_size > 1` the leader packs up to `batch_size` client
//! commands into one slot ([`msg::Value::Batch`]), so a single quorum
//! round trip chooses a whole batch; replicas unpack batches and execute
//! them through `StateMachine::apply_many`, replying per command.
//!
//! ## Sharding
//!
//! Past one leader's ceiling, a [`harness::ShardedCluster`] runs N
//! independent consensus groups ([`GroupId`]) — own leader, acceptors,
//! and replicas each — behind **one shared matchmaker set** (§6: a
//! single matchmaker set serves many protocol instances; the log is
//! keyed `(group, round)` with per-group GC). Clients route keys to
//! groups by hash ([`roles::router::ShardClient`]); per-shard
//! exactly-once/FIFO and per-key linearizability are property-tested
//! under concurrent multi-group reconfiguration storms. The X6
//! experiment (`repro exp x6`) gates ≥ 2.5x aggregate throughput at 4
//! groups. See DESIGN.md §Sharding.
//!
//! ## Linearizable reads
//!
//! Read-heavy workloads skip the Phase-2 hot path entirely: clients
//! classify a fraction of requests as read-only (the
//! [`workload::WorkloadSpec`] `read_fraction` knob) and send them to
//! **replicas** ([`msg::Msg::Read`]), which
//! answer from local state ([`statemachine::StateMachine::query`]) once
//! their applied prefix covers a *read index*. With read leases enabled
//! ([`config::LeaseSpec`], `leases =` config line) the leader keeps a
//! quorum-confirmed leadership lease alive and continuously pushes its
//! chosen watermark to the replicas ([`msg::Msg::LeaseGrant`]), so a
//! leased read costs the leader nothing; without a lease the replica
//! falls back to a one-message ReadIndex, still linearizable. The
//! paper's reconfiguration machinery is what makes naive leases unsafe
//! — renewals are fenced by P1/P2 quorum intersection and a new leader
//! waits out the old lease before its first proposal; see DESIGN.md
//! §Reads. The X7 experiment (`repro exp x7`) gates a ≥ 2x aggregate
//! win for a 90/10 mix over the all-through-Phase-2 baseline, with
//! every read checked against the global write history.
//!
//! ## State retention
//!
//! Long runs are memory-bounded by the state-retention subsystem
//! ([`config::SnapshotSpec`]): replicas snapshot their
//! [`statemachine::StateMachine`] periodically and truncate the chosen
//! log below the snapshot watermark; lagging or freshly joined replicas
//! catch up via snapshot-plus-tail transfer from a peer
//! ([`msg::Msg::SnapshotResp`]); the leader truncates its own log at the
//! f+1-durable watermark and continuously propagates it to the acceptors
//! so voted state is dropped in steady state (the replica/acceptor half
//! of the paper's §5 garbage-collection story). See DESIGN.md for the
//! full walkthrough.
//!
//! ## Workloads
//!
//! Clusters are described with a builder and driven by a
//! [`workload::WorkloadSpec`]. The README quickstart, runnable (this
//! example executes in the deterministic simulator in a few ms of wall
//! clock):
//!
//! ```
//! use matchmaker::harness::{msec, Cluster};
//! use matchmaker::sim::NetworkModel;
//! use matchmaker::workload::WorkloadSpec;
//!
//! let mut cluster = Cluster::builder()
//!     .f(1)
//!     .clients(2)
//!     .workload(WorkloadSpec::pipelined(4))
//!     .net(NetworkModel::lan())
//!     .seed(7)
//!     .build();
//! cluster.sim.run_until(msec(500));
//! cluster.assert_safe();
//! assert!(!cluster.samples().is_empty());
//! ```
//!
//! [`WorkloadSpec::closed_loop`] reproduces the paper's §8.1 client
//! (one outstanding request, so the numbers stay comparable);
//! [`WorkloadSpec::pipelined`] keeps a window of `k` requests in flight
//! with per-client FIFO preserved end to end (the leader's
//! [`roles::sequencer`] re-orders what the network shuffles); the
//! open-loop modes offer load at a configured rate — fixed or
//! deterministic-Poisson — independent of completions, which is what
//! exposes saturation and tail latency (X4 experiment,
//! [`metrics::OpenLoopSummary`]).
//!
//! Replicas execute commands against a pluggable [`statemachine`]; the
//! `TensorStateMachine` executes batched commands through an AOT-compiled
//! JAX/Pallas computation loaded via PJRT ([`runtime`], `pjrt` feature) or
//! through a bit-identical pure-Rust reference backend (default build),
//! proving the three-layer Rust + JAX + Pallas stack composes with Python
//! never on the request path.

pub mod check;
pub mod codec;
pub mod config;
pub mod discovery;
pub mod dpaxos;
pub mod harness;
pub mod metrics;
pub mod msg;
pub mod nemesis;
pub mod net;
pub mod node;
pub mod quorum;
pub mod roles;
pub mod round;
pub mod runtime;
pub mod sim;
pub mod statemachine;
pub mod storage;
pub mod sweep;
pub mod util;
pub mod workload;

pub use config::{Configuration, DeploymentConfig};
pub use msg::{Command, CommandId, Envelope, MmLog, Msg, Value};
pub use node::{Announce, Effects, Node, Timer};
pub use quorum::QuorumSpec;
pub use round::Round;
pub use workload::{PayloadSpec, WorkloadMode, WorkloadSpec};

/// A node identifier. Node ids are dense small integers assigned by the
/// deployment config; the simulator indexes nodes by id.
pub type NodeId = u32;

/// A consensus-group (shard) identifier. A sharded deployment
/// ([`harness::ShardedCluster`]) runs many independent Matchmaker
/// MultiPaxos groups — each with its own leader, acceptors, and replicas
/// — against a **single shared matchmaker set** (§6: one matchmaker set
/// can serve reconfigurations for many protocol instances). Matchmaker
/// log entries are keyed by `(group, round)` with per-group GC
/// watermarks, and the client role routes commands to groups by key
/// hash. Single-group deployments use group `0` everywhere.
pub type GroupId = u32;

/// A log slot (MultiPaxos instance index).
pub type Slot = u64;

/// Virtual or wall-clock time in nanoseconds since harness start.
pub type Time = u64;

/// Nanoseconds per millisecond, for readable experiment scripts.
pub const MS: Time = 1_000_000;
/// Nanoseconds per microsecond.
pub const US: Time = 1_000;
/// Nanoseconds per second.
pub const SEC: Time = 1_000_000_000;
