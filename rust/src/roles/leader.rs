//! The Matchmaker MultiPaxos leader (§4, §5.3, §6).
//!
//! The leader pipelines three phases per round: **Matchmaking** (learn the
//! prior configurations `H_i` from f+1 matchmakers), **Phase 1** (intersect
//! a P1 quorum of every configuration in `H_i`), and steady-state
//! **Phase 2** with its own configuration `C_i`. Reconfiguration is "baked
//! in" (§4.3): to move from `C_old` in round `i` to `C_new`, the leader
//! advances to round `i+1 = (epoch, id, seq+1)` and re-runs Matchmaking —
//! with **Optimization 1** (proactive matchmaking) commands keep flowing to
//! `C_old` during matchmaking, and with **Optimization 2** (Phase 1
//! bypassing) Phase 1 is skipped entirely for the empty log suffix, so no
//! command is ever delayed (§4.4, Figure 6).
//!
//! Steady-state Phase 2 is **batched and pipelined**: with
//! `OptFlags::batch_size > 1` the leader accumulates client commands into
//! a per-slot [`Value::Batch`] (flushed when full or after
//! `OptFlags::batch_delay`), so one quorum round trip chooses up to
//! `batch_size` commands; slots are proposed without waiting for earlier
//! slots to be chosen (no α window), so any number of batches are in
//! flight concurrently. Batches keep flowing through reconfigurations: a
//! batch proposed in `C_old` during matchmaking (Optimization 1)
//! completes in its original round, and the Phase 2 watchdog re-proposes
//! the *same* batch in the new round if the old configuration stops
//! answering — replicas deduplicate per command, so every command
//! executes exactly once, in per-client FIFO order.
//!
//! The leader also drives configuration retirement (§5.3): once every log
//! entry below the reconfiguration barrier is chosen, stored on f+1
//! replicas, and a P2 quorum of the new configuration has been told so
//! (`PrefixPersisted`), it issues `GarbageA⟨i⟩` and, after f+1 `GarbageB`s,
//! the old acceptors can shut down.
//!
//! Finally, the leader implements matchmaker reconfiguration (§6):
//! stop-and-copy of the matchmaker state plus a meta-Paxos (with the old
//! matchmakers as acceptors) choosing the new matchmaker set.
//!
//! With snapshotting enabled ([`crate::config::SnapshotSpec`]) the leader
//! also drives steady-state retention: it continuously propagates the
//! f+1-durable chosen watermark to the acceptors (`PrefixPersisted`, so
//! vote state is dropped between reconfigurations, not only at GC
//! barriers), truncates its own log and command→slot map below
//! `watermark - tail`, and points replicas whose acks fall below the
//! truncated prefix at a caught-up peer for snapshot transfer
//! (`CatchUp`).

use super::sequencer::{ClientSequencer, Offered};
use crate::config::{Configuration, OptFlags};
use crate::msg::{Command, MmLog, Msg, Value};
use crate::node::{Announce, Effects, Node, Timer};
use crate::round::Round;
use crate::storage::{Storage, WalRecord};
use crate::util::Rng;
use crate::{GroupId, NodeId, Slot, Time, MS, US};
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

/// Timing knobs. All values are virtual-time nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct LeaderTiming {
    /// Resend Matchmaking / Phase 1 messages if quorums stall.
    pub phase_resend: Time,
    /// Thrifty Phase 2 fallback: re-send Phase2A to all acceptors if the
    /// sampled quorum hasn't answered (§8.1 thriftiness trade-off).
    pub phase2_retry: Time,
    /// Heartbeat period (leader → proposers).
    pub heartbeat_period: Time,
    /// Follower checks leader liveness this often.
    pub leader_check_period: Time,
    /// Follower declares the leader dead after this much heartbeat silence.
    pub election_timeout: Time,
    /// A leader with unchosen in-flight slots and no chosen-watermark
    /// progress for this long has lost quorum contact (it is on the
    /// minority side of a partition): it steps down instead of stalling
    /// proposals forever, so clients get `NotLeader` redirects and the
    /// majority side can elect (DESIGN.md §Nemesis).
    pub quorum_loss_timeout: Time,
}

impl Default for LeaderTiming {
    fn default() -> Self {
        LeaderTiming {
            phase_resend: 50 * MS,
            phase2_retry: 25 * MS,
            heartbeat_period: 20 * MS,
            leader_check_period: 50 * MS,
            election_timeout: 500 * MS,
            quorum_loss_timeout: 500 * MS,
        }
    }
}

/// Per-slot Phase 2 bookkeeping.
#[derive(Clone, Debug)]
struct SlotState {
    value: Value,
    /// Round in which we proposed this slot. In-flight slots from before a
    /// bypassed reconfiguration keep completing in their original round
    /// against the *old* configuration (§4.4 Case 1).
    round: Round,
    acks: BTreeSet<NodeId>,
    chosen: bool,
    /// Guards stale retries against re-proposed slots.
    generation: u64,
    /// When the last Phase2A fan-out for this slot was sent (watchdog).
    proposed_at: Time,
}

/// Sample window for the adaptive-batching controller. Small enough that
/// the p99 estimate tracks a load step within a few dozen chosen slots,
/// large enough that one straggler cannot flip the knobs.
const TUNE_WINDOW: usize = 64;
/// Re-tune every this many samples (not every sample — adjusting at the
/// window cadence lets each knob change be observed before the next).
const TUNE_EVERY: usize = 16;

/// Latency-targeted adaptive batching controller (DESIGN.md §Overload).
///
/// Tracks a sliding window of proposal→chosen latencies and nudges the
/// *effective* batch size / flush delay between hard bounds to hold the
/// configured [`crate::config::AdmissionSpec::target_p99_us`] SLO: when
/// the windowed p99 runs hot the controller grows batches (amortizing one
/// quorum round trip over more commands drains the queue faster) and
/// flushes promptly; when it runs comfortably cold it shrinks batches
/// back toward 1 (stop paying batching latency for throughput headroom
/// that is not needed). Multiplicative increase / additive decrease plus
/// a ±10% hysteresis band around the target keep the knobs from
/// oscillating on a step load change.
///
/// Identity when admission is disabled: `effective_*` return the
/// configured knobs verbatim and `observe` is a no-op, so runs without an
/// `admission =` config line behave exactly as before this controller
/// existed.
#[derive(Debug)]
pub(crate) struct BatchTuner {
    enabled: bool,
    /// SLO target in virtual-time ns.
    target: Time,
    /// Configured knobs (the bounds: batch ∈ [1, cfg_batch], delay ∈
    /// [cfg_delay/16, cfg_delay]).
    cfg_batch: usize,
    cfg_delay: Time,
    /// Live knobs (admission enabled only).
    batch: usize,
    delay: Time,
    /// Sliding latency window (ring buffer).
    window: Vec<Time>,
    cursor: usize,
    since_adjust: usize,
}

impl BatchTuner {
    pub(crate) fn new(opts: &OptFlags) -> BatchTuner {
        BatchTuner {
            enabled: opts.admission.enabled,
            target: opts.admission.target_p99_us.max(1) * US,
            cfg_batch: opts.batch_size,
            cfg_delay: opts.batch_delay,
            batch: opts.batch_size.max(1),
            delay: opts.batch_delay.max(1),
            window: Vec::new(),
            cursor: 0,
            since_adjust: 0,
        }
    }

    /// Record one proposal→chosen latency sample; re-tunes every
    /// [`TUNE_EVERY`] samples. No-op while admission is disabled.
    pub(crate) fn observe(&mut self, latency: Time) {
        if !self.enabled {
            return;
        }
        if self.window.len() < TUNE_WINDOW {
            self.window.push(latency);
        } else {
            self.window[self.cursor] = latency;
        }
        self.cursor = (self.cursor + 1) % TUNE_WINDOW;
        self.since_adjust += 1;
        if self.since_adjust >= TUNE_EVERY {
            self.since_adjust = 0;
            self.adjust();
        }
    }

    /// Windowed p99 of proposal→chosen latency (nearest-rank; 0 until the
    /// first sample or with admission disabled).
    pub(crate) fn windowed_p99(&self) -> Time {
        if self.window.is_empty() {
            return 0;
        }
        let mut sorted = self.window.clone();
        sorted.sort_unstable();
        sorted[(sorted.len() * 99 + 99) / 100 - 1]
    }

    fn min_delay(&self) -> Time {
        (self.cfg_delay / 16).max(1)
    }

    fn adjust(&mut self) {
        let p99 = self.windowed_p99();
        let band = self.target / 10;
        if p99 > self.target + band {
            // Hot: amortize harder (multiplicative) and flush promptly —
            // under queueing overload, throughput is the path back to the
            // latency target.
            self.batch = self.batch.saturating_mul(2).min(self.cfg_batch.max(1));
            self.delay = (self.delay / 2).max(self.min_delay());
        } else if p99 + band < self.target {
            // Cold: back off gently (additive) toward minimal batching —
            // small batches minimize per-command latency.
            self.batch = self.batch.saturating_sub(1).max(1);
            self.delay = (self.delay + self.cfg_delay / 8 + 1).min(self.cfg_delay.max(1));
        }
        // Inside the ±10% band: hold (hysteresis, no oscillation).
    }

    /// The batch-size knob Phase 2 should use right now.
    pub(crate) fn effective_batch_size(&self) -> usize {
        if self.enabled {
            self.batch
        } else {
            self.cfg_batch
        }
    }

    /// The flush-delay knob Phase 2 should use right now.
    pub(crate) fn effective_batch_delay(&self) -> Time {
        if self.enabled {
            self.delay
        } else {
            self.cfg_delay
        }
    }
}

/// Installation state for the round being established.
#[derive(Debug)]
enum Install {
    /// Steady state: Phase 2 in `active_round`.
    None,
    /// Matchmaking phase: collecting f+1 MatchB.
    Matchmaking {
        acks: BTreeMap<NodeId, (Option<Round>, BTreeMap<Round, Configuration>)>,
        /// Whether Optimization 2 may skip Phase 1 after matchmaking.
        bypass: bool,
        /// Optimization 5: Phase1Bs that raced ahead of the MatchBs
        /// (concurrent Matchmaking + Phase 1 on a leader change), replayed
        /// once the prior configurations are known.
        early_p1: Vec<(NodeId, Vec<crate::msg::SlotVote>, Slot)>,
    },
    /// Phase 1: collecting P1 quorums from every configuration in `prior`.
    Phase1 {
        prior: BTreeMap<Round, Configuration>,
        /// round → acceptors that sent Phase1B for our round.
        acked: BTreeSet<NodeId>,
        /// Merged votes: slot → (vr, vv) with the largest vr per slot.
        votes: BTreeMap<Slot, (Round, Value)>,
        /// Largest chosen watermark reported by any acceptor.
        acc_watermark: Slot,
    },
    /// Phase 1 is complete on a *leader change* with read leases
    /// enabled: hold every Phase-2 proposal (re-proposals included —
    /// replicas execute and ack re-chosen values, which would be new
    /// acknowledgements invisible to a still-valid old lease) until the
    /// previous leader's possible leases have expired
    /// (`Timer::LeaseFence`; DESIGN.md §Reads).
    LeaseFence {
        /// The merged Phase-1 votes, re-proposed when the fence lifts.
        votes: BTreeMap<Slot, (Round, Value)>,
        /// Largest chosen watermark reported by any acceptor.
        acc_watermark: Slot,
        /// Absolute fence deadline. A stale `LeaseFence` timer from an
        /// earlier leadership stint must not lift a newer fence early.
        until: Time,
    },
}

/// Garbage-collection driver state (§5.3).
#[derive(Debug, PartialEq)]
enum GcStage {
    Idle,
    /// Wait for all slots `< barrier` chosen & persisted on f+1 replicas.
    WaitPrefix,
    /// `PrefixPersisted(barrier)` sent; waiting for a P2 quorum of acks.
    WaitPrefixAck { acks: BTreeSet<NodeId> },
    /// `GarbageA(round)` sent; waiting for f+1 GarbageB.
    WaitGarbageB { acks: BTreeSet<NodeId> },
    Done,
}

#[derive(Debug)]
struct GcState {
    round: Round,
    /// Slots `< barrier` may hold values from rounds `< round` and must be
    /// secured before `GarbageA(round)` (§5.3).
    barrier: Slot,
    stage: GcStage,
}

/// Matchmaker-reconfiguration driver state (§6).
#[derive(Debug)]
enum MmStage {
    /// StopA sent to the old set; collecting f+1 StopB (multi-group logs
    /// + per-group GC watermarks).
    Stopping {
        acks: BTreeMap<NodeId, (MmLog, BTreeMap<GroupId, Round>)>,
    },
    /// Bootstrap sent to the new set; collecting acks from all of them.
    Bootstrapping { acks: BTreeSet<NodeId> },
    /// Meta-Paxos Phase 1 with the old matchmakers as acceptors.
    MetaPhase1 { round: Round, acks: BTreeMap<NodeId, (Option<Round>, Option<Vec<NodeId>>)> },
    /// Meta-Paxos Phase 2.
    MetaPhase2 { round: Round, value: Vec<NodeId>, acks: BTreeSet<NodeId> },
}

#[derive(Debug)]
struct MmReconfig {
    old: Vec<NodeId>,
    new: Vec<NodeId>,
    stage: MmStage,
    attempt: u64,
}

/// The Matchmaker MultiPaxos leader/proposer node. Every proposer runs this
/// role; at most one is active (leader) at a time, the rest are followers
/// that answer `NotLeader` and monitor heartbeats.
pub struct Leader {
    /// This node's id.
    pub id: NodeId,
    /// The consensus group (shard) this leader serves. Matchmakers are
    /// shared across groups (§6), so every matchmaking/GC message is
    /// tagged with this; acceptors and replicas are per group and need no
    /// tag. Single-group deployments leave it at 0.
    pub group: GroupId,
    /// Fault-tolerance parameter.
    pub f: usize,
    /// Protocol optimization flags + batching/snapshot knobs.
    pub opts: OptFlags,
    /// Timing knobs (resends, heartbeats, election timeout).
    pub timing: LeaderTiming,
    /// Current active matchmaker set (replaced by §6 reconfiguration).
    pub matchmakers: Vec<NodeId>,
    /// The replica group (chosen-value dissemination + GC acks).
    pub replicas: Vec<NodeId>,
    /// All proposers (heartbeats + election).
    pub proposers: Vec<NodeId>,
    rng: Rng,

    // ---- Round / configuration state ----
    /// The round being installed or active.
    round: Round,
    /// `C_i` for `round`.
    config: Configuration,
    /// Configurations of every round we have used (quorum checks for
    /// in-flight slots span a reconfiguration).
    round_configs: BTreeMap<Round, Configuration>,
    install: Install,
    /// The round in which Phase 2 is currently permitted. During a
    /// proactive reconfiguration this lags `round` (commands flow in the
    /// old round, §4.4 Case 1); `None` while commands must stall.
    active_round: Option<Round>,

    // ---- Log state ----
    log: BTreeMap<Slot, SlotState>,
    next_slot: Slot,
    /// Slots `< chosen_watermark` are contiguously chosen.
    chosen_watermark: Slot,
    /// Commands waiting for an active round (stalled during non-proactive
    /// matchmaking / Phase 1 — the §8.2 ablation measures exactly this).
    stalled: VecDeque<Command>,
    /// Commands accumulating into the next `Value::Batch` slot
    /// (`opts.batch_size > 1` only). Flushed when full or when the
    /// `BatchFlush` timer fires after `opts.batch_delay`.
    pending_batch: Vec<Command>,
    /// Whether a `BatchFlush` timer is outstanding.
    batch_timer_armed: bool,
    /// Per-client FIFO admission: dedups retries and re-orders pipelined
    /// requests the network delivered out of order.
    sequencer: ClientSequencer,
    cmd_slots: HashMap<(NodeId, u64), Slot>,

    // ---- Replica / GC state ----
    /// replica → contiguous executed prefix it acked.
    replica_acks: BTreeMap<NodeId, Slot>,
    /// Log entries below this are compacted away (stored on *all*
    /// replicas; the leader no longer needs the values). Keeps leader
    /// memory bounded on long runs.
    compacted_below: Slot,
    /// Prefix persisted on f+1 replicas (max f+1'th largest ack).
    persisted_f1: Slot,
    /// Last `persisted_f1` value broadcast to the acceptors as a
    /// `PrefixPersisted` watermark (steady-state vote-state GC; only
    /// advances with `opts.snapshot.enabled`).
    last_wm_propagated: Slot,
    gc: GcState,

    // ---- Election ----
    /// Whether this proposer currently believes it is the leader.
    pub is_leader: bool,
    epoch_seen: u64,
    last_leader_hb: Time,
    last_leader: Option<NodeId>,
    started: bool,
    /// Quorum-contact watchdog: `Some((watermark, since))` while unchosen
    /// in-flight slots have made no chosen-watermark progress since
    /// `since`. Past `timing.quorum_loss_timeout` the leader steps down
    /// (minority-partition degradation, DESIGN.md §Nemesis). Pure
    /// watchdog bookkeeping — excluded from `state_repr` like the other
    /// liveness timestamps.
    stall_probe: Option<(Slot, Time)>,

    // ---- Read-lease state (DESIGN.md §Reads) ----
    /// Renewal sequence number (matches acks to the renewal in flight).
    lease_seq: u64,
    /// Outstanding renewal: `(seq, sent_at, acks)`. Validity is counted
    /// from the *send* time, so a slow quorum yields a short lease, not
    /// an unsafe one.
    lease_inflight: Option<(u64, Time, BTreeSet<NodeId>)>,
    /// Self-lease horizon: a P2 quorum of the active configuration has
    /// confirmed (via renewals) that no higher round intruded through
    /// here. Zeroed on step-down.
    lease_valid_until: Time,
    /// When the last `LeaseGrant` was broadcast (throttles the
    /// watermark-advance pushes to `LeaseSpec::push_gap`).
    last_grant_at: Time,
    /// Whether the `LeaseRenewTick` chain is armed.
    lease_timer_armed: bool,
    /// ReadIndex requests awaiting a quorum-confirmed renewal:
    /// `(replica, request id, arrived_at)`. Answered only by a renewal
    /// *sent* at or after they arrived.
    pending_read_index: Vec<(NodeId, u64, Time)>,
    /// Set on `become_leader`: the next Phase-1 completion must fence
    /// out the previous leader's leases before any Phase-2 proposal.
    lease_fence_pending: bool,
    /// A new leader's chosen watermark lags writes acknowledged under
    /// the previous lineage until every Phase-1-recovered slot is
    /// re-chosen (the Raft §6.4 subtlety: a leader must commit in its
    /// own term before serving reads). No grant is pushed and no
    /// ReadIndex answered until `chosen_watermark` reaches this barrier
    /// — `Slot::MAX` from election until Phase 1 fixes it, the first
    /// install's barrier afterwards. Same-leader reconfigurations keep
    /// a continuous watermark lineage and never raise it.
    read_barrier: Slot,
    /// ReadIndex requests answered instantly under the self-lease
    /// (metrics).
    pub read_index_fast: u64,
    /// ReadIndex requests answered after a quorum-confirmed renewal
    /// (metrics).
    pub read_index_confirmed: u64,

    /// Bumped on every round/phase change; invalidates stale resend timers.
    generation: u64,
    /// Whether the Phase-2 watchdog timer is armed.
    watchdog_armed: bool,
    mm_reconfig: Option<MmReconfig>,
    /// Generation of the current matchmaker set (§6 meta-Paxos instances).
    mm_generation: u64,
    /// Queued acceptor reconfiguration (applied when the current install
    /// completes).
    pending_reconfig: Option<Configuration>,
    /// Durable epoch log (`None` in sim/model-checker runs; the TCP
    /// runtime attaches a WAL). Every activated `(round, config)` is
    /// persisted before it is announced, so a proposer restarted after
    /// `kill -9` re-elects in a strictly higher epoch than any round it
    /// ever used — reusing a round with amnesia could contradict the
    /// Phase-1/Phase-2 state it previously established under it.
    storage: Option<Box<dyn Storage>>,

    // ---- Metrics (read by the harness) ----
    /// Rounds installed to steady state (startup counts as one).
    pub reconfigs_completed: u64,
    /// GC cycles driven to completion (§5.3).
    pub gc_completed: u64,
    /// Max |H_i| observed after matchmaking (paper: "matchmakers usually
    /// return just a single configuration").
    pub max_prior_configs: usize,

    // ---- Overload control (DESIGN.md §Overload) ----
    /// Adaptive batching controller. Identity (and sample-free) unless
    /// `admission =` is configured, so admission-disabled runs — the
    /// model checker's domain — are unaffected.
    tuner: BatchTuner,
    /// Requests refused with `Msg::Busy` because the proposal inbox was
    /// over `AdmissionSpec::inbox` (metrics; `busy_rate` derives from
    /// this).
    pub busy_rejections: u64,
}

impl Leader {
    /// A proposer over `initial_config`, initially a follower; the
    /// designated first proposer self-elects in `on_start`. `seed` feeds
    /// the thrifty quorum sampler (identical seeds, identical runs).
    pub fn new(
        id: NodeId,
        f: usize,
        initial_config: Configuration,
        matchmakers: Vec<NodeId>,
        replicas: Vec<NodeId>,
        proposers: Vec<NodeId>,
        opts: OptFlags,
        seed: u64,
    ) -> Leader {
        let tuner = BatchTuner::new(&opts);
        Leader {
            id,
            group: 0,
            f,
            opts,
            timing: LeaderTiming::default(),
            matchmakers,
            replicas,
            proposers,
            rng: Rng::new(seed ^ (id as u64) << 32),
            round: Round::first(0, id),
            config: initial_config,
            round_configs: BTreeMap::new(),
            install: Install::None,
            active_round: None,
            log: BTreeMap::new(),
            next_slot: 0,
            chosen_watermark: 0,
            stalled: VecDeque::new(),
            pending_batch: Vec::new(),
            batch_timer_armed: false,
            sequencer: ClientSequencer::new(),
            cmd_slots: HashMap::new(),
            replica_acks: BTreeMap::new(),
            compacted_below: 0,
            persisted_f1: 0,
            last_wm_propagated: 0,
            gc: GcState { round: Round::first(0, id), barrier: 0, stage: GcStage::Idle },
            is_leader: false,
            epoch_seen: 0,
            last_leader_hb: 0,
            last_leader: None,
            started: false,
            stall_probe: None,
            lease_seq: 0,
            lease_inflight: None,
            lease_valid_until: 0,
            last_grant_at: 0,
            lease_timer_armed: false,
            pending_read_index: Vec::new(),
            lease_fence_pending: false,
            read_barrier: 0,
            read_index_fast: 0,
            read_index_confirmed: 0,
            generation: 0,
            watchdog_armed: false,
            mm_reconfig: None,
            mm_generation: 0,
            pending_reconfig: None,
            storage: None,
            reconfigs_completed: 0,
            gc_completed: 0,
            max_prior_configs: 0,
            tuner,
            busy_rejections: 0,
        }
    }

    /// The configuration currently used for new commands.
    pub fn current_config(&self) -> &Configuration {
        &self.config
    }

    /// Current round (for tests/harness).
    pub fn current_round(&self) -> Round {
        self.round
    }

    /// True when the leader can serve commands immediately.
    pub fn is_steady(&self) -> bool {
        self.is_leader && self.active_round.is_some()
    }

    /// Diagnostics: the unchosen slots with their proposal round and ack
    /// count (used by tests and the debug tooling).
    pub fn unchosen_slots(&self) -> Vec<(Slot, Round, usize)> {
        self.log
            .iter()
            .filter(|(_, s)| !s.chosen)
            .map(|(&slot, s)| (slot, s.round, s.acks.len()))
            .collect()
    }

    /// Diagnostics: `(next_slot, chosen_watermark, persisted_f1)`.
    pub fn log_watermarks(&self) -> (Slot, Slot, Slot) {
        (self.next_slot, self.chosen_watermark, self.persisted_f1)
    }

    /// Load metric: the proposal-inbox depth — in-flight unchosen slots
    /// plus commands buffered for the next batch plus commands stalled on
    /// an installation. This is the quantity `admission = inbox:N`
    /// bounds; the scan is O(in-flight window), which admission itself
    /// keeps bounded.
    pub fn inbox_depth(&self) -> usize {
        let inflight = self
            .log
            .range(self.chosen_watermark..)
            .filter(|(_, s)| !s.chosen)
            .count();
        inflight + self.pending_batch.len() + self.stalled.len()
    }

    /// Load metric: the adaptive-batching controller's windowed p99 of
    /// proposal→chosen latency (0 until the first sample; always 0 with
    /// admission disabled).
    pub fn windowed_p99(&self) -> Time {
        self.tuner.windowed_p99()
    }

    /// The controller's current effective `(batch_size, batch_delay)`
    /// (tests/harness; equals the configured knobs with admission
    /// disabled).
    pub fn effective_batch(&self) -> (usize, Time) {
        (self.tuner.effective_batch_size(), self.tuner.effective_batch_delay())
    }

    // =====================================================================
    // Durability (DESIGN.md §Durability)
    // =====================================================================

    /// Attach a durable epoch log. Call before `on_start`; combine with
    /// [`Leader::recover`] when the directory may hold state from a
    /// previous incarnation.
    pub fn attach_storage(&mut self, storage: Box<dyn Storage>) {
        self.storage = Some(storage);
    }

    /// Detach and return the durable log (crash simulation: the "disk"
    /// survives the process, so tests move it into a fresh instance).
    pub fn take_storage(&mut self) -> Option<Box<dyn Storage>> {
        self.storage.take()
    }

    /// Append `rec` to the attached log, if any. A storage failure is
    /// fatal by design: a leader that cannot persist its active round
    /// must not propose in it.
    fn persist(&mut self, rec: WalRecord) {
        if let Some(s) = self.storage.as_mut() {
            s.append(&rec).expect("leader wal append failed");
        }
    }

    /// Replay the durable epoch log after a crash: raise the election
    /// epoch floor above every round this proposer ever activated and
    /// restore the newest configuration as the matchmaking guess. The
    /// restarted proposer comes back as a *follower* — the epoch floor
    /// only guarantees that if it is elected again, `become_leader`
    /// picks a round strictly above everything it used before.
    pub fn recover(&mut self) {
        let Some(s) = self.storage.as_mut() else {
            return;
        };
        let recs = s.replay().expect("leader wal replay failed");
        let mut best: Option<Round> = None;
        for rec in recs {
            if let WalRecord::LeaderEpoch { group, round, config } = rec {
                if group != self.group {
                    continue;
                }
                self.epoch_seen = self.epoch_seen.max(round.epoch);
                if best.map_or(true, |cur| round > cur) {
                    best = Some(round);
                    self.config = config;
                }
            }
        }
    }

    // =====================================================================
    // Leadership & round installation
    // =====================================================================

    /// Become leader: pick the first round of a fresh epoch and install it
    /// (full path: Matchmaking → Phase 1 → Phase 2). Called at startup by
    /// the designated initial leader and by followers on election timeout.
    pub fn become_leader(&mut self, now: Time, fx: &mut Effects) {
        self.is_leader = true;
        self.epoch_seen += 1;
        self.round = Round::first(self.epoch_seen, self.id);
        self.active_round = None;
        self.generation += 1;
        // A leader change invalidates outstanding read leases: before
        // this round's first Phase-2 proposal, the previous leader's
        // possible grants must have expired (DESIGN.md §Reads). Our own
        // old self-lease is from a dead round lineage — drop it too.
        self.lease_fence_pending = self.opts.leases.enabled;
        self.lease_valid_until = 0;
        self.lease_inflight = None;
        self.pending_read_index.clear();
        // Unknown until Phase 1 reveals the previous lineage's reach:
        // until then this leader must answer no read (see `read_barrier`).
        self.read_barrier = Slot::MAX;
        // Learn the chosen prefix from the replicas (§4.1).
        for &r in &self.replicas.clone() {
            fx.send(r, Msg::ReadPrefix { from: self.chosen_watermark });
        }
        self.start_matchmaking(false, now, fx);
        // Optimization 5: race Phase 1 against the Matchmaking phase using
        // our configuration guess. If the guess covers H_i (leaders rarely
        // change the acceptors during an election), the buffered Phase1Bs
        // complete Phase 1 instantly when the MatchBs arrive, saving one
        // round trip.
        if self.opts.concurrent_phase1 {
            let msg = Msg::Phase1A { round: self.round, from_slot: self.chosen_watermark };
            for &a in &self.config.acceptors.clone() {
                fx.send(a, msg.clone());
            }
        }
        fx.timer(self.timing.heartbeat_period, Timer::HeartbeatTick);
    }

    /// Reconfigure the acceptors to `new_config` (§4.3): advance
    /// `(r, id, s) → (r, id, s+1)` and re-run Matchmaking. Queued if an
    /// installation is already in flight.
    pub fn reconfigure(&mut self, new_config: Configuration, now: Time, fx: &mut Effects) {
        if !self.is_leader {
            return;
        }
        if !matches!(self.install, Install::None) {
            self.pending_reconfig = Some(new_config);
            return;
        }
        // Optimization 2 preconditions: we established Phase-1 facts in the
        // current round and own its immediate successor.
        let bypass = self.opts.phase1_bypass && self.active_round == Some(self.round);
        self.round = self.round.next();
        self.config = new_config;
        self.generation += 1;
        if !self.opts.proactive_matchmaking {
            // Ablation: commands stall during matchmaking (§8.2, Fig 6a).
            self.active_round = None;
        }
        self.start_matchmaking(bypass, now, fx);
    }

    fn start_matchmaking(&mut self, bypass: bool, _now: Time, fx: &mut Effects) {
        self.install =
            Install::Matchmaking { acks: BTreeMap::new(), bypass, early_p1: Vec::new() };
        let msg = Msg::MatchA {
            group: self.group,
            round: self.round,
            config: self.config.clone(),
        };
        fx.broadcast(&self.matchmakers.clone(), &msg);
        fx.timer(self.timing.phase_resend, Timer::PhaseResend { generation: self.generation });
    }

    fn on_match_b(
        &mut self,
        from: NodeId,
        round: Round,
        gc_watermark: Option<Round>,
        prior: BTreeMap<Round, Configuration>,
        now: Time,
        fx: &mut Effects,
    ) {
        if round != self.round {
            return;
        }
        let Install::Matchmaking { acks, .. } = &mut self.install else {
            return;
        };
        acks.insert(from, (gc_watermark, prior));
        if acks.len() < self.f + 1 {
            return;
        }
        let early_p1 = match &mut self.install {
            Install::Matchmaking { early_p1, .. } => std::mem::take(early_p1),
            _ => unreachable!(),
        };
        // f+1 MatchBs: H_i = union of priors, pruned below the max GC
        // watermark (§5: "if any of the f+1 matchmakers have garbage
        // collected round j, then the proposer also garbage collects j").
        let Install::Matchmaking { acks, bypass, .. } = &mut self.install else {
            unreachable!()
        };
        let bypass = *bypass;
        let mut h: BTreeMap<Round, Configuration> = BTreeMap::new();
        let mut wm: Option<Round> = None;
        for (w, prior) in acks.values() {
            for (r, c) in prior {
                h.insert(*r, c.clone());
            }
            if let Some(w) = w {
                if wm.map_or(true, |cur| *w > cur) {
                    wm = Some(*w);
                }
            }
        }
        if let Some(w) = wm {
            h = h.split_off(&w);
        }
        h.remove(&self.round);
        self.max_prior_configs = self.max_prior_configs.max(h.len());
        self.round_configs.insert(self.round, self.config.clone());
        // Persist the activated (round, config) before announcing or
        // proposing anything in it: a post-crash restart must never
        // reuse this round (fsync-before-act, DESIGN.md §Durability).
        if self.storage.is_some() {
            self.persist(WalRecord::LeaderEpoch {
                group: self.group,
                round: self.round,
                config: self.config.clone(),
            });
        }
        fx.announce(Announce::ConfigActive {
            group: self.group,
            round: self.round,
            config_id: self.config.id,
        });
        fx.announce(Announce::QuorumConfig {
            group: self.group,
            round: self.round,
            config: self.config.clone(),
        });

        if bypass {
            // Optimization 2: every slot ≥ next_slot has k = -1 by
            // construction (we assigned no command past it in the previous
            // round), so Phase 1 is skipped and Phase 2 starts immediately.
            // In-flight slots below the barrier keep completing in the old
            // round with the old configuration (§4.4).
            self.enter_steady(self.next_slot, now, fx);
        } else {
            // Full path: Phase 1 with every configuration in H_i.
            self.install = Install::Phase1 {
                prior: h,
                acked: BTreeSet::new(),
                votes: BTreeMap::new(),
                acc_watermark: 0,
            };
            self.generation += 1;
            self.active_round = None; // commands stall during Phase 1 (§4.4 Case 2)
            self.send_phase1a(fx);
            fx.timer(self.timing.phase_resend, Timer::PhaseResend { generation: self.generation });
            // Optimization 5: credit Phase1Bs that arrived during the
            // Matchmaking phase (the concurrent Phase 1 race).
            let round = self.round;
            for (from, votes, wm) in early_p1 {
                self.on_phase1b(from, round, votes, wm, now, fx);
            }
            // Maybe Phase 1 is trivially complete (no prior configs).
            self.try_finish_phase1(now, fx);
        }
    }

    fn send_phase1a(&mut self, fx: &mut Effects) {
        let Install::Phase1 { prior, .. } = &self.install else {
            return;
        };
        let mut targets: BTreeSet<NodeId> = BTreeSet::new();
        for c in prior.values() {
            targets.extend(c.acceptors.iter().copied());
        }
        let msg = Msg::Phase1A { round: self.round, from_slot: self.chosen_watermark };
        for t in targets {
            fx.send(t, msg.clone());
        }
    }

    fn on_phase1b(
        &mut self,
        from: NodeId,
        round: Round,
        votes: Vec<crate::msg::SlotVote>,
        chosen_watermark: Slot,
        now: Time,
        fx: &mut Effects,
    ) {
        if round != self.round {
            return;
        }
        if let Install::Matchmaking { early_p1, .. } = &mut self.install {
            // Optimization 5: Phase 1 raced ahead of Matchmaking.
            early_p1.push((from, votes, chosen_watermark));
            return;
        }
        let Install::Phase1 { acked, votes: merged, acc_watermark, .. } = &mut self.install else {
            return;
        };
        if !acked.insert(from) {
            return;
        }
        *acc_watermark = (*acc_watermark).max(chosen_watermark);
        for v in votes {
            match merged.get(&v.slot) {
                Some((vr, _)) if *vr >= v.vr => {}
                _ => {
                    merged.insert(v.slot, (v.vr, v.vv));
                }
            }
        }
        self.try_finish_phase1(now, fx);
    }

    fn try_finish_phase1(&mut self, now: Time, fx: &mut Effects) {
        let Install::Phase1 { prior, acked, votes, acc_watermark } = &self.install else {
            return;
        };
        // Need a P1 quorum from *every* prior configuration (§3.2).
        let complete = prior.values().all(|c| c.is_p1_quorum(acked));
        if !complete {
            return;
        }
        let votes = votes.clone();
        let acc_watermark = *acc_watermark;

        // Leader change with read leases: Phase 1 is done, but the old
        // leader may still hold a lease whose last successful renewal
        // was sent before our Phase-1 quorum assembled (any later one
        // is nacked by the quorum intersection). Wait out one full
        // lease duration plus the drift bound before proposing
        // anything — including hole-filling re-proposals, whose
        // execution acks would be invisible to the old lease's grants.
        if self.lease_fence_pending {
            self.lease_fence_pending = false;
            let delay = self.opts.leases.duration + self.opts.leases.drift;
            self.install = Install::LeaseFence { votes, acc_watermark, until: now + delay };
            self.generation += 1;
            fx.timer(delay, Timer::LeaseFence);
            return;
        }
        self.finish_phase1(votes, acc_watermark, now, fx);
    }

    /// The back half of Phase 1: adopt watermarks, re-propose the voted
    /// middle subsequence, enter steady state. Runs immediately for
    /// same-leader installations, or when the lease fence lifts after a
    /// leader change.
    fn finish_phase1(
        &mut self,
        votes: BTreeMap<Slot, (Round, Value)>,
        acc_watermark: Slot,
        now: Time,
        fx: &mut Effects,
    ) {
        // Slots below the acceptor watermark are chosen & replica-stored
        // (Scenario 3): skip them entirely.
        self.chosen_watermark = self.chosen_watermark.max(acc_watermark);
        let max_voted = votes.keys().next_back().copied();
        let barrier = match max_voted {
            Some(m) => (m + 1).max(self.next_slot).max(self.chosen_watermark),
            None => self.next_slot.max(self.chosen_watermark),
        };
        // Every slot the previous lineage could have chosen (and had
        // acknowledged) is below the barrier — its P2 quorum intersects
        // our P1 quorum, so it appeared in `votes`. Reads may be served
        // once our watermark covers it (the re-proposals just below).
        if self.read_barrier == Slot::MAX {
            self.read_barrier = barrier;
        }

        // Repropose the middle subsequence in our round; fill holes with
        // no-ops (§4.1, Figure 5).
        let round = self.round;
        for slot in self.chosen_watermark..barrier {
            if self.log.get(&slot).map_or(false, |s| s.chosen) {
                continue;
            }
            let value = votes
                .get(&slot)
                .map(|(_, v)| v.clone())
                .unwrap_or(Value::Noop);
            self.propose(slot, value, round, now, fx);
        }
        self.next_slot = self.next_slot.max(barrier);
        self.enter_steady(barrier, now, fx);
    }

    /// Enter steady-state Phase 2 in `self.round`. `barrier` marks the end
    /// of slots that may carry values from earlier rounds (GC §5.3).
    fn enter_steady(&mut self, barrier: Slot, now: Time, fx: &mut Effects) {
        self.install = Install::None;
        self.active_round = Some(self.round);
        self.generation += 1;
        self.reconfigs_completed += 1;
        fx.announce(Announce::LeaderSteady { round: self.round });

        // Resume (or begin) the read-lease renewal chain in the new
        // round. Same-leader reconfigurations keep the same watermark
        // lineage, so grants simply continue under the new round; a
        // leader change reaches here only after the lease fence lifted.
        // With leases disabled this still fires when ReadIndex requests
        // queued up during the installation — they need a confirm round
        // now, not at the replicas' next retry tick.
        if self.opts.leases.enabled || !self.pending_read_index.is_empty() {
            self.start_lease_renewal(now, fx);
        }

        // Drain commands stalled during installation, then flush any
        // partial batch immediately — the stall already cost them latency.
        while let Some(cmd) = self.stalled.pop_front() {
            self.assign_and_propose(cmd, now, fx);
        }
        self.flush_batch(now, fx);

        // Start the GC driver for this round (§5.3).
        if self.opts.garbage_collection {
            self.gc = GcState { round: self.round, barrier, stage: GcStage::WaitPrefix };
            self.gc_advance(now, fx);
        }

        // Apply a queued reconfiguration, if any.
        if let Some(cfg) = self.pending_reconfig.take() {
            self.reconfigure(cfg, now, fx);
        }
    }

    // =====================================================================
    // Phase 2 (steady state)
    // =====================================================================

    /// Entry point for client traffic: the sequencer admits requests in
    /// per-client FIFO order (buffering reordered pipelined requests) and
    /// routes retries to the already-assigned slot.
    fn on_client_request(&mut self, cmd: Command, lowest: u64, now: Time, fx: &mut Effects) {
        match self.sequencer.offer(cmd, lowest) {
            Offered::Admit(cmds) => {
                for c in cmds {
                    self.assign_and_propose(c, now, fx);
                }
            }
            Offered::Duplicate(cmd) => {
                // Retry of an admitted command. If it was chosen,
                // re-inform the replicas (they re-reply with the cached
                // result); otherwise the Phase 2 watchdog is already on it.
                if let Some(&slot) = self.cmd_slots.get(&cmd.id()) {
                    if self.log.get(&slot).map_or(false, |s| s.chosen) {
                        let value = self.log[&slot].value.clone();
                        fx.broadcast_move(&self.replicas, Msg::Chosen { slot, value });
                    }
                }
            }
            Offered::Buffered => {}
        }
    }

    /// Assign a slot (or batch membership) to an admitted command. Only
    /// in-order, deduplicated commands reach this point.
    fn assign_and_propose(&mut self, cmd: Command, now: Time, fx: &mut Effects) {
        let round = match self.active_round {
            Some(r) => r,
            None => {
                self.stalled.push_back(cmd);
                return;
            }
        };
        if self.opts.batch_size > 1 {
            // Phase 2 batching: accumulate; flush when full, or let the
            // delay timer flush a partial batch.
            self.pending_batch.push(cmd);
            if self.pending_batch.len() >= self.tuner.effective_batch_size() {
                self.flush_batch(now, fx);
            } else if !self.batch_timer_armed {
                self.batch_timer_armed = true;
                fx.timer(self.tuner.effective_batch_delay(), Timer::BatchFlush);
            }
            return;
        }
        let slot = self.next_slot;
        self.next_slot += 1;
        self.cmd_slots.insert(cmd.id(), slot);
        self.propose(slot, Value::Cmd(cmd), round, now, fx);
    }

    /// Propose the accumulated batch in one slot. No-op while no round is
    /// active (e.g. mid-Phase 1 without Optimization 1); the commands stay
    /// pending and flush once the installation completes.
    fn flush_batch(&mut self, now: Time, fx: &mut Effects) {
        if self.pending_batch.is_empty() {
            return;
        }
        let Some(round) = self.active_round else {
            return;
        };
        let cmds = std::mem::take(&mut self.pending_batch);
        let slot = self.next_slot;
        self.next_slot += 1;
        for c in &cmds {
            self.cmd_slots.insert(c.id(), slot);
        }
        let value = if cmds.len() == 1 {
            Value::Cmd(cmds.into_iter().next().unwrap())
        } else {
            Value::Batch(cmds)
        };
        self.propose(slot, value, round, now, fx);
    }

    fn propose(&mut self, slot: Slot, value: Value, round: Round, now: Time, fx: &mut Effects) {
        self.generation += 1;
        let generation = self.generation;
        // Hot path: no Configuration clone — borrow the config, emit the
        // Phase2A fan-out directly into the effects buffer.
        let cfg = self.round_configs.get(&round).unwrap_or(&self.config);
        if self.opts.thrifty {
            let targets = cfg.quorum.sample_p2(&cfg.acceptors, &mut self.rng);
            for &t in &targets {
                fx.send(t, Msg::Phase2A { round, slot, value: value.clone() });
            }
        } else {
            for &t in &cfg.acceptors {
                fx.send(t, Msg::Phase2A { round, slot, value: value.clone() });
            }
        }
        self.log.insert(
            slot,
            SlotState {
                value,
                round,
                acks: BTreeSet::new(),
                chosen: false,
                generation,
                proposed_at: now,
            },
        );
        // The watchdog rescues slots whose thrifty quorum never answers
        // and slots stranded by an overlapping reconfiguration (an
        // acceptor shared between C_old and C_new that has advanced to
        // round i+1 nacks round-i Phase2As; the watchdog re-proposes in
        // the newer round — safe by Optimization 2: we are the only
        // proposer of round i and re-propose our own value). One periodic
        // timer covers the whole in-flight window (perf: per-slot timers
        // cost a heap operation per command).
        if !self.watchdog_armed {
            self.watchdog_armed = true;
            fx.timer(self.timing.phase2_retry, Timer::Phase2Watchdog);
        }
    }

    fn on_phase2b(&mut self, from: NodeId, round: Round, slot: Slot, now: Time, fx: &mut Effects) {
        let Some(ss) = self.log.get_mut(&slot) else {
            return;
        };
        if ss.chosen || ss.round != round {
            return;
        }
        ss.acks.insert(from);
        let cfg = match self.round_configs.get(&round) {
            Some(c) => c,
            None => return,
        };
        if !cfg.is_p2_quorum(&ss.acks) {
            return;
        }
        ss.chosen = true;
        let value = ss.value.clone();
        // Feed the adaptive-batching controller (no-op when admission is
        // disabled). `proposed_at` resets on watchdog retries, so a
        // rescued slot reports its last-fan-out latency — an
        // underestimate that still trends with queueing delay, which is
        // what the controller steers on.
        self.tuner.observe(now.saturating_sub(ss.proposed_at));
        fx.announce(Announce::Chosen { group: self.group, slot, round, value: value.clone() });
        // Hot path: move the value into the fan-out instead of cloning a
        // broadcast template (one full Value clone saved per chosen slot).
        fx.broadcast_move(&self.replicas, Msg::Chosen { slot, value });
        // Advance the contiguous chosen prefix.
        let before = self.chosen_watermark;
        while self.log.get(&self.chosen_watermark).map_or(false, |s| s.chosen) {
            self.chosen_watermark += 1;
        }
        // Piggyback a lease grant on watermark advances (throttled), so
        // replicas' pending leased reads resolve at write-traffic
        // cadence instead of waiting for the next renewal tick.
        if self.opts.leases.enabled
            && self.chosen_watermark > before
            && now.saturating_sub(self.last_grant_at) >= self.opts.leases.push_gap()
        {
            self.push_grant(now, fx);
        }
        self.gc_advance(now, fx);
    }

    // =====================================================================
    // Replica acks & GC driver (§5.3)
    // =====================================================================

    fn on_replica_ack(&mut self, from: NodeId, upto: Slot, now: Time, fx: &mut Effects) {
        if !self.is_leader {
            return;
        }
        let prev = self.replica_acks.get(&from).copied().unwrap_or(0);
        // Record the replica's LATEST ack verbatim, not the max: a
        // crashed-and-replaced replica legitimately regresses to 0, and
        // keeping its stale high-water ack would (a) let the f+1-durable
        // watermark count a prefix the fresh machine no longer holds and
        // (b) mis-rank it as the most caught-up CatchUp peer. A reordered
        // old ack only makes the watermark transiently conservative —
        // `persisted_f1` itself never regresses.
        self.replica_acks.insert(from, upto);
        // Persisted-on-f+1 watermark: (f+1)'th largest ack.
        let mut acks: Vec<Slot> = self.replica_acks.values().copied().collect();
        acks.sort_unstable_by(|a, b| b.cmp(a));
        if acks.len() >= self.f + 1 {
            self.persisted_f1 = self.persisted_f1.max(acks[self.f]);
        }
        // Replica catch-up: re-send entries only when the replica shows
        // NO progress (a repeated ack below our watermark = a hole from a
        // lost Chosen). Acks that merely lag the watermark are normal
        // pipelining at high client counts — re-sending on those is
        // quadratic in load.
        if upto <= prev && upto < self.chosen_watermark {
            // If we no longer hold the entry the replica needs (truncated
            // below the durable watermark, or never learned it from the
            // replicas after an election), entry-by-entry re-send cannot
            // help: point the replica at the most caught-up peer for
            // snapshot transfer instead.
            let unavailable = self.log.get(&upto).map_or(true, |ss| !ss.chosen);
            if unavailable {
                let peer = self
                    .replica_acks
                    .iter()
                    .filter(|&(&r, _)| r != from)
                    .max_by_key(|&(_, &a)| a)
                    .map(|(&r, _)| r)
                    .or_else(|| self.replicas.iter().copied().find(|&r| r != from));
                if let Some(peer) = peer {
                    fx.send(from, Msg::CatchUp { below: self.chosen_watermark, peer });
                }
            } else {
                let batch_end = (upto + 256).min(self.chosen_watermark);
                for slot in upto..batch_end {
                    if let Some(ss) = self.log.get(&slot) {
                        if ss.chosen {
                            fx.send(from, Msg::Chosen { slot, value: ss.value.clone() });
                        }
                    }
                }
            }
        }
        if self.opts.snapshot.enabled {
            // State retention: truncate at the f+1-durable watermark
            // minus the retained tail — lagging replicas catch up via
            // peer snapshots, so waiting for every replica (which stalls
            // forever if one crashed) is no longer necessary. Amortized
            // in tail-sized strides.
            let stride = self.opts.snapshot.tail.max(256);
            let floor = self.persisted_f1.saturating_sub(self.opts.snapshot.tail);
            if floor >= self.compacted_below + stride {
                self.log = self.log.split_off(&floor);
                self.compacted_below = floor;
                #[allow(clippy::disallowed_methods)] // pure predicate, order-insensitive
                self.cmd_slots.retain(|_, slot| *slot >= floor);
                fx.announce(Announce::LogTruncated {
                    group: self.group,
                    below: floor,
                    durable: self.persisted_f1,
                });
            }
            self.propagate_watermark(fx);
        } else if self.replica_acks.len() == self.replicas.len() {
            // Without snapshots, compact only entries stored on ALL
            // replicas (nobody can need them from us again): amortized,
            // in 4k-slot strides.
            let min_ack = *self.replica_acks.values().min().unwrap();
            if min_ack >= self.compacted_below + 4096 {
                self.log = self.log.split_off(&min_ack);
                self.compacted_below = min_ack;
                #[allow(clippy::disallowed_methods)] // pure predicate, order-insensitive
                self.cmd_slots.retain(|_, slot| *slot >= min_ack);
                fx.announce(Announce::LogTruncated {
                    group: self.group,
                    below: min_ack,
                    durable: self.persisted_f1,
                });
            }
        }
        self.gc_advance(now, fx);
    }

    /// Steady-state acceptor-state GC: as the f+1-durable prefix grows,
    /// keep telling the active configuration's acceptors (Scenario 3,
    /// §5.3) so they drop voted state below it — continuously, not only
    /// at reconfiguration barriers. Amortized in strides so a busy
    /// cluster is not flooded with watermark traffic.
    fn propagate_watermark(&mut self, fx: &mut Effects) {
        let Some(round) = self.active_round else {
            return;
        };
        if !matches!(self.install, Install::None) {
            return;
        }
        let stride = (self.opts.snapshot.tail / 4).max(64);
        if self.persisted_f1 < self.last_wm_propagated + stride {
            return;
        }
        self.last_wm_propagated = self.persisted_f1;
        let cfg = self.round_configs.get(&round).unwrap_or(&self.config).clone();
        fx.broadcast_move(
            &cfg.acceptors,
            Msg::PrefixPersisted { round, upto: self.persisted_f1 },
        );
    }

    /// Drive the GC state machine forward as prerequisites are met.
    fn gc_advance(&mut self, _now: Time, fx: &mut Effects) {
        if !self.opts.garbage_collection || !self.is_leader {
            return;
        }
        if self.gc.stage == GcStage::WaitPrefix {
            // Scenario 1+3 preconditions: all slots below the barrier are
            // chosen (contiguously) and stored on f+1 replicas.
            if self.chosen_watermark >= self.gc.barrier && self.persisted_f1 >= self.gc.barrier {
                let round = self.gc.round;
                let upto = self.gc.barrier;
                let cfg = self.round_configs.get(&round).unwrap_or(&self.config).clone();
                fx.broadcast(&cfg.acceptors, &Msg::PrefixPersisted { round, upto });
                self.gc.stage = GcStage::WaitPrefixAck { acks: BTreeSet::new() };
            }
        }
    }

    fn on_prefix_ack(&mut self, from: NodeId, round: Round, upto: Slot, _now: Time, fx: &mut Effects) {
        if round != self.gc.round || upto < self.gc.barrier {
            return;
        }
        let GcStage::WaitPrefixAck { acks } = &mut self.gc.stage else {
            return;
        };
        acks.insert(from);
        let cfg = self.round_configs.get(&round).unwrap_or(&self.config);
        if !cfg.is_p2_quorum(acks) {
            return;
        }
        // A P2 quorum of C_i knows the prefix is persisted: GarbageA(i).
        fx.broadcast(
            &self.matchmakers.clone(),
            &Msg::GarbageA { group: self.group, round: self.gc.round },
        );
        self.gc.stage = GcStage::WaitGarbageB { acks: BTreeSet::new() };
    }

    fn on_garbage_b(&mut self, from: NodeId, round: Round, _now: Time, fx: &mut Effects) {
        if round != self.gc.round {
            return;
        }
        let GcStage::WaitGarbageB { acks } = &mut self.gc.stage else {
            return;
        };
        acks.insert(from);
        if acks.len() < self.f + 1 {
            return;
        }
        self.gc.stage = GcStage::Done;
        self.gc_completed += 1;
        // All of this group's configurations below gc.round are retired;
        // drop them.
        let round = self.gc.round;
        self.round_configs = self.round_configs.split_off(&round);
        fx.announce(Announce::ConfigRetired { group: self.group, round });
    }

    // =====================================================================
    // Read leases + ReadIndex (DESIGN.md §Reads)
    // =====================================================================

    /// Send a lease renewal to the active configuration's acceptors (if
    /// none is in flight) and keep the renewal tick armed. Skipped
    /// while an installation or a matchmaker migration is in flight —
    /// leases deliberately lapse there, so reads fall back to the
    /// ReadIndex path instead of trusting a lease across the change.
    ///
    /// With leases *disabled* this still runs whenever ReadIndex
    /// requests are queued: the renewal round then acts as a pure
    /// leadership confirmation (no grants are pushed, no self-lease
    /// fast path), which is what keeps the no-lease fallback both live
    /// and linearizable.
    fn start_lease_renewal(&mut self, now: Time, fx: &mut Effects) {
        if !self.is_leader {
            return;
        }
        if !self.opts.leases.enabled && self.pending_read_index.is_empty() {
            return;
        }
        if !matches!(self.install, Install::None) || self.mm_reconfig.is_some() {
            return;
        }
        let Some(round) = self.active_round else {
            return;
        };
        if self.lease_inflight.is_none() {
            self.lease_seq += 1;
            self.lease_inflight = Some((self.lease_seq, now, BTreeSet::new()));
            let msg = Msg::LeaseRenew { round, seq: self.lease_seq };
            let cfg = self.round_configs.get(&round).unwrap_or(&self.config);
            fx.broadcast(&cfg.acceptors, &msg);
        }
        if !self.lease_timer_armed {
            self.lease_timer_armed = true;
            fx.timer(self.opts.leases.refresh, Timer::LeaseRenewTick);
        }
    }

    fn on_lease_renew_ack(
        &mut self,
        from: NodeId,
        round: Round,
        seq: u64,
        now: Time,
        fx: &mut Effects,
    ) {
        if !self.is_leader || self.active_round != Some(round) {
            return;
        }
        // Hot path (one renewal per refresh tick, forever): the quorum
        // check runs against the ack set in place, no clone.
        let (sent_at, quorum) = {
            let Some((cur, sent, acks)) = &mut self.lease_inflight else {
                return;
            };
            if *cur != seq {
                return;
            }
            acks.insert(from);
            let cfg = self.round_configs.get(&round).unwrap_or(&self.config);
            (*sent, cfg.is_p2_quorum(acks))
        };
        if !quorum {
            return;
        }
        // Quorum-confirmed: no round above ours reached a P2 quorum of
        // this configuration before `sent_at` (a newer round's Phase 1
        // would have left at least one nacking acceptor in the quorum).
        self.lease_inflight = None;
        self.lease_valid_until = self.lease_valid_until.max(sent_at + self.opts.leases.duration);
        self.push_grant(now, fx);
        self.answer_pending_read_index(sent_at, now, fx);
    }

    /// Broadcast the lease (round, chosen watermark, validity) to the
    /// replicas. Called on every renewal confirmation and — throttled to
    /// [`crate::config::LeaseSpec::push_gap`] — on chosen-watermark
    /// advances, so a replica's pending reads resolve within a fraction
    /// of the refresh interval under write load.
    fn push_grant(&mut self, now: Time, fx: &mut Effects) {
        if !self.opts.leases.enabled || !self.is_leader {
            return;
        }
        if !matches!(self.install, Install::None) {
            return;
        }
        let Some(round) = self.active_round else {
            return;
        };
        // A fresh leader's watermark must first cover everything the
        // previous lineage could have acknowledged (`read_barrier`) —
        // until then a grant could carry a watermark below an already
        // acknowledged write.
        if self.chosen_watermark < self.read_barrier {
            return;
        }
        // Advertise the validity minus the drift bound: replicas may
        // trust it on their own clocks.
        let valid_until = self.lease_valid_until.saturating_sub(self.opts.leases.drift);
        if valid_until <= now {
            return;
        }
        self.last_grant_at = now;
        // `granted_at` is compared against read-arrival times on the
        // *replica's* clock, so discount it by the drift bound too: a
        // replica then only resolves a read against a grant provably
        // issued after the read arrived, even with skewed clocks.
        let granted_at = now.saturating_sub(self.opts.leases.drift);
        fx.announce(Announce::LeaseGranted { round, valid_until });
        fx.broadcast_move(
            &self.replicas,
            Msg::LeaseGrant { round, upto: self.chosen_watermark, granted_at, valid_until },
        );
    }

    /// A replica asks for the chosen watermark (ReadIndex). Under an
    /// active self-lease the answer is immediate; otherwise it is
    /// deferred until a renewal *sent after the request arrived*
    /// completes at a P2 quorum — a deposed leader can never answer,
    /// because its renewals are nacked from the new round's Phase 1 on.
    fn on_read_index_req(&mut self, from: NodeId, id: u64, now: Time, fx: &mut Effects) {
        if !self.is_leader {
            fx.send(from, Msg::NotLeader { group: self.group, hint: self.last_leader });
            return;
        }
        let steady = matches!(self.install, Install::None) && self.active_round.is_some();
        if steady
            && self.opts.leases.enabled
            && self.chosen_watermark >= self.read_barrier
            && now + self.opts.leases.drift < self.lease_valid_until
        {
            self.read_index_fast += 1;
            fx.send(from, Msg::ReadIndexResp { id, upto: self.chosen_watermark });
            return;
        }
        if self.pending_read_index.len() >= 1024 {
            return; // overload guard; the replica's retry re-asks
        }
        self.pending_read_index.push((from, id, now));
        if steady {
            self.start_lease_renewal(now, fx);
        }
    }

    /// Answer queued ReadIndex requests covered by a renewal sent at
    /// `sent_at` (only those that arrived before the renewal was sent —
    /// the watermark must postdate the read's arrival). Later arrivals
    /// wait for the next renewal, triggered here if needed.
    fn answer_pending_read_index(&mut self, sent_at: Time, now: Time, fx: &mut Effects) {
        if self.pending_read_index.is_empty() {
            return;
        }
        // New-leader gate (see `read_barrier`): hold the answers until
        // the re-proposed prefix is re-chosen. The renewal tick keeps
        // confirm rounds coming while requests are pending, so these
        // are answered within a refresh of the barrier being crossed.
        if self.chosen_watermark < self.read_barrier {
            return;
        }
        let upto = self.chosen_watermark;
        let mut keep = Vec::new();
        for (rep, id, arrived) in std::mem::take(&mut self.pending_read_index) {
            if arrived <= sent_at {
                self.read_index_confirmed += 1;
                fx.send(rep, Msg::ReadIndexResp { id, upto });
            } else {
                keep.push((rep, id, arrived));
            }
        }
        self.pending_read_index = keep;
        if !self.pending_read_index.is_empty() {
            self.start_lease_renewal(now, fx);
        }
    }

    /// Drop all lease authority (step-down): a deposed leader must
    /// neither grant nor answer ReadIndex requests.
    fn drop_lease(&mut self) {
        self.lease_valid_until = 0;
        self.lease_inflight = None;
        self.pending_read_index.clear();
    }

    // =====================================================================
    // Matchmaker reconfiguration (§6)
    // =====================================================================

    /// Replace the matchmaker set with `new`. Stop-and-copy + meta-Paxos.
    pub fn reconfigure_matchmakers(&mut self, new: Vec<NodeId>, _now: Time, fx: &mut Effects) {
        if !self.is_leader || self.mm_reconfig.is_some() {
            return;
        }
        let old = self.matchmakers.clone();
        fx.broadcast(&old, &Msg::StopA);
        self.mm_reconfig = Some(MmReconfig {
            old,
            new,
            stage: MmStage::Stopping { acks: BTreeMap::new() },
            attempt: 0,
        });
    }

    fn on_stop_b(
        &mut self,
        from: NodeId,
        log: MmLog,
        wms: BTreeMap<GroupId, Round>,
        _now: Time,
        fx: &mut Effects,
    ) {
        let Some(mm) = &mut self.mm_reconfig else {
            return;
        };
        let MmStage::Stopping { acks } = &mut mm.stage else {
            return;
        };
        acks.insert(from, (log, wms));
        if acks.len() < self.f + 1 {
            return;
        }
        // Merge the f+1 stopped multi-group logs (§6, Figure 7, applied
        // per group) and bootstrap the new set with the result. The
        // matchmakers carry every group's state, so the reconfigurer
        // (one group's leader) migrates the whole shared set on behalf of
        // all groups.
        let states: Vec<_> = acks.values().cloned().collect();
        let (merged, wms) = super::matchmaker::merge_stopped(&states);
        fx.announce(Announce::MmMerged {
            inputs: states,
            merged: merged.clone(),
            watermarks: wms.clone(),
        });
        let new = mm.new.clone();
        mm.stage = MmStage::Bootstrapping { acks: BTreeSet::new() };
        let generation = self.mm_generation + 1;
        fx.broadcast(&new, &Msg::Bootstrap { log: merged, gc_watermarks: wms, generation });
    }

    fn on_bootstrap_ack(&mut self, from: NodeId, _now: Time, fx: &mut Effects) {
        let Some(mm) = &mut self.mm_reconfig else {
            return;
        };
        let MmStage::Bootstrapping { acks } = &mut mm.stage else {
            return;
        };
        acks.insert(from);
        if acks.len() < mm.new.len() {
            return;
        }
        // All new matchmakers hold the merged state. Choose M_new via
        // meta-Paxos with the *old* matchmakers as acceptors.
        mm.attempt += 1;
        let round = Round { epoch: self.epoch_seen, proposer: self.id, seq: mm.attempt };
        let old = mm.old.clone();
        mm.stage = MmStage::MetaPhase1 { round, acks: BTreeMap::new() };
        let generation = self.mm_generation;
        fx.broadcast(&old, &Msg::MetaPhase1A { round, generation });
    }

    fn on_meta_phase1b(
        &mut self,
        from: NodeId,
        round: Round,
        vr: Option<Round>,
        vv: Option<Vec<NodeId>>,
        _now: Time,
        fx: &mut Effects,
    ) {
        let Some(mm) = &mut self.mm_reconfig else {
            return;
        };
        let MmStage::MetaPhase1 { round: r, acks } = &mut mm.stage else {
            return;
        };
        if *r != round {
            return;
        }
        acks.insert(from, (vr, vv));
        if acks.len() < self.f + 1 {
            return;
        }
        // Standard Paxos value selection: adopt the value of the largest
        // vote round, else our own M_new.
        let mut best: Option<(Round, Vec<NodeId>)> = None;
        for (vr, vv) in acks.values() {
            if let (Some(vr), Some(vv)) = (vr, vv) {
                if best.as_ref().map_or(true, |(br, _)| vr > br) {
                    best = Some((*vr, vv.clone()));
                }
            }
        }
        let value = best.map(|(_, v)| v).unwrap_or_else(|| mm.new.clone());
        let old = mm.old.clone();
        mm.stage = MmStage::MetaPhase2 { round, value: value.clone(), acks: BTreeSet::new() };
        let generation = self.mm_generation;
        fx.broadcast(&old, &Msg::MetaPhase2A { round, generation, matchmakers: value });
    }

    fn on_meta_phase2b(&mut self, from: NodeId, round: Round, _now: Time, fx: &mut Effects) {
        let Some(mm) = &mut self.mm_reconfig else {
            return;
        };
        let MmStage::MetaPhase2 { round: r, value, acks } = &mut mm.stage else {
            return;
        };
        if *r != round {
            return;
        }
        acks.insert(from);
        if acks.len() < self.f + 1 {
            return;
        }
        // M_new is chosen: activate and switch over. Our follower
        // proposers learn the new set too, so a later failover does not
        // elect a leader pointed at the stopped old set.
        let chosen = value.clone();
        let new_generation = self.mm_generation + 1;
        let activation =
            Msg::MatchmakersActivated { generation: new_generation, matchmakers: chosen.clone() };
        fx.broadcast(&chosen, &activation);
        for &p in &self.proposers.clone() {
            if p != self.id {
                fx.send(p, activation.clone());
            }
        }
        self.matchmakers = chosen.clone();
        self.mm_generation = new_generation;
        self.mm_reconfig = None;
        fx.announce(Announce::MatchmakersReconfigured { matchmakers: chosen });
    }

    /// Control-plane: adopt a new matchmaker set chosen elsewhere. In a
    /// sharded deployment the matchmakers are shared, but the §6
    /// stop-and-copy is driven by *one* group's leader — the admin plane
    /// (or the harness standing in for it) must hand the chosen set to
    /// every other group's leader, exactly as it hands out acceptor
    /// reconfigurations. Without this, other groups would keep
    /// broadcasting MatchA at the old, permanently stopped set.
    pub fn set_matchmakers(&mut self, matchmakers: Vec<NodeId>) {
        self.matchmakers = matchmakers;
        self.mm_generation += 1;
    }

    // =====================================================================
    // Election / heartbeats
    // =====================================================================

    fn handle_nack(&mut self, higher: Round, _now: Time, _fx: &mut Effects) {
        if higher.proposer == self.id {
            return; // our own round echoed back
        }
        if higher > self.round {
            // Someone with a higher round is active: step down.
            self.epoch_seen = self.epoch_seen.max(higher.epoch);
            self.is_leader = false;
            self.install = Install::None;
            self.active_round = None;
            self.generation += 1;
            self.drop_lease();
        }
    }
}

impl Node for Leader {
    fn on_start(&mut self, now: Time, fx: &mut Effects) {
        self.started = true;
        self.last_leader_hb = now;
        // The lowest-id proposer bootstraps as the initial leader.
        if self.proposers.first() == Some(&self.id) && self.epoch_seen == 0 {
            self.become_leader(now, fx);
        } else {
            fx.timer(self.timing.leader_check_period, Timer::LeaderCheck);
        }
    }

    fn on_msg(&mut self, now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::ClientRequest { group, cmd, lowest } => {
                // A misrouted shard request would corrupt the per-group
                // seq streams; routing is static (key hash), so this only
                // fires under a broken router.
                debug_assert_eq!(group, self.group, "client request routed to wrong group");
                if group != self.group {
                    return;
                }
                if !self.is_leader {
                    fx.send(from, Msg::NotLeader { group: self.group, hint: self.last_leader });
                    return;
                }
                // Admission control (DESIGN.md §Overload): refuse with
                // Busy while the proposal inbox is over its bound. The
                // request never touches the sequencer, so a shed is a
                // *drop*, not an ack — the client keeps `seq` in its
                // outstanding window, its advertised `lowest` cannot
                // advance past the shed command, and a later retry is
                // admitted in FIFO position like any first attempt.
                if self.opts.admission.enabled
                    && self.inbox_depth() >= self.opts.admission.inbox
                {
                    self.busy_rejections += 1;
                    fx.send(
                        from,
                        Msg::Busy {
                            group: self.group,
                            seq: cmd.seq,
                            retry_after_us: self.opts.admission.target_p99_us,
                        },
                    );
                    return;
                }
                self.on_client_request(cmd, lowest, now, fx);
            }
            Msg::MatchB { group, round, gc_watermark, prior } => {
                if group != self.group {
                    return;
                }
                self.on_match_b(from, round, gc_watermark, prior, now, fx)
            }
            Msg::MatchNack { group, round, blocking } => {
                if group == self.group && round == self.round {
                    self.handle_nack(blocking, now, fx);
                }
            }
            Msg::Phase1B { round, votes, chosen_watermark } => {
                self.on_phase1b(from, round, votes, chosen_watermark, now, fx)
            }
            Msg::Phase2B { round, slot } => self.on_phase2b(from, round, slot, now, fx),
            Msg::LeaseRenewAck { round, seq } => {
                self.on_lease_renew_ack(from, round, seq, now, fx)
            }
            Msg::ReadIndexReq { id } => self.on_read_index_req(from, id, now, fx),
            Msg::Nack { round: _, higher } => self.handle_nack(higher, now, fx),
            Msg::ReplicaAck { upto } => self.on_replica_ack(from, upto, now, fx),
            Msg::PrefixResp { entries, upto } => {
                // Adopt the replica's chosen prefix (new-leader recovery).
                for (slot, value) in entries {
                    let generation = self.generation;
                    self.log.entry(slot).or_insert(SlotState {
                        value,
                        round: self.round,
                        acks: BTreeSet::new(),
                        chosen: true,
                        generation,
                        proposed_at: now,
                    });
                    self.log.get_mut(&slot).unwrap().chosen = true;
                }
                self.chosen_watermark = self.chosen_watermark.max(upto);
                self.next_slot = self.next_slot.max(upto);
            }
            Msg::PrefixAck { round, upto } => self.on_prefix_ack(from, round, upto, now, fx),
            Msg::GarbageB { group, round } => {
                if group == self.group {
                    self.on_garbage_b(from, round, now, fx)
                }
            }
            Msg::StopB { log, gc_watermarks } => {
                self.on_stop_b(from, log, gc_watermarks, now, fx)
            }
            Msg::BootstrapAck => self.on_bootstrap_ack(from, now, fx),
            Msg::MetaPhase1B { round, vr, vv } => {
                self.on_meta_phase1b(from, round, vr, vv, now, fx)
            }
            Msg::MetaPhase2B { round } => self.on_meta_phase2b(from, round, now, fx),
            Msg::MatchmakersActivated { generation, matchmakers } => {
                // The driving leader announces the §6-chosen set to its
                // follower proposers. Adopt it unconditionally w.r.t.
                // leadership — a proposer that self-elected while the
                // migration was in flight must not keep matchmaking at
                // the stopped old set — but only for a strictly newer
                // generation, so a reordered stale activation cannot
                // regress the set. (The driver never receives this: it
                // only sends to its peers.)
                if generation > self.mm_generation {
                    self.matchmakers = matchmakers;
                    self.mm_generation = generation;
                }
            }
            Msg::Heartbeat { epoch } => {
                // A heartbeat refreshes the election timer only if its
                // sender could still win the epoch's round ordering:
                // strictly newer epoch, or same epoch from a proposer id
                // >= the one we last followed (rounds order by
                // `(epoch, proposer, _)`, so the higher id is the epoch's
                // surviving leader). Without the same-epoch tiebreak, a
                // deposed leader whose stale heartbeats still arrive
                // through an asymmetric partition would suppress election
                // ticks on followers forever — they would keep refreshing
                // `last_leader_hb` for a leader that can no longer choose
                // anything (regression: sim_cluster
                // `stale_heartbeats_do_not_suppress_elections`).
                let live = epoch > self.epoch_seen
                    || (epoch == self.epoch_seen
                        && self.last_leader.map_or(true, |l| from >= l));
                if live {
                    self.epoch_seen = epoch;
                    self.last_leader_hb = now;
                    self.last_leader = Some(from);
                    if self.is_leader && from != self.id && epoch > self.round.epoch {
                        // A higher-epoch leader exists: step down.
                        self.is_leader = false;
                        self.install = Install::None;
                        self.active_round = None;
                        self.drop_lease();
                    }
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, now: Time, timer: Timer, fx: &mut Effects) {
        match timer {
            Timer::Phase2Watchdog => {
                if !self.is_leader {
                    self.watchdog_armed = false;
                    return;
                }
                // Scan the in-flight window for slots whose last fan-out
                // is older than the retry interval.
                let retry_after = self.timing.phase2_retry;
                let mut stale: Vec<(Slot, Round, Value)> = Vec::new();
                let mut inflight = 0usize;
                for (&slot, ss) in self.log.range(self.chosen_watermark..) {
                    if ss.chosen {
                        continue;
                    }
                    inflight += 1;
                    if now.saturating_sub(ss.proposed_at) >= retry_after {
                        stale.push((slot, ss.round, ss.value.clone()));
                    }
                }
                for (slot, round, value) in stale {
                    match self.active_round {
                        // A reconfiguration advanced past the slot's
                        // round: re-propose the same value in the current
                        // round/configuration (Optimization 2 — we own
                        // every round in between and proposed only
                        // `value`).
                        Some(active) if active > round => {
                            self.log.remove(&slot);
                            self.propose(slot, value, active, now, fx);
                        }
                        // Thrifty fallback (§8.1) / lost messages: fan out
                        // to every acceptor of the slot's round.
                        _ => {
                            let cfg = self
                                .round_configs
                                .get(&round)
                                .unwrap_or(&self.config)
                                .clone();
                            fx.broadcast_move(&cfg.acceptors, Msg::Phase2A { round, slot, value });
                            if let Some(ss) = self.log.get_mut(&slot) {
                                ss.proposed_at = now;
                            }
                        }
                    }
                }
                if inflight > 0 {
                    fx.timer(retry_after, Timer::Phase2Watchdog);
                } else {
                    self.watchdog_armed = false;
                }
            }
            Timer::BatchFlush => {
                self.batch_timer_armed = false;
                if self.is_leader {
                    self.flush_batch(now, fx);
                    if !self.pending_batch.is_empty() {
                        // No active round yet (installation in flight):
                        // keep the timer alive so the batch flushes soon
                        // after steady state returns.
                        self.batch_timer_armed = true;
                        fx.timer(self.tuner.effective_batch_delay(), Timer::BatchFlush);
                    }
                }
            }
            Timer::PhaseResend { generation } => {
                if generation != self.generation || !self.is_leader {
                    return;
                }
                match &self.install {
                    Install::Matchmaking { .. } => {
                        let msg = Msg::MatchA {
                            group: self.group,
                            round: self.round,
                            config: self.config.clone(),
                        };
                        fx.broadcast(&self.matchmakers.clone(), &msg);
                        fx.timer(self.timing.phase_resend, Timer::PhaseResend { generation });
                    }
                    Install::Phase1 { .. } => {
                        self.send_phase1a(fx);
                        fx.timer(self.timing.phase_resend, Timer::PhaseResend { generation });
                    }
                    // Waiting out the lease fence: nothing to re-send —
                    // the LeaseFence timer finishes the installation.
                    Install::LeaseFence { .. } => {}
                    Install::None => {}
                }
            }
            Timer::LeaseFence => {
                if !self.is_leader {
                    return;
                }
                // The previous leader's possible leases have expired:
                // finish the installation (re-proposals + steady state).
                // A stale timer from an earlier stint fires before the
                // current fence's deadline and is ignored — the timer
                // armed with this fence lifts it.
                if let Install::LeaseFence { until, .. } = &self.install {
                    if now < *until {
                        return;
                    }
                    let Install::LeaseFence { votes, acc_watermark, .. } =
                        std::mem::replace(&mut self.install, Install::None)
                    else {
                        unreachable!()
                    };
                    fx.announce(Announce::FenceLifted { round: self.round });
                    self.finish_phase1(votes, acc_watermark, now, fx);
                }
            }
            Timer::LeaseRenewTick => {
                self.lease_timer_armed = false;
                if !self.is_leader {
                    return;
                }
                if !self.opts.leases.enabled && self.pending_read_index.is_empty() {
                    // Leases off and no confirm rounds needed: let the
                    // chain die (it re-arms from the next ReadIndexReq).
                    self.lease_inflight = None;
                    return;
                }
                // A renewal unanswered for a full refresh interval is
                // dead (lost or nacked): clear it so the next starts.
                let stale = matches!(
                    &self.lease_inflight,
                    Some((_, sent, _)) if now.saturating_sub(*sent) >= self.opts.leases.refresh
                );
                if stale {
                    self.lease_inflight = None;
                }
                self.start_lease_renewal(now, fx);
                if !self.lease_timer_armed {
                    // Not steady right now (installation / matchmaker
                    // migration in flight): keep the chain alive so
                    // renewals resume when steady state returns.
                    self.lease_timer_armed = true;
                    fx.timer(self.opts.leases.refresh, Timer::LeaseRenewTick);
                }
            }
            Timer::HeartbeatTick => {
                if self.is_leader {
                    // Quorum-contact watchdog: in-flight slots that make
                    // no chosen-watermark progress for a full
                    // `quorum_loss_timeout` mean our Phase-2 quorum is
                    // unreachable (minority side of a partition). Step
                    // down instead of stalling proposals forever: clients
                    // get `NotLeader` and chase the majority's leader;
                    // if nobody else elects (we *are* the only proposer),
                    // the LeaderCheck chain re-elects us after a full
                    // election timeout. The Phase2Watchdog keeps retrying
                    // far faster than this fires, so only a genuine loss
                    // of quorum contact trips it.
                    let inflight =
                        self.log.range(self.chosen_watermark..).any(|(_, ss)| !ss.chosen);
                    if !inflight {
                        self.stall_probe = None;
                    } else {
                        match self.stall_probe {
                            Some((wm, since)) if wm == self.chosen_watermark => {
                                if now.saturating_sub(since) >= self.timing.quorum_loss_timeout
                                {
                                    self.is_leader = false;
                                    self.install = Install::None;
                                    self.active_round = None;
                                    self.drop_lease();
                                    self.stall_probe = None;
                                    // Full heartbeat grace before any
                                    // self re-election, so a majority-side
                                    // leader elected meanwhile wins.
                                    self.last_leader_hb = now;
                                    fx.timer(
                                        self.timing.leader_check_period,
                                        Timer::LeaderCheck,
                                    );
                                    return;
                                }
                            }
                            _ => self.stall_probe = Some((self.chosen_watermark, now)),
                        }
                    }
                    let msg = Msg::Heartbeat { epoch: self.round.epoch };
                    for &p in &self.proposers.clone() {
                        if p != self.id {
                            fx.send(p, msg.clone());
                        }
                    }
                    fx.timer(self.timing.heartbeat_period, Timer::HeartbeatTick);
                }
            }
            Timer::LeaderCheck => {
                if !self.is_leader {
                    if now.saturating_sub(self.last_leader_hb) > self.timing.election_timeout {
                        self.become_leader(now, fx);
                    } else {
                        fx.timer(self.timing.leader_check_period, Timer::LeaderCheck);
                    }
                }
            }
            _ => {}
        }
    }

    fn role(&self) -> &'static str {
        "leader"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn state_repr(&self) -> Option<String> {
        use std::fmt::Write;
        // All protocol state, minus absolute timestamps (heartbeat/lease
        // clocks, `Install::LeaseFence::until`'s deadline is kept — it
        // gates behavior) and minus pure metrics counters. The adaptive
        // batching controller (`tuner`) is excluded with them: it holds
        // latency samples (timestamps in disguise) and only influences
        // behavior when `admission =` is configured, which model-checked
        // runs never enable. HashMaps are rendered sorted.
        let mut s = format!(
            "ldr g={} r={:?} cfg={:?} rcfgs={:?} inst={:?} act={:?} next={} cw={} \
             stalled={:?} batch={:?}/{} seq={:?} racks={:?} compacted={} pf1={} wmprop={} \
             gc={:?}/{:?}/{:?} lead={} epoch={} fence={} rb={} gen={} mm={:?} mmgen={} \
             pend={:?} li={:?} pri={:?}",
            self.group,
            self.round,
            self.config,
            self.round_configs,
            self.install,
            self.active_round,
            self.next_slot,
            self.chosen_watermark,
            self.stalled,
            self.pending_batch,
            self.batch_timer_armed,
            self.sequencer.state_repr(),
            self.replica_acks,
            self.compacted_below,
            self.persisted_f1,
            self.last_wm_propagated,
            self.gc.round,
            self.gc.barrier,
            self.gc.stage,
            self.is_leader,
            self.epoch_seen,
            self.lease_fence_pending,
            self.read_barrier,
            self.generation,
            self.mm_reconfig,
            self.mm_generation,
            self.pending_reconfig,
            self.lease_inflight,
            self.pending_read_index.iter().map(|(r, id, _)| (*r, *id)).collect::<Vec<_>>(),
        );
        for (slot, ss) in &self.log {
            // Time-free rendering: `proposed_at` is watchdog bookkeeping,
            // not protocol state — including it would split states that
            // differ only in when (not whether) a slot was proposed.
            let _ = write!(
                s,
                " s{slot}={:?}@{:?} acks={:?} ch={} gen={}",
                ss.value, ss.round, ss.acks, ss.chosen, ss.generation
            );
        }
        #[allow(clippy::disallowed_methods)] // sorted immediately below
        let mut cmds: Vec<_> = self.cmd_slots.iter().collect();
        cmds.sort();
        let _ = write!(s, " cs={cmds:?} rng={:?}", self.rng.state());
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny single-threaded message pump wiring a leader to in-process
    /// matchmaker/acceptor/replica role instances, for leader unit tests.
    /// (Full network effects are exercised by the simulator tests.)
    struct Pump {
        leader: Leader,
        mms: Vec<crate::roles::Matchmaker>,
        accs: Vec<crate::roles::Acceptor>,
        reps: Vec<crate::roles::Replica>,
        announces: Vec<Announce>,
    }

    impl Pump {
        fn new(opts: OptFlags) -> Pump {
            // ids: leader=0; mm=1,2,3; acc=4..10 (pool); rep=10,11,12
            let cfg = Configuration::majority(0, vec![4, 5, 6]);
            let mut leader = Leader::new(
                0,
                1,
                cfg,
                vec![1, 2, 3],
                vec![10, 11, 12],
                vec![0],
                opts,
                7,
            );
            leader.timing.phase_resend = u64::MAX / 2; // no resends in tests
            Pump {
                leader,
                mms: vec![1, 2, 3].into_iter().map(crate::roles::Matchmaker::new).collect(),
                accs: (4..10).map(crate::roles::Acceptor::new).collect(),
                reps: (10..13)
                    .map(|id| crate::roles::Replica::new(id, Box::new(crate::statemachine::Noop)))
                    .collect(),
                announces: Vec::new(),
            }
        }

        /// Deliver all queued effects until quiescent.
        fn pump(&mut self, mut fx: Effects, now: Time) {
            let mut queue: VecDeque<(NodeId, NodeId, Msg)> = VecDeque::new();
            self.announces.extend(fx.announces.drain(..));
            for (to, m) in fx.msgs.drain(..) {
                queue.push_back((0, to, m));
            }
            while let Some((from, to, msg)) = queue.pop_front() {
                let mut fx = Effects::new();
                match to {
                    0 => self.leader.on_msg(now, from, msg, &mut fx),
                    1..=3 => self.mms[(to - 1) as usize].on_msg(now, from, msg, &mut fx),
                    4..=9 => self.accs[(to - 4) as usize].on_msg(now, from, msg, &mut fx),
                    10..=12 => self.reps[(to - 10) as usize].on_msg(now, from, msg, &mut fx),
                    _ => {} // clients: dropped
                }
                self.announces.extend(fx.announces.drain(..));
                for (dst, m) in fx.msgs.drain(..) {
                    queue.push_back((to, dst, m));
                }
            }
        }

        fn start(&mut self) {
            let mut fx = Effects::new();
            self.leader.become_leader(0, &mut fx);
            self.pump(fx, 0);
        }

        fn client_cmd(&mut self, client: NodeId, seq: u64) {
            let mut fx = Effects::new();
            let cmd = Command { client, seq, payload: vec![0] };
            // Closed-loop clients: the request being sent is the oldest
            // (only) one in flight.
            self.leader.on_msg(1, client, Msg::ClientRequest { group: 0, cmd, lowest: seq }, &mut fx);
            self.pump(fx, 1);
        }

        fn chosen_count(&self) -> usize {
            self.announces
                .iter()
                .filter(|a| matches!(a, Announce::Chosen { .. }))
                .count()
        }
    }

    #[test]
    fn leader_startup_reaches_steady() {
        let mut p = Pump::new(OptFlags::default());
        p.start();
        assert!(p.leader.is_steady());
        assert!(p
            .announces
            .iter()
            .any(|a| matches!(a, Announce::LeaderSteady { .. })));
    }

    #[test]
    fn commands_get_chosen_and_executed() {
        let mut p = Pump::new(OptFlags::default());
        p.start();
        for seq in 1..=5 {
            p.client_cmd(100, seq);
        }
        assert_eq!(p.chosen_count(), 5);
        assert_eq!(p.leader.chosen_watermark, 5);
        for r in &p.reps {
            assert_eq!(r.exec_watermark, 5);
        }
    }

    #[test]
    fn duplicate_client_request_not_reassigned() {
        let mut p = Pump::new(OptFlags::default());
        p.start();
        p.client_cmd(100, 1);
        p.client_cmd(100, 1);
        assert_eq!(p.leader.next_slot, 1);
        assert_eq!(p.chosen_count(), 1);
    }

    #[test]
    fn reordered_pipelined_requests_assigned_in_fifo_order() {
        let mut p = Pump::new(OptFlags::default());
        p.start();
        // A pipelined client's seq 2 arrives before seq 1 (both in
        // flight, lowest = 1): seq 2 must wait, then both get slots in
        // client order.
        let c2 = Command { client: 100, seq: 2, payload: vec![0] };
        let mut fx = Effects::new();
        p.leader.on_msg(1, 100, Msg::ClientRequest { group: 0, cmd: c2, lowest: 1 }, &mut fx);
        assert!(fx.msgs.is_empty(), "out-of-order request must buffer");
        assert_eq!(p.leader.next_slot, 0);
        let c1 = Command { client: 100, seq: 1, payload: vec![0] };
        let mut fx2 = Effects::new();
        p.leader.on_msg(1, 100, Msg::ClientRequest { group: 0, cmd: c1, lowest: 1 }, &mut fx2);
        p.pump(fx2, 1);
        assert_eq!(p.leader.next_slot, 2);
        assert_eq!(p.chosen_count(), 2);
        // Slot order matches seq order.
        let slots: Vec<(Slot, u64)> = p
            .announces
            .iter()
            .filter_map(|a| match a {
                Announce::Chosen { slot, value: Value::Cmd(c), .. } => Some((*slot, c.seq)),
                _ => None,
            })
            .collect();
        assert_eq!(slots, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn reconfiguration_with_bypass_keeps_round_configs() {
        let mut p = Pump::new(OptFlags::default());
        p.start();
        p.client_cmd(100, 1);
        let r0 = p.leader.current_round();
        // Reconfigure to a disjoint acceptor set.
        let newcfg = Configuration::majority(1, vec![7, 8, 9]);
        let mut fx = Effects::new();
        p.leader.reconfigure(newcfg.clone(), 2, &mut fx);
        p.pump(fx, 2);
        assert!(p.leader.is_steady());
        assert_eq!(p.leader.current_round(), r0.next());
        assert_eq!(p.leader.current_config(), &newcfg);
        // Commands continue, now against the new acceptors.
        p.client_cmd(100, 2);
        assert_eq!(p.chosen_count(), 2);
        // GC retired the old configuration.
        assert!(p
            .announces
            .iter()
            .any(|a| matches!(a, Announce::ConfigRetired { round, .. } if *round == r0.next())));
        // And the matchmakers' logs only hold the new round.
        for m in &p.mms {
            assert_eq!(m.group_log_len(0), 1);
        }
    }

    #[test]
    fn crash_recovery_raises_epoch_floor_above_used_rounds() {
        let mut p = Pump::new(OptFlags::default());
        p.leader.attach_storage(Box::new(crate::storage::MemStorage::new()));
        p.start();
        let newcfg = Configuration::majority(1, vec![7, 8, 9]);
        let mut fx = Effects::new();
        p.leader.reconfigure(newcfg.clone(), 2, &mut fx);
        p.pump(fx, 2);
        let used = p.leader.current_round();
        // kill -9: the disk survives, the process state does not.
        let disk = p.leader.take_storage().expect("storage attached");
        let cfg = Configuration::majority(0, vec![4, 5, 6]);
        let mut l =
            Leader::new(0, 1, cfg, vec![1, 2, 3], vec![10, 11, 12], vec![0], OptFlags::default(), 7);
        l.attach_storage(disk);
        l.recover();
        assert_eq!(l.current_config(), &newcfg, "newest activated config restored");
        assert!(!l.is_leader, "recovery does not self-elect");
        let mut fx = Effects::new();
        l.become_leader(3, &mut fx);
        assert!(l.current_round() > used, "must re-elect strictly above every used round");
        assert_eq!(l.current_round().epoch, used.epoch + 1);
    }

    #[test]
    fn reconfiguration_without_bypass_runs_phase1() {
        let mut opts = OptFlags::default();
        opts.phase1_bypass = false;
        let mut p = Pump::new(opts);
        p.start();
        p.client_cmd(100, 1);
        let newcfg = Configuration::majority(1, vec![7, 8, 9]);
        let mut fx = Effects::new();
        p.leader.reconfigure(newcfg, 2, &mut fx);
        p.pump(fx, 2);
        // Still reaches steady (Phase 1 runs against the old config which
        // is alive in this pump).
        assert!(p.leader.is_steady());
        p.client_cmd(100, 2);
        assert_eq!(p.chosen_count(), 2);
    }

    #[test]
    fn non_leader_redirects_clients() {
        let cfg = Configuration::majority(0, vec![4, 5, 6]);
        let mut l = Leader::new(1, 1, cfg, vec![1, 2, 3], vec![10], vec![0, 1], OptFlags::default(), 7);
        let mut fx = Effects::new();
        let cmd = Command { client: 100, seq: 1, payload: vec![] };
        l.on_msg(0, 100, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut fx);
        assert!(matches!(fx.msgs[0].1, Msg::NotLeader { .. }));
    }

    #[test]
    fn matchmaker_reconfiguration_switches_set() {
        let mut p = Pump::new(OptFlags::default());
        p.start();
        p.client_cmd(100, 1);
        // Standby matchmakers don't exist in the pump; reuse the same ids
        // reversed to exercise the protocol path (stop → bootstrap →
        // meta-paxos → activate).
        let mut fx = Effects::new();
        p.leader.reconfigure_matchmakers(vec![3, 2, 1], 3, &mut fx);
        p.pump(fx, 3);
        assert_eq!(p.leader.matchmakers, vec![3, 2, 1]);
        assert!(p
            .announces
            .iter()
            .any(|a| matches!(a, Announce::MatchmakersReconfigured { .. })));
        // The protocol still works after the mm reconfiguration.
        let newcfg = Configuration::majority(2, vec![7, 8, 9]);
        let mut fx = Effects::new();
        p.leader.reconfigure(newcfg, 4, &mut fx);
        p.pump(fx, 4);
        assert!(p.leader.is_steady());
        p.client_cmd(100, 2);
        assert_eq!(p.chosen_count(), 2);
    }

    #[test]
    fn batching_packs_commands_into_one_slot() {
        let mut p = Pump::new(OptFlags::default().with_batching(3, u64::MAX / 4));
        p.start();
        // Deliver three requests without pumping, so they accumulate
        // instead of completing one at a time.
        let mut fx = Effects::new();
        for seq in 1..=2 {
            let cmd = Command { client: 100, seq, payload: vec![0] };
            p.leader.on_msg(1, 100, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut fx);
        }
        assert!(fx.msgs.is_empty(), "commands must buffer until the batch fills");
        let cmd = Command { client: 101, seq: 1, payload: vec![0] };
        p.leader.on_msg(1, 101, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut fx);
        assert!(!fx.msgs.is_empty(), "a full batch must flush immediately");
        p.pump(fx, 1);
        // One slot chose all three commands; replicas executed each.
        assert_eq!(p.leader.next_slot, 1);
        assert_eq!(p.chosen_count(), 1);
        for r in &p.reps {
            assert_eq!(r.exec_watermark, 1);
            assert_eq!(r.executed, 3);
        }
    }

    #[test]
    fn partial_batch_flushes_on_timer() {
        let mut p = Pump::new(OptFlags::default().with_batching(8, 42));
        p.start();
        let mut fx = Effects::new();
        let cmd = Command { client: 100, seq: 1, payload: vec![0] };
        p.leader.on_msg(1, 100, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut fx);
        assert!(fx.msgs.is_empty());
        assert!(fx
            .timers
            .iter()
            .any(|(d, t)| *d == 42 && matches!(t, Timer::BatchFlush)));
        let mut fx2 = Effects::new();
        p.leader.on_timer(43, Timer::BatchFlush, &mut fx2);
        p.pump(fx2, 43);
        assert_eq!(p.chosen_count(), 1);
        for r in &p.reps {
            assert_eq!(r.executed, 1);
        }
    }

    fn lease_opts() -> OptFlags {
        let mut o = OptFlags::default();
        o.leases = crate::config::LeaseSpec::every(50 * MS, 2 * MS, crate::US);
        o
    }

    #[test]
    fn lease_fence_gates_first_proposals_after_election() {
        let mut p = Pump::new(lease_opts());
        p.start();
        // Phase 1 completed, but the fence holds: not steady, and a
        // client command stalls instead of being proposed.
        assert!(!p.leader.is_steady(), "leases on: must wait out the fence");
        let mut fx = Effects::new();
        let cmd = Command { client: 100, seq: 1, payload: vec![0] };
        p.leader.on_msg(1, 100, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut fx);
        assert!(fx.msgs.is_empty(), "commands must stall during the fence");
        assert_eq!(p.chosen_count(), 0);
        // A stale (premature) fence timer is ignored.
        let mut early = Effects::new();
        p.leader.on_timer(MS, Timer::LeaseFence, &mut early);
        assert!(!p.leader.is_steady());
        // The real fence lifts: steady, the stalled command is chosen,
        // and the renewal chain produced a self-lease plus grants.
        let mut fx2 = Effects::new();
        p.leader.on_timer(51 * MS, Timer::LeaseFence, &mut fx2);
        p.pump(fx2, 51 * MS);
        assert!(p.leader.is_steady());
        assert_eq!(p.chosen_count(), 1);
        assert!(p.leader.lease_valid_until > 51 * MS, "renewal quorum confirmed");
        for r in &p.reps {
            assert!(r.lease_active(52 * MS), "replica {} missing a grant", r.id);
        }
    }

    #[test]
    fn read_index_fast_under_self_lease_confirmed_without() {
        let mut p = Pump::new(lease_opts());
        p.start();
        let mut fxf = Effects::new();
        p.leader.on_timer(51 * MS, Timer::LeaseFence, &mut fxf);
        p.pump(fxf, 51 * MS);
        // Active self-lease: immediate ReadIndexResp, no quorum round.
        let mut fx = Effects::new();
        p.leader.on_msg(52 * MS, 10, Msg::ReadIndexReq { id: 1 }, &mut fx);
        assert!(fx
            .msgs
            .iter()
            .any(|(to, m)| *to == 10 && matches!(m, Msg::ReadIndexResp { id: 1, .. })));
        assert_eq!(p.leader.read_index_fast, 1);
        // Past expiry: the answer is deferred until a renewal sent at or
        // after the request completes at a P2 quorum.
        let late = p.leader.lease_valid_until + MS;
        let mut fx2 = Effects::new();
        p.leader.on_msg(late, 10, Msg::ReadIndexReq { id: 2 }, &mut fx2);
        assert!(
            fx2.msgs.iter().all(|(_, m)| !matches!(m, Msg::ReadIndexResp { .. })),
            "no immediate answer without an active self-lease"
        );
        p.pump(fx2, late);
        assert_eq!(p.leader.read_index_confirmed, 1);
        assert!(p.leader.lease_valid_until > late);
    }

    #[test]
    fn nack_deposes_leader_and_drops_lease() {
        let mut p = Pump::new(lease_opts());
        p.start();
        let mut fxf = Effects::new();
        p.leader.on_timer(51 * MS, Timer::LeaseFence, &mut fxf);
        p.pump(fxf, 51 * MS);
        assert!(p.leader.lease_valid_until > 0);
        let higher = Round { epoch: 9, proposer: 1, seq: 0 };
        let mut fx = Effects::new();
        p.leader.on_msg(
            60 * MS,
            4,
            Msg::Nack { round: p.leader.current_round(), higher },
            &mut fx,
        );
        assert!(!p.leader.is_leader);
        assert_eq!(p.leader.lease_valid_until, 0, "deposed leader must drop its lease");
        // A ReadIndex request now gets a redirect, never a watermark.
        let mut fx2 = Effects::new();
        p.leader.on_msg(61 * MS, 10, Msg::ReadIndexReq { id: 3 }, &mut fx2);
        assert!(fx2.msgs.iter().any(|(_, m)| matches!(m, Msg::NotLeader { .. })));
        assert!(fx2.msgs.iter().all(|(_, m)| !matches!(m, Msg::ReadIndexResp { .. })));
    }

    #[test]
    fn leases_disabled_no_fence_no_grants() {
        // The default path is byte-for-byte the old behavior: steady
        // immediately after startup, no lease traffic at all.
        let mut p = Pump::new(OptFlags::default());
        p.start();
        assert!(p.leader.is_steady());
        assert_eq!(p.leader.lease_valid_until, 0);
        for r in &p.reps {
            assert!(!r.lease_active(MS));
        }
    }

    #[test]
    fn snapshot_mode_truncates_leader_log_and_compacts_acceptors() {
        let mut opts = OptFlags::default();
        opts.snapshot = crate::config::SnapshotSpec { enabled: true, interval: MS, tail: 64 };
        let mut p = Pump::new(opts);
        p.start();
        for seq in 1..=400 {
            p.client_cmd(100, seq);
        }
        assert_eq!(p.leader.chosen_watermark, 400);
        // The leader truncated its log (and slot routing) at the durable
        // watermark minus the retained tail — without waiting for every
        // replica, which is what keeps memory bounded on long runs.
        assert!(
            p.leader.compacted_below >= 256,
            "leader never truncated: compacted_below = {}",
            p.leader.compacted_below
        );
        assert!(p.leader.log.len() < 200, "leader log unbounded: {}", p.leader.log.len());
        // The steady-state watermark reached the acceptors (no
        // reconfiguration happened since startup) and they compacted
        // voted state below it.
        let acc = &p.accs[0]; // id 4: member of the initial configuration
        assert!(acc.chosen_watermark >= 256, "no watermark propagated: {}", acc.chosen_watermark);
        assert!(acc.votes.len() < 150, "acceptor votes unbounded: {}", acc.votes.len());
    }

    #[test]
    fn ack_below_truncated_prefix_gets_catchup_hint() {
        let mut opts = OptFlags::default();
        opts.snapshot = crate::config::SnapshotSpec { enabled: true, interval: MS, tail: 64 };
        let mut p = Pump::new(opts);
        p.start();
        for seq in 1..=400 {
            p.client_cmd(100, seq);
        }
        assert!(p.leader.compacted_below > 0);
        // A replica that lost its state acks 0 twice (no progress): the
        // leader cannot re-send truncated entries, so it must name a
        // caught-up peer for snapshot transfer.
        let mut fx = Effects::new();
        p.leader.on_msg(5, 10, Msg::ReplicaAck { upto: 0 }, &mut fx);
        let catchup = fx.msgs.iter().find_map(|(to, m)| match m {
            Msg::CatchUp { below, peer } => Some((*to, *below, *peer)),
            _ => None,
        });
        let (to, below, peer) = catchup.expect("expected a CatchUp hint");
        assert_eq!(to, 10);
        assert_eq!(below, p.leader.chosen_watermark);
        assert!(peer != 10 && (11..=12).contains(&peer), "bad peer {peer}");
    }

    #[test]
    fn stalled_commands_drain_on_steady() {
        // Without proactive matchmaking, commands during matchmaking stall
        // but are not lost (§8.2 ablation behavior).
        let mut opts = OptFlags::default();
        opts.proactive_matchmaking = false;
        opts.phase1_bypass = false;
        let mut p = Pump::new(opts);
        p.start();
        // Inject a command while matchmaking is in flight: do it manually
        // (don't pump matchmaking yet).
        let newcfg = Configuration::majority(1, vec![7, 8, 9]);
        let mut fx = Effects::new();
        p.leader.reconfigure(newcfg, 2, &mut fx);
        // Leader is now matchmaking and NOT steady.
        assert!(!p.leader.is_steady());
        let mut fx2 = Effects::new();
        let cmd = Command { client: 100, seq: 1, payload: vec![] };
        p.leader.on_msg(2, 100, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut fx2);
        assert!(fx2.msgs.is_empty()); // stalled
        // Now deliver the matchmaking + phase1 messages.
        p.pump(fx, 3);
        p.pump(fx2, 3);
        assert!(p.leader.is_steady());
        assert_eq!(p.chosen_count(), 1);
    }

    // ---- Adaptive batching controller (DESIGN.md §Overload) ----

    /// A tuner with admission enabled at `target_us`, bounds
    /// `batch ∈ [1, batch]`, `delay ∈ [delay/16, delay]`.
    fn tuner(batch: usize, delay: Time, target_us: u64) -> BatchTuner {
        let opts = OptFlags::none()
            .with_batching(batch, delay)
            .with_admission(crate::config::AdmissionSpec::slo(1024, target_us, false));
        BatchTuner::new(&opts)
    }

    /// Feed `n` identical latency samples.
    fn feed(t: &mut BatchTuner, latency: Time, n: usize) {
        for _ in 0..n {
            t.observe(latency);
        }
    }

    #[test]
    fn tuner_disabled_is_identity() {
        // Without an `admission =` line the controller must be inert:
        // configured knobs verbatim, no samples retained.
        let mut t = BatchTuner::new(&OptFlags::none().with_batching(8, 42));
        feed(&mut t, 500 * MS, 1000);
        assert_eq!(t.effective_batch_size(), 8);
        assert_eq!(t.effective_batch_delay(), 42);
        assert_eq!(t.windowed_p99(), 0);
    }

    #[test]
    fn tuner_converges_from_both_directions() {
        // Target 1ms. Cold load (100µs p99): batch walks down to 1 and
        // the delay relaxes back to the configured ceiling. Then a hot
        // step (50ms p99): batch climbs back to the ceiling and the delay
        // drops to its floor.
        let mut t = tuner(16, MS, 1_000);
        feed(&mut t, 100 * US, 1024);
        assert_eq!(t.effective_batch_size(), 1, "cold load should reach minimal batching");
        assert_eq!(t.effective_batch_delay(), MS);
        feed(&mut t, 50 * MS, 1024);
        assert_eq!(t.effective_batch_size(), 16, "hot load should reach the batch ceiling");
        assert_eq!(t.effective_batch_delay(), MS / 16, "hot load should floor the delay");
    }

    #[test]
    fn tuner_respects_bounds_under_sustained_extremes() {
        let mut t = tuner(8, 160, 1_000);
        // Sustained extreme overload: knobs saturate at the bounds and
        // stay there — no overflow, no runaway.
        feed(&mut t, 10_000 * MS, 4096);
        assert_eq!(t.effective_batch_size(), 8);
        assert_eq!(t.effective_batch_delay(), 10); // 160/16 floor
        // Sustained idle: back to [1, configured delay].
        feed(&mut t, 1, 4096);
        assert_eq!(t.effective_batch_size(), 1);
        assert_eq!(t.effective_batch_delay(), 160);
    }

    #[test]
    fn tuner_holds_steady_inside_hysteresis_band() {
        // Samples inside the ±10% band must not move the knobs at all:
        // a steady load at the target does not oscillate.
        let mut t = tuner(16, MS, 1_000);
        let (b0, d0) = (t.effective_batch_size(), t.effective_batch_delay());
        feed(&mut t, 1_000 * US, 2048); // exactly on target
        assert_eq!((t.effective_batch_size(), t.effective_batch_delay()), (b0, d0));
        // And once converged after a step change, further identical load
        // leaves the knobs fixed (no limit cycle).
        feed(&mut t, 50 * MS, 1024);
        let hot = (t.effective_batch_size(), t.effective_batch_delay());
        feed(&mut t, 50 * MS, 1024);
        assert_eq!((t.effective_batch_size(), t.effective_batch_delay()), hot);
    }

    #[test]
    fn leader_sheds_with_busy_beyond_inbox_bound_without_sequencer_effects() {
        // inbox:2 — the third concurrent command is refused with Busy,
        // and the refusal must not perturb the per-client FIFO: the same
        // seq retried later is admitted normally.
        let mut opts = OptFlags::default();
        opts.admission = crate::config::AdmissionSpec::slo(2, 5_000, false);
        let mut p = Pump::new(opts);
        p.start();
        // Two commands proposed but NOT pumped to acceptors: they stay
        // unchosen, holding the inbox at its bound.
        let mut held = Effects::new();
        for seq in 1..=2u64 {
            let cmd = Command { client: 100, seq, payload: vec![] };
            p.leader.on_msg(2, 100, Msg::ClientRequest { group: 0, cmd, lowest: 1 }, &mut held);
        }
        assert_eq!(p.leader.inbox_depth(), 2);
        let repr_before = p.leader.state_repr();
        let mut fx = Effects::new();
        let cmd3 = Command { client: 100, seq: 3, payload: vec![] };
        p.leader.on_msg(2, 100, Msg::ClientRequest { group: 0, cmd: cmd3.clone(), lowest: 1 }, &mut fx);
        let busy = fx.msgs.iter().find_map(|(to, m)| match m {
            Msg::Busy { group, seq, retry_after_us } => Some((*to, *group, *seq, *retry_after_us)),
            _ => None,
        });
        assert_eq!(busy, Some((100, 0, 3, 5_000)));
        assert_eq!(p.leader.busy_rejections, 1);
        // A shed is a drop, not an ack: no sequencer/log side effects.
        assert_eq!(p.leader.state_repr(), repr_before);
        // Drain the held proposals to choice; the retried seq 3 is then
        // admitted in FIFO position.
        p.pump(held, 3);
        assert_eq!(p.chosen_count(), 2);
        let mut fx2 = Effects::new();
        p.leader.on_msg(4, 100, Msg::ClientRequest { group: 0, cmd: cmd3, lowest: 1 }, &mut fx2);
        p.pump(fx2, 4);
        assert_eq!(p.chosen_count(), 3);
    }
}
