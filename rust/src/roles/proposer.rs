//! Single-decree proposers: Matchmaker Paxos (Algorithm 3) and the
//! Matchmaker Fast Paxos variant of §7 (Algorithm 5).
//!
//! These are the paper's consensus-layer protocols, kept separate from the
//! MultiPaxos [`super::leader`] so the theory sections (§3, §7) have a
//! direct, testable counterpart. [`Proposer`] implements Optimization 4
//! (round pruning): a vote in round `vr` removes the obligation to
//! intersect configurations in rounds `< vr`.

use crate::config::Configuration;
use crate::msg::{Msg, Value};
use crate::node::{Announce, Effects, Node, Timer};
use crate::round::Round;
use crate::{NodeId, Time};
use std::collections::{BTreeMap, BTreeSet};

/// Phases of a single-decree proposal.
#[derive(Debug)]
enum Phase {
    Idle,
    Matchmaking {
        acks: BTreeMap<NodeId, (Option<Round>, BTreeMap<Round, Configuration>)>,
    },
    Phase1 {
        prior: BTreeMap<Round, Configuration>,
        acked: BTreeSet<NodeId>,
        /// Largest `(vr, vv)` pair seen.
        best: Option<(Round, Value)>,
        /// Optimization 4: configurations at rounds `< max_vr` are pruned
        /// from the intersection obligation.
        max_vr: Option<Round>,
    },
    Phase2 {
        value: Value,
        acks: BTreeSet<NodeId>,
    },
    Done,
}

/// A single-decree Matchmaker Paxos proposer (Algorithm 3).
pub struct Proposer {
    /// This node's id.
    pub id: NodeId,
    /// Fault-tolerance parameter.
    pub f: usize,
    /// The matchmaker set (f+1 answers complete matchmaking).
    pub matchmakers: Vec<NodeId>,
    /// Whether Optimization 4 (round pruning) is enabled.
    pub round_pruning: bool,
    round: Round,
    config: Configuration,
    /// The client value to propose (may be displaced by a Phase-1 find).
    value: Option<Value>,
    phase: Phase,
    /// Phase-1-bypass credit (Optimization 2): set when a completed round
    /// established `k = -1` without proposing, or proposed `v`; the next
    /// owned round may skip Phase 1 (and must re-propose `v` if set).
    bypass_credit: Option<Option<Value>>,
    /// The chosen value once known.
    pub chosen: Option<Value>,
}

impl Proposer {
    /// A single-decree proposer starting from `config`.
    pub fn new(id: NodeId, f: usize, matchmakers: Vec<NodeId>, config: Configuration) -> Proposer {
        Proposer {
            id,
            f,
            matchmakers,
            round_pruning: true,
            round: Round { epoch: 0, proposer: id, seq: u64::MAX }, // pre-first
            config,
            value: None,
            phase: Phase::Idle,
            bypass_credit: None,
            chosen: None,
        }
    }

    fn advance_round(&mut self) {
        self.round = if self.round.seq == u64::MAX {
            Round::first(0, self.id)
        } else {
            self.round.next()
        };
    }

    /// Propose `value` using `config` for this round (Algorithm 3 lines
    /// 1–5). Matchmaking phase starts immediately.
    pub fn propose(&mut self, value: Value, config: Configuration, _now: Time, fx: &mut Effects) {
        self.advance_round();
        self.config = config;
        self.value = Some(value);
        self.phase = Phase::Matchmaking { acks: BTreeMap::new() };
        fx.broadcast(
            &self.matchmakers.clone(),
            &Msg::MatchA { group: 0, round: self.round, config: self.config.clone() },
        );
    }

    /// Re-run with a fresh round (dueling-proposer recovery). The caller's
    /// value is retained.
    pub fn retry(&mut self, _now: Time, fx: &mut Effects) {
        let value = self.value.clone().expect("retry without a proposal");
        let config = self.config.clone();
        self.propose(value, config, _now, fx);
    }

    fn finish_phase1(&mut self, fx: &mut Effects) {
        let Phase::Phase1 { best, .. } = &self.phase else {
            return;
        };
        // Algorithm 3 lines 10–12: k ≠ -1 → adopt the vote value.
        let value = match best {
            Some((_, vv)) => {
                self.bypass_credit = Some(Some(vv.clone()));
                vv.clone()
            }
            None => {
                // k = -1: free to propose our own value; record the
                // Optimization-2 credit for the next owned round.
                self.bypass_credit = Some(None);
                self.value.clone().expect("no value to propose")
            }
        };
        self.phase = Phase::Phase2 { value: value.clone(), acks: BTreeSet::new() };
        let msg = Msg::Phase2A { round: self.round, slot: 0, value };
        fx.broadcast(&self.config.acceptors.clone(), &msg);
    }
}

impl Node for Proposer {
    fn on_msg(&mut self, _now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::MatchB { round, gc_watermark, prior, .. } => {
                if round != self.round {
                    return;
                }
                let Phase::Matchmaking { acks } = &mut self.phase else {
                    return;
                };
                acks.insert(from, (gc_watermark, prior));
                if acks.len() < self.f + 1 {
                    return;
                }
                // H_i = union of priors, pruned below the max watermark.
                let mut h: BTreeMap<Round, Configuration> = BTreeMap::new();
                let mut wm: Option<Round> = None;
                for (w, prior) in acks.values() {
                    for (r, c) in prior {
                        h.insert(*r, c.clone());
                    }
                    if let Some(w) = w {
                        if wm.map_or(true, |cur| *w > cur) {
                            wm = Some(*w);
                        }
                    }
                }
                if let Some(w) = wm {
                    h = h.split_off(&w);
                }
                h.remove(&self.round);
                self.phase = Phase::Phase1 {
                    prior: h,
                    acked: BTreeSet::new(),
                    best: None,
                    max_vr: None,
                };
                // Phase 1 with every prior configuration (skip if none).
                let Phase::Phase1 { prior, .. } = &self.phase else {
                    unreachable!()
                };
                if prior.is_empty() {
                    self.finish_phase1(fx);
                } else {
                    let mut targets: BTreeSet<NodeId> = BTreeSet::new();
                    for c in prior.values() {
                        targets.extend(c.acceptors.iter().copied());
                    }
                    for t in targets {
                        fx.send(t, Msg::Phase1A { round: self.round, from_slot: 0 });
                    }
                }
            }

            Msg::Phase1B { round, votes, .. } => {
                if round != self.round {
                    return;
                }
                let pruning = self.round_pruning;
                let Phase::Phase1 { prior, acked, best, max_vr } = &mut self.phase else {
                    return;
                };
                acked.insert(from);
                for v in votes.iter().filter(|v| v.slot == 0) {
                    if best.as_ref().map_or(true, |(br, _)| v.vr > *br) {
                        *best = Some((v.vr, v.vv.clone()));
                    }
                    if max_vr.map_or(true, |m| v.vr > m) {
                        *max_vr = Some(v.vr);
                    }
                }
                // Optimization 4: intersect only configurations at rounds
                // ≥ max_vr (earlier rounds cannot change the outcome).
                let needed: Vec<&Configuration> = prior
                    .iter()
                    .filter(|(r, _)| !pruning || max_vr.map_or(true, |m| **r >= m))
                    .map(|(_, c)| c)
                    .collect();
                if needed.iter().all(|c| c.is_p1_quorum(acked)) {
                    self.finish_phase1(fx);
                }
            }

            Msg::Phase2B { round, slot: 0 } => {
                if round != self.round {
                    return;
                }
                let Phase::Phase2 { value, acks } = &mut self.phase else {
                    return;
                };
                acks.insert(from);
                if self.config.is_p2_quorum(acks) {
                    let value = value.clone();
                    self.chosen = Some(value.clone());
                    fx.announce(Announce::Chosen { group: 0, slot: 0, round, value });
                    self.phase = Phase::Done;
                }
            }

            Msg::MatchNack { .. } | Msg::Nack { .. } => {
                // Dueling proposers: the harness decides when to retry.
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, _timer: Timer, _fx: &mut Effects) {}

    fn role(&self) -> &'static str {
        "proposer"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ===========================================================================
// Matchmaker Fast Paxos (§7, Algorithm 5)
// ===========================================================================

/// Coordinator states for the fast variant.
#[derive(Debug)]
enum FastPhase {
    Idle,
    Matchmaking {
        acks: BTreeMap<NodeId, (Option<Round>, BTreeMap<Round, Configuration>)>,
    },
    Phase1 {
        prior: BTreeMap<Round, Configuration>,
        acked: BTreeSet<NodeId>,
        /// All votes seen: acceptor → (vr, vv). Fast value selection needs
        /// the *set* of values at the max round, not just one.
        votes: BTreeMap<NodeId, (Round, Value)>,
    },
    /// Fast round open: clients propose directly to the acceptors; we
    /// collect their votes here.
    FastListen { votes: BTreeMap<NodeId, Value> },
    /// Classic recovery round after a conflict.
    Phase2 { value: Value, acks: BTreeSet<NodeId> },
    Done,
}

/// The Matchmaker Fast Paxos coordinator (§7): deploys `f+1` acceptors
/// with singleton P1 quorums and a single unanimous P2 quorum — the first
/// protocol to meet the Fast Paxos quorum-size lower bound.
pub struct FastProposer {
    /// This node's id.
    pub id: NodeId,
    /// Fault-tolerance parameter.
    pub f: usize,
    /// The matchmaker set.
    pub matchmakers: Vec<NodeId>,
    round: Round,
    config: Configuration,
    phase: FastPhase,
    /// Default value proposed on conflicted recovery ("any", Algorithm 5
    /// lines 11/15) — deterministic: the lexicographically first conflicting
    /// value.
    pub chosen: Option<Value>,
}

impl FastProposer {
    /// `config` must use [`crate::quorum::QuorumSpec::FastUnanimous`] over
    /// `f+1` acceptors.
    pub fn new(id: NodeId, f: usize, matchmakers: Vec<NodeId>, config: Configuration) -> FastProposer {
        FastProposer {
            id,
            f,
            matchmakers,
            round: Round { epoch: 0, proposer: id, seq: u64::MAX },
            config,
            phase: FastPhase::Idle,
            chosen: None,
        }
    }

    /// Open a fast round (Algorithm 5 lines 1–3): matchmaking, then Phase 1
    /// with prior configurations, then — if no value constrains us — the
    /// fast path where clients propose directly to the acceptors.
    pub fn open_round(&mut self, _now: Time, fx: &mut Effects) {
        self.round = if self.round.seq == u64::MAX {
            Round::first(0, self.id)
        } else {
            self.round.next()
        };
        self.phase = FastPhase::Matchmaking { acks: BTreeMap::new() };
        fx.broadcast(
            &self.matchmakers.clone(),
            &Msg::MatchA { group: 0, round: self.round, config: self.config.clone() },
        );
    }

    /// The current round, so clients know where to send `FastPropose`.
    pub fn fast_round(&self) -> Option<Round> {
        matches!(self.phase, FastPhase::FastListen { .. }).then_some(self.round)
    }

    fn value_selection(&mut self, fx: &mut Effects) {
        // Algorithm 5 lines 8–15 over the votes collected in Phase 1.
        let FastPhase::Phase1 { votes, .. } = &self.phase else {
            return;
        };
        let k = votes.values().map(|(vr, _)| *vr).max();
        match k {
            None => {
                // k = -1: open the fast path (line 11 proposes "any" — in
                // the fast variant "any" means letting clients race).
                self.phase = FastPhase::FastListen { votes: BTreeMap::new() };
            }
            Some(k) => {
                let mut vals: Vec<&Value> =
                    votes.values().filter(|(vr, _)| *vr == k).map(|(_, v)| v).collect();
                vals.sort_by_key(|v| crate::codec::Wire::encode(*v));
                vals.dedup();
                // |V| = 1 → propose v; else propose "any" (deterministically
                // the first value).
                let value = (*vals[0]).clone();
                self.phase = FastPhase::Phase2 { value: value.clone(), acks: BTreeSet::new() };
                fx.broadcast(
                    &self.config.acceptors.clone(),
                    &Msg::Phase2A { round: self.round, slot: 0, value },
                );
            }
        }
    }
}

impl Node for FastProposer {
    fn on_msg(&mut self, now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::MatchB { round, gc_watermark, prior, .. } => {
                if round != self.round {
                    return;
                }
                let FastPhase::Matchmaking { acks } = &mut self.phase else {
                    return;
                };
                acks.insert(from, (gc_watermark, prior));
                if acks.len() < self.f + 1 {
                    return;
                }
                let mut h: BTreeMap<Round, Configuration> = BTreeMap::new();
                let mut wm: Option<Round> = None;
                for (w, prior) in acks.values() {
                    for (r, c) in prior {
                        h.insert(*r, c.clone());
                    }
                    if let Some(w) = w {
                        if wm.map_or(true, |cur| *w > cur) {
                            wm = Some(*w);
                        }
                    }
                }
                if let Some(w) = wm {
                    h = h.split_off(&w);
                }
                h.remove(&self.round);
                if h.is_empty() {
                    self.phase = FastPhase::Phase1 {
                        prior: h,
                        acked: BTreeSet::new(),
                        votes: BTreeMap::new(),
                    };
                    self.value_selection(fx);
                } else {
                    let mut targets: BTreeSet<NodeId> = BTreeSet::new();
                    for c in h.values() {
                        targets.extend(c.acceptors.iter().copied());
                    }
                    self.phase = FastPhase::Phase1 {
                        prior: h,
                        acked: BTreeSet::new(),
                        votes: BTreeMap::new(),
                    };
                    for t in targets {
                        fx.send(t, Msg::Phase1A { round: self.round, from_slot: 0 });
                    }
                }
            }

            Msg::Phase1B { round, votes: vs, .. } => {
                if round != self.round {
                    return;
                }
                let FastPhase::Phase1 { prior, acked, votes } = &mut self.phase else {
                    return;
                };
                acked.insert(from);
                for v in vs.iter().filter(|v| v.slot == 0) {
                    votes.insert(from, (v.vr, v.vv.clone()));
                }
                if prior.values().all(|c| c.is_p1_quorum(acked)) {
                    self.value_selection(fx);
                }
            }

            // Fast-round votes stream in from the acceptors.
            Msg::FastPhase2B { round, value } => {
                if round != self.round {
                    return;
                }
                let n_acceptors = self.config.acceptors.len();
                let FastPhase::FastListen { votes } = &mut self.phase else {
                    return;
                };
                votes.insert(from, value);
                if votes.len() < n_acceptors {
                    return;
                }
                // Unanimous P2 quorum: all acceptors voted. Same value →
                // chosen on the fast path; conflict → coordinated recovery
                // in the next round (classic path; Phase 1 sees the fast
                // votes and Algorithm 5's selection rule applies).
                let first = votes.values().next().unwrap().clone();
                if votes.values().all(|v| *v == first) {
                    self.chosen = Some(first.clone());
                    fx.announce(Announce::FastChosen { round, value: first.clone() });
                    fx.announce(Announce::Chosen { group: 0, slot: 0, round, value: first });
                    self.phase = FastPhase::Done;
                } else {
                    self.open_round(now, fx);
                }
            }

            Msg::Phase2B { round, slot: 0 } => {
                if round != self.round {
                    return;
                }
                let FastPhase::Phase2 { value, acks } = &mut self.phase else {
                    return;
                };
                acks.insert(from);
                if self.config.is_p2_quorum(acks) {
                    let value = value.clone();
                    self.chosen = Some(value.clone());
                    fx.announce(Announce::Chosen { group: 0, slot: 0, round, value });
                    self.phase = FastPhase::Done;
                }
            }

            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, _timer: Timer, _fx: &mut Effects) {}

    fn role(&self) -> &'static str {
        "fast-proposer"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Command;
    use crate::roles::{Acceptor, Matchmaker};
    use std::collections::VecDeque;

    /// Message pump over proposer + matchmakers + a pool of acceptors.
    struct Net {
        mms: Vec<Matchmaker>,
        accs: Vec<Acceptor>,
        announces: Vec<Announce>,
    }

    impl Net {
        fn new(n_mm: usize, n_acc: usize, fast: bool) -> Net {
            Net {
                mms: (1..=n_mm as NodeId).map(Matchmaker::new).collect(),
                accs: (10..10 + n_acc as NodeId)
                    .map(|id| if fast { Acceptor::new_fast(id) } else { Acceptor::new(id) })
                    .collect(),
                announces: Vec::new(),
            }
        }

        fn pump<P: Node>(&mut self, p: &mut P, pid: NodeId, fx: Effects) {
            let mut q: VecDeque<(NodeId, NodeId, Msg)> = VecDeque::new();
            self.announces.extend(fx.announces);
            for (to, m) in fx.msgs {
                q.push_back((pid, to, m));
            }
            while let Some((from, to, msg)) = q.pop_front() {
                let mut fx = Effects::new();
                if to == pid {
                    p.on_msg(0, from, msg, &mut fx);
                } else if (1..=self.mms.len() as NodeId).contains(&to) {
                    self.mms[(to - 1) as usize].on_msg(0, from, msg, &mut fx);
                } else if to >= 10 && to < 10 + self.accs.len() as NodeId {
                    self.accs[(to - 10) as usize].on_msg(0, from, msg, &mut fx);
                }
                self.announces.extend(fx.announces);
                for (dst, m) in fx.msgs {
                    q.push_back((to, dst, m));
                }
            }
        }
    }

    fn val(tag: u8) -> Value {
        Value::Cmd(Command { client: 100 + tag as NodeId, seq: 1, payload: vec![tag] })
    }

    #[test]
    fn single_decree_chooses_value() {
        let cfg = Configuration::majority(0, vec![10, 11, 12]);
        let mut net = Net::new(3, 3, false);
        let mut p = Proposer::new(0, 1, vec![1, 2, 3], cfg.clone());
        let mut fx = Effects::new();
        p.propose(val(1), cfg, 0, &mut fx);
        net.pump(&mut p, 0, fx);
        assert_eq!(p.chosen, Some(val(1)));
    }

    #[test]
    fn second_proposer_learns_first_value() {
        // p1 chooses x with config A; p2 proposes y with a different
        // config B but must learn and re-propose x (safety across
        // reconfiguration).
        let cfg_a = Configuration::majority(0, vec![10, 11, 12]);
        let cfg_b = Configuration::majority(1, vec![13, 14, 15]);
        let mut net = Net::new(3, 6, false);

        let mut p1 = Proposer::new(0, 1, vec![1, 2, 3], cfg_a.clone());
        let mut fx = Effects::new();
        p1.propose(val(1), cfg_a, 0, &mut fx);
        net.pump(&mut p1, 0, fx);
        assert_eq!(p1.chosen, Some(val(1)));

        let mut p2 = Proposer::new(5, 1, vec![1, 2, 3], cfg_b.clone());
        let mut fx = Effects::new();
        p2.propose(val(2), cfg_b, 0, &mut fx);
        net.pump(&mut p2, 5, fx);
        // p2 must choose val(1), not its own val(2).
        assert_eq!(p2.chosen, Some(val(1)));
    }

    #[test]
    fn round_pruning_reduces_obligations() {
        let cfg = Configuration::majority(0, vec![10, 11, 12]);
        let mut p = Proposer::new(0, 1, vec![1, 2, 3], cfg);
        assert!(p.round_pruning);
        p.round_pruning = false; // both settings must choose identically
        let cfg = Configuration::majority(0, vec![10, 11, 12]);
        let mut net = Net::new(3, 3, false);
        let mut fx = Effects::new();
        p.propose(val(3), cfg, 0, &mut fx);
        net.pump(&mut p, 0, fx);
        assert_eq!(p.chosen, Some(val(3)));
    }

    #[test]
    fn fast_path_no_conflict() {
        // f = 1 → f+1 = 2 acceptors, unanimous P2, singleton P1 (§7).
        let cfg = Configuration {
            id: 0,
            acceptors: vec![10, 11],
            quorum: crate::quorum::QuorumSpec::FastUnanimous,
        };
        let mut net = Net::new(3, 2, true);
        let mut p = FastProposer::new(0, 1, vec![1, 2, 3], cfg);
        let mut fx = Effects::new();
        p.open_round(0, &mut fx);
        net.pump(&mut p, 0, fx);
        let round = p.fast_round().expect("fast round open");

        // One client proposes to both acceptors: chosen in one round trip.
        let mut fx = Effects::new();
        fx.send(10, Msg::FastPropose { round, value: val(7) });
        fx.send(11, Msg::FastPropose { round, value: val(7) });
        net.pump(&mut p, 0, fx);
        assert_eq!(p.chosen, Some(val(7)));
        assert!(net.announces.iter().any(|a| matches!(a, Announce::FastChosen { .. })));
    }

    #[test]
    fn fast_path_conflict_recovers() {
        let cfg = Configuration {
            id: 0,
            acceptors: vec![10, 11],
            quorum: crate::quorum::QuorumSpec::FastUnanimous,
        };
        let mut net = Net::new(3, 2, true);
        let mut p = FastProposer::new(0, 1, vec![1, 2, 3], cfg);
        let mut fx = Effects::new();
        p.open_round(0, &mut fx);
        net.pump(&mut p, 0, fx);
        let round = p.fast_round().unwrap();

        // Two clients race with different values: acceptor 10 sees val(1)
        // first, acceptor 11 sees val(2) first → conflict → coordinated
        // recovery must still choose exactly one of them.
        let mut fx = Effects::new();
        fx.send(10, Msg::FastPropose { round, value: val(1) });
        fx.send(11, Msg::FastPropose { round, value: val(2) });
        net.pump(&mut p, 0, fx);
        let chosen = p.chosen.clone().expect("recovery must choose");
        assert!(chosen == val(1) || chosen == val(2));
        // Exactly one Chosen announce (no divergence).
        let chosen_vals: Vec<&Value> = net
            .announces
            .iter()
            .filter_map(|a| match a {
                Announce::Chosen { value, .. } => Some(value),
                _ => None,
            })
            .collect();
        assert!(!chosen_vals.is_empty());
        assert!(chosen_vals.iter().all(|v| **v == chosen));
    }
}
