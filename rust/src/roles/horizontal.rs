//! Baseline: MultiPaxos with **horizontal reconfiguration** (§7.2, §9).
//!
//! The classic approach (Lamport's "Reconfiguring a state machine" [21]):
//! the new configuration is itself chosen as a log entry; a configuration
//! chosen at slot `s` governs slots `≥ s + α`. The leader may never run
//! more than `α` slots ahead of its chosen watermark, which is the
//! concurrency limitation the paper contrasts against (Figures 8, 10, 19).
//!
//! This leader shares the [`super::acceptor::Acceptor`] and
//! [`super::replica::Replica`] roles with Matchmaker MultiPaxos; only the
//! leader differs (no matchmakers, no matchmaking phase).

use super::sequencer::{ClientSequencer, Offered};
use crate::config::Configuration;
use crate::msg::{Command, Msg, Value};
use crate::node::{Announce, Effects, Node, Timer};
use crate::round::Round;
use crate::util::Rng;
use crate::{NodeId, Slot, Time, MS};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

#[derive(Clone, Debug)]
struct SlotState {
    value: Value,
    acks: BTreeSet<NodeId>,
    chosen: bool,
    generation: u64,
}

/// A pending configuration installed by a chosen `Reconfig` log entry:
/// effective from `from_slot`, usable once a P1 quorum of the new acceptors
/// has promised our round.
#[derive(Debug)]
struct PendingConfig {
    from_slot: Slot,
    config: Configuration,
    p1_acks: BTreeSet<NodeId>,
    ready: bool,
}

/// MultiPaxos leader with horizontal reconfiguration and α-window flow
/// control.
pub struct HorizontalLeader {
    /// This node's id.
    pub id: NodeId,
    /// The α concurrency window (§7.2): slot `s` waits on slot `s - α`.
    pub alpha: u64,
    /// Send Phase2A to a sampled P2 quorum instead of all acceptors.
    pub thrifty: bool,
    /// The replica group.
    pub replicas: Vec<NodeId>,
    rng: Rng,
    /// Phase 2 re-send interval for unanswered slots.
    pub phase2_retry: Time,

    round: Round,
    /// `(effective_from, config)` — config for slot `s` is the last entry
    /// with `effective_from ≤ s`.
    configs: Vec<(Slot, Configuration)>,
    pending: Option<PendingConfig>,

    /// Phase 1 state at startup.
    phase1: Option<BTreeSet<NodeId>>,
    steady: bool,

    log: BTreeMap<Slot, SlotState>,
    next_slot: Slot,
    chosen_watermark: Slot,
    stalled: VecDeque<Command>,
    /// Per-client FIFO admission (dedup + reorder of pipelined requests).
    sequencer: ClientSequencer,
    generation: u64,

    /// Metrics: commands stalled by the α window.
    pub alpha_stalls: u64,
    /// Metrics: reconfigurations that took effect.
    pub reconfigs_completed: u64,
}

impl HorizontalLeader {
    /// A horizontal-reconfiguration leader over `initial_config` with the
    /// given α window.
    pub fn new(
        id: NodeId,
        initial_config: Configuration,
        replicas: Vec<NodeId>,
        alpha: u64,
        seed: u64,
    ) -> HorizontalLeader {
        HorizontalLeader {
            id,
            alpha,
            thrifty: true,
            replicas,
            rng: Rng::new(seed ^ 0x4a5a),
            phase2_retry: 25 * MS,
            round: Round::first(0, id),
            configs: vec![(0, initial_config)],
            pending: None,
            phase1: None,
            steady: false,
            log: BTreeMap::new(),
            next_slot: 0,
            chosen_watermark: 0,
            stalled: VecDeque::new(),
            sequencer: ClientSequencer::new(),
            generation: 0,
            alpha_stalls: 0,
            reconfigs_completed: 0,
        }
    }

    /// True once startup Phase 1 completed and commands flow.
    pub fn is_steady(&self) -> bool {
        self.steady
    }

    fn config_for(&self, slot: Slot) -> &Configuration {
        self.configs
            .iter()
            .rev()
            .find(|(from, _)| *from <= slot)
            .map(|(_, c)| c)
            .expect("config for slot 0 always present")
    }

    /// Propose a reconfiguration: the new configuration is chosen as an
    /// ordinary log entry and becomes effective α slots later (§7.2).
    pub fn reconfigure(&mut self, new_config: Configuration, now: Time, fx: &mut Effects) {
        if !self.steady || self.pending.is_some() {
            return;
        }
        let slot = self.next_slot;
        self.next_slot += 1;
        self.propose(slot, Value::Reconfig(new_config), now, fx);
    }

    fn propose(&mut self, slot: Slot, value: Value, _now: Time, fx: &mut Effects) {
        self.generation += 1;
        let generation = self.generation;
        let cfg = self.config_for(slot).clone();
        let targets: Vec<NodeId> = if self.thrifty {
            cfg.quorum.sample_p2(&cfg.acceptors, &mut self.rng)
        } else {
            cfg.acceptors.clone()
        };
        let msg = Msg::Phase2A { round: self.round, slot, value: value.clone() };
        for &t in &targets {
            fx.send(t, msg.clone());
        }
        self.log.insert(
            slot,
            SlotState { value, acks: BTreeSet::new(), chosen: false, generation },
        );
        if self.thrifty {
            fx.timer(self.phase2_retry, Timer::Phase2Retry { slot, generation });
        }
    }

    /// The α window: slot `s` may only be proposed once slot `s - α` is
    /// chosen ("the proposer cannot have more than α outstanding
    /// operations", §7.2).
    fn window_open(&self) -> bool {
        self.next_slot < self.chosen_watermark + self.alpha
    }

    /// Admit client traffic in per-client FIFO order, then assign.
    /// Duplicates (client retries) are dropped — the replicas re-reply
    /// from their result cache when the retried command is re-chosen.
    fn on_client_request(&mut self, cmd: Command, lowest: u64, now: Time, fx: &mut Effects) {
        match self.sequencer.offer(cmd, lowest) {
            Offered::Admit(cmds) => {
                for c in cmds {
                    self.assign(c, now, fx);
                }
            }
            Offered::Duplicate(_) | Offered::Buffered => {}
        }
    }

    /// Assign a slot to an admitted (in-order, deduplicated) command.
    fn assign(&mut self, cmd: Command, now: Time, fx: &mut Effects) {
        if !self.steady {
            self.stalled.push_back(cmd);
            return;
        }
        if !self.window_open() {
            self.alpha_stalls += 1;
            self.stalled.push_back(cmd);
            return;
        }
        // If a pending config governs this slot but isn't ready, stall.
        if let Some(p) = &self.pending {
            if self.next_slot >= p.from_slot && !p.ready {
                self.stalled.push_back(cmd);
                return;
            }
        }
        let slot = self.next_slot;
        self.next_slot += 1;
        self.propose(slot, Value::Cmd(cmd), now, fx);
    }

    fn drain(&mut self, now: Time, fx: &mut Effects) {
        while !self.stalled.is_empty() && self.steady && self.window_open() {
            if let Some(p) = &self.pending {
                if self.next_slot >= p.from_slot && !p.ready {
                    break;
                }
            }
            let cmd = self.stalled.pop_front().unwrap();
            self.assign(cmd, now, fx);
        }
    }

    fn on_chosen(&mut self, slot: Slot, now: Time, fx: &mut Effects) {
        let value = self.log[&slot].value.clone();
        fx.announce(Announce::Chosen { group: 0, slot, round: self.round, value: value.clone() });
        fx.broadcast(&self.replicas.clone(), &Msg::Chosen { slot, value: value.clone() });

        // A chosen Reconfig at slot s installs the new config at s + α
        // after a Phase 1 handshake with the new acceptors.
        if let Value::Reconfig(cfg) = &value {
            let from_slot = slot + self.alpha;
            let pending = PendingConfig {
                from_slot,
                config: cfg.clone(),
                p1_acks: BTreeSet::new(),
                ready: false,
            };
            for &a in &cfg.acceptors {
                fx.send(a, Msg::Phase1A { round: self.round, from_slot });
            }
            fx.announce(Announce::ConfigActive { group: 0, round: self.round, config_id: cfg.id });
            self.pending = Some(pending);
        }

        while self.log.get(&self.chosen_watermark).map_or(false, |s| s.chosen) {
            self.chosen_watermark += 1;
        }
        self.drain(now, fx);
    }
}

impl Node for HorizontalLeader {
    fn on_start(&mut self, _now: Time, fx: &mut Effects) {
        // Phase 1 with the initial configuration (fresh log: no votes).
        self.phase1 = Some(BTreeSet::new());
        let cfg = self.configs[0].1.clone();
        for &a in &cfg.acceptors {
            fx.send(a, Msg::Phase1A { round: self.round, from_slot: 0 });
        }
    }

    fn on_msg(&mut self, now: Time, from: NodeId, msg: Msg, fx: &mut Effects) {
        match msg {
            Msg::ClientRequest { cmd, lowest, .. } => {
                self.on_client_request(cmd, lowest, now, fx);
            }
            Msg::Phase1B { round, votes, .. } => {
                if round != self.round {
                    return;
                }
                // Startup Phase 1?
                if let Some(acks) = &mut self.phase1 {
                    acks.insert(from);
                    for v in votes {
                        // Adopt prior votes (restart recovery).
                        let generation = self.generation;
                        self.log.entry(v.slot).or_insert(SlotState {
                            value: v.vv,
                            acks: BTreeSet::new(),
                            chosen: false,
                            generation,
                        });
                        self.next_slot = self.next_slot.max(v.slot + 1);
                    }
                    if self.configs[0].1.is_p1_quorum(acks) {
                        self.phase1 = None;
                        self.steady = true;
                        fx.announce(Announce::LeaderSteady { round: self.round });
                        // Re-propose adopted entries.
                        let slots: Vec<Slot> = self
                            .log
                            .iter()
                            .filter(|(_, s)| !s.chosen)
                            .map(|(s, _)| *s)
                            .collect();
                        for s in slots {
                            let v = self.log[&s].value.clone();
                            self.propose(s, v, now, fx);
                        }
                        self.drain(now, fx);
                    }
                    return;
                }
                // Pending-config Phase 1 handshake.
                if let Some(p) = &mut self.pending {
                    if p.config.acceptors.contains(&from) {
                        p.p1_acks.insert(from);
                        if p.config.is_p1_quorum(&p.p1_acks) && !p.ready {
                            p.ready = true;
                            let from_slot = p.from_slot;
                            let config = p.config.clone();
                            self.configs.push((from_slot, config));
                            self.pending = None;
                            self.reconfigs_completed += 1;
                            self.drain(now, fx);
                        }
                    }
                }
            }
            Msg::Phase2B { round, slot } => {
                if round != self.round {
                    return;
                }
                let cfg = self.config_for(slot).clone();
                let Some(ss) = self.log.get_mut(&slot) else { return };
                if ss.chosen {
                    return;
                }
                ss.acks.insert(from);
                if cfg.is_p2_quorum(&ss.acks) {
                    ss.chosen = true;
                    self.on_chosen(slot, now, fx);
                }
            }
            Msg::ReplicaAck { upto } => {
                // Replica catch-up, same as the matchmaker leader.
                if upto < self.chosen_watermark {
                    let end = (upto + 256).min(self.chosen_watermark);
                    for slot in upto..end {
                        if let Some(ss) = self.log.get(&slot) {
                            if ss.chosen {
                                fx.send(from, Msg::Chosen { slot, value: ss.value.clone() });
                            }
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, _now: Time, timer: Timer, fx: &mut Effects) {
        if let Timer::Phase2Retry { slot, generation } = timer {
            let Some(ss) = self.log.get(&slot) else { return };
            if ss.chosen || ss.generation != generation {
                return;
            }
            let value = ss.value.clone();
            let cfg = self.config_for(slot).clone();
            fx.broadcast(&cfg.acceptors, &Msg::Phase2A { round: self.round, slot, value });
            fx.timer(self.phase2_retry, Timer::Phase2Retry { slot, generation });
        }
    }

    fn role(&self) -> &'static str {
        "horizontal-leader"
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roles::{Acceptor, Replica};
    use crate::statemachine::Noop;

    struct Pump {
        leader: HorizontalLeader,
        accs: Vec<Acceptor>,
        reps: Vec<Replica>,
        announces: Vec<Announce>,
    }

    impl Pump {
        fn new(alpha: u64) -> Pump {
            // leader=0, acceptors 4..10 (pool of 6), replicas 10..13
            let cfg = Configuration::majority(0, vec![4, 5, 6]);
            let mut leader = HorizontalLeader::new(0, cfg, vec![10, 11, 12], alpha, 1);
            leader.thrifty = false;
            Pump {
                leader,
                accs: (4..10).map(Acceptor::new).collect(),
                reps: (10..13).map(|id| Replica::new(id, Box::new(Noop))).collect(),
                announces: Vec::new(),
            }
        }

        fn pump(&mut self, mut fx: Effects) {
            let mut q: VecDeque<(NodeId, NodeId, Msg)> = VecDeque::new();
            self.announces.extend(fx.announces.drain(..));
            for (to, m) in fx.msgs.drain(..) {
                q.push_back((0, to, m));
            }
            while let Some((from, to, msg)) = q.pop_front() {
                let mut fx = Effects::new();
                match to {
                    0 => self.leader.on_msg(0, from, msg, &mut fx),
                    4..=9 => self.accs[(to - 4) as usize].on_msg(0, from, msg, &mut fx),
                    10..=12 => self.reps[(to - 10) as usize].on_msg(0, from, msg, &mut fx),
                    _ => {}
                }
                self.announces.extend(fx.announces.drain(..));
                for (dst, m) in fx.msgs.drain(..) {
                    q.push_back((to, dst, m));
                }
            }
        }

        fn start(&mut self) {
            let mut fx = Effects::new();
            self.leader.on_start(0, &mut fx);
            self.pump(fx);
        }

        fn cmd(&mut self, client: NodeId, seq: u64) {
            let mut fx = Effects::new();
            let cmd = Command { client, seq, payload: vec![0] };
            self.leader.on_msg(0, client, Msg::ClientRequest { group: 0, cmd, lowest: seq }, &mut fx);
            self.pump(fx);
        }
    }

    #[test]
    fn startup_and_commands() {
        let mut p = Pump::new(8);
        p.start();
        assert!(p.leader.is_steady());
        for seq in 1..=5 {
            p.cmd(100, seq);
        }
        assert_eq!(p.leader.chosen_watermark, 5);
        for r in &p.reps {
            assert_eq!(r.exec_watermark, 5);
        }
    }

    #[test]
    fn horizontal_reconfiguration() {
        let mut p = Pump::new(4);
        p.start();
        p.cmd(100, 1);
        let new_cfg = Configuration::majority(1, vec![7, 8, 9]);
        let mut fx = Effects::new();
        p.leader.reconfigure(new_cfg.clone(), 0, &mut fx);
        p.pump(fx);
        assert_eq!(p.leader.reconfigs_completed, 1);
        // Commands past the α boundary use the new config.
        for seq in 2..=8 {
            p.cmd(100, seq);
        }
        assert_eq!(p.leader.chosen_watermark, 9); // 1 cmd + reconfig + 7 cmds
        // Slot 9 (≥ 1 + α = 5) must be governed by the new config.
        assert_eq!(p.leader.config_for(8).id, 1);
        assert_eq!(p.leader.config_for(4).id, 0);
    }

    #[test]
    fn alpha_window_stalls() {
        // α = 1: every command must wait for the previous to be chosen.
        // In the synchronous pump this never stalls; verify the window
        // logic directly instead.
        let mut p = Pump::new(1);
        p.start();
        p.cmd(100, 1);
        assert_eq!(p.leader.alpha_stalls, 0);
        assert!(p.leader.window_open());
        // Simulate an unchosen outstanding slot.
        p.leader.next_slot = p.leader.chosen_watermark + 1;
        assert!(!p.leader.window_open());
    }

    #[test]
    fn replica_catchup() {
        let mut p = Pump::new(8);
        p.start();
        for seq in 1..=3 {
            p.cmd(100, seq);
        }
        // A replica that lost everything asks implicitly via a low ack.
        let mut fx = Effects::new();
        p.leader.on_msg(0, 10, Msg::ReplicaAck { upto: 0 }, &mut fx);
        let resent = fx.msgs.iter().filter(|(_, m)| matches!(m, Msg::Chosen { .. })).count();
        assert_eq!(resent, 3);
    }
}
