//! Leader-side per-client request sequencing.
//!
//! Pipelined clients keep a window of requests in flight, and the network
//! is free to reorder them, so a leader can receive `seq 3` before
//! `seq 2`. Admitting requests in arrival order would assign log slots
//! out of client order (breaking per-client FIFO execution) and — worse —
//! a naive "highest seq wins" dedup table would silently drop the late
//! `seq 2` forever. The [`ClientSequencer`] restores per-client FIFO:
//! requests are buffered until their seq is next, then admitted in
//! contiguous order.
//!
//! The client advertises `lowest` — its oldest in-flight seq — on every
//! request. Seqs below `lowest` are acknowledged client-side, so the
//! sequencer can initialize its cursor mid-stream (a new leader taking
//! over sees `lowest = k` and starts at `k` rather than waiting for a
//! `seq 1` that was settled long ago) and retire stale buffered entries.
//!
//! Overload control (DESIGN.md §Overload) composes with this by staying
//! *in front of* it: the leader's admission check refuses a request with
//! [`crate::msg::Msg::Busy`] before [`ClientSequencer::offer`] is
//! called, so a rejection is a drop, not an ack — no cursor or buffer
//! state moves. A retried seq is later admitted in its normal FIFO
//! position, and a seq the client *sheds* on `Busy` heals through the
//! same `lowest` mechanism: the shed seq leaves the client's window, the
//! next request advertises a floor above it, and the cursor jumps the
//! gap instead of waiting for a request that can no longer be resent.

use crate::msg::Command;
use crate::NodeId;
use std::collections::{BTreeMap, HashMap};

#[derive(Debug, Default)]
struct ClientCursor {
    /// Next seq to admit; 0 = uninitialized (client seqs start at 1).
    next: u64,
    /// Out-of-order arrivals waiting for the gap to fill.
    pending: BTreeMap<u64, Command>,
}

/// What [`ClientSequencer::offer`] decided about an arriving request.
#[derive(Debug)]
pub enum Offered {
    /// The request (and possibly buffered successors) are now in order:
    /// propose them, in this order.
    Admit(Vec<Command>),
    /// The request was already admitted earlier (a client retry): answer
    /// from the dedup/chosen state, do not assign a new slot.
    Duplicate(Command),
    /// Out of order: buffered until the gap fills. Nothing to do.
    Buffered,
}

/// Per-client FIFO admission control for a leader.
#[derive(Debug, Default)]
pub struct ClientSequencer {
    cursors: HashMap<NodeId, ClientCursor>,
}

impl ClientSequencer {
    /// An empty sequencer (cursors initialize on first contact).
    pub fn new() -> ClientSequencer {
        ClientSequencer::default()
    }

    /// Feed one arriving request. `lowest` is the client's advertised
    /// oldest in-flight seq (see [`crate::msg::Msg::ClientRequest`]).
    pub fn offer(&mut self, cmd: Command, lowest: u64) -> Offered {
        let cur = self.cursors.entry(cmd.client).or_default();
        if cur.next == 0 {
            // First contact with this client: trust its window floor.
            cur.next = lowest.max(1);
        } else if lowest > cur.next {
            // The client acknowledged everything below `lowest` (this can
            // outrun us after a leader change); drop settled buffer state.
            cur.next = lowest;
            cur.pending = cur.pending.split_off(&lowest);
        }
        if cmd.seq < cur.next {
            return Offered::Duplicate(cmd);
        }
        cur.pending.insert(cmd.seq, cmd);
        let mut ready = Vec::new();
        while let Some(c) = cur.pending.remove(&cur.next) {
            cur.next += 1;
            ready.push(c);
        }
        if ready.is_empty() {
            Offered::Buffered
        } else {
            Offered::Admit(ready)
        }
    }

    /// Number of requests buffered across all clients (diagnostics).
    #[allow(clippy::disallowed_methods)] // order-insensitive sum over values
    pub fn buffered(&self) -> usize {
        self.cursors.values().map(|c| c.pending.len()).sum()
    }

    /// Canonical (sorted) rendering for the model checker's state
    /// fingerprint — the cursors live in a `HashMap`, whose `Debug`
    /// order is not deterministic across processes.
    pub fn state_repr(&self) -> String {
        #[allow(clippy::disallowed_methods)] // sorted immediately below
        let mut clients: Vec<(&NodeId, &ClientCursor)> = self.cursors.iter().collect();
        clients.sort_by_key(|(id, _)| **id);
        let mut s = String::new();
        for (id, cur) in clients {
            use std::fmt::Write;
            let _ = write!(s, "c{}@{}{:?};", id, cur.next, cur.pending);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd(client: NodeId, seq: u64) -> Command {
        Command { client, seq, payload: vec![] }
    }

    fn admit_seqs(o: Offered) -> Vec<u64> {
        match o {
            Offered::Admit(v) => v.into_iter().map(|c| c.seq).collect(),
            other => panic!("expected Admit, got {other:?}"),
        }
    }

    #[test]
    fn in_order_stream_admits_immediately() {
        let mut s = ClientSequencer::new();
        for seq in 1..=5 {
            assert_eq!(admit_seqs(s.offer(cmd(7, seq), seq)), vec![seq]);
        }
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn reordered_window_admits_in_fifo_order() {
        let mut s = ClientSequencer::new();
        // seq 3 and 2 arrive before 1 (network reorder, window = 3).
        assert!(matches!(s.offer(cmd(7, 3), 1), Offered::Buffered));
        assert!(matches!(s.offer(cmd(7, 2), 1), Offered::Buffered));
        assert_eq!(s.buffered(), 2);
        // seq 1 unblocks the whole run, in order.
        assert_eq!(admit_seqs(s.offer(cmd(7, 1), 1)), vec![1, 2, 3]);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn retries_are_duplicates() {
        let mut s = ClientSequencer::new();
        s.offer(cmd(7, 1), 1);
        assert!(matches!(s.offer(cmd(7, 1), 1), Offered::Duplicate(_)));
    }

    #[test]
    fn midstream_start_uses_lowest() {
        // A new leader first hears seq 42 with lowest = 41: it must not
        // wait for seq 1.
        let mut s = ClientSequencer::new();
        assert!(matches!(s.offer(cmd(7, 42), 41), Offered::Buffered));
        assert_eq!(admit_seqs(s.offer(cmd(7, 41), 41)), vec![41, 42]);
    }

    #[test]
    fn advancing_lowest_retires_buffered_state() {
        let mut s = ClientSequencer::new();
        assert!(matches!(s.offer(cmd(7, 3), 1), Offered::Buffered));
        // The client advances past the gap (it got its seq 1-3 replies
        // from the previous leader); the stale buffer entry is dropped.
        assert_eq!(admit_seqs(s.offer(cmd(7, 4), 4)), vec![4]);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn busy_shed_gap_heals_via_lowest() {
        // seq 2 was refused with Busy and shed client-side — it never
        // reached the sequencer (a Busy is a drop, not an ack). seq 3,
        // issued after the shed, advertises lowest = 3: the cursor must
        // jump the gap rather than wait for a seq 2 that can no longer
        // be resent.
        let mut s = ClientSequencer::new();
        assert_eq!(admit_seqs(s.offer(cmd(7, 1), 1)), vec![1]);
        assert_eq!(admit_seqs(s.offer(cmd(7, 3), 3)), vec![3]);
        assert_eq!(s.buffered(), 0);
        // Ordinary flow continues after the healed gap.
        assert_eq!(admit_seqs(s.offer(cmd(7, 4), 4)), vec![4]);
    }

    #[test]
    fn clients_are_independent() {
        let mut s = ClientSequencer::new();
        assert!(matches!(s.offer(cmd(1, 2), 1), Offered::Buffered));
        assert_eq!(admit_seqs(s.offer(cmd(2, 1), 1)), vec![1]);
        assert_eq!(admit_seqs(s.offer(cmd(1, 1), 1)), vec![1, 2]);
    }
}
